//! Open-loop load test: Poisson-arrival workload trace replayed against a
//! live serving stack — queueing delay vs service time under pressure.
//!
//!   cargo run --release --example load_test [requests] [rate_rps]

use std::sync::Arc;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{self, Client, Server, ServerConfig, TraceSpec};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let rate_rps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(15.0);

    let registry = Arc::new(Registry::load("artifacts").expect("run `make artifacts` first"));
    let coord = Arc::new(Coordinator::new(
        registry,
        CoordinatorConfig { workers: 2, queue_cap: 32, ..Default::default() },
    ));
    let metrics = coord.metrics();
    let server = Server::bind(&ServerConfig::ephemeral(), coord).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    let spec = TraceSpec {
        requests,
        rate_rps,
        sizes: vec![128, 256],
        sparsities: vec![0.98, 0.99, 0.995],
        patterns: vec!["uniform".into(), "banded".into()],
        seed: 0x10AD,
    };
    let items = serve::generate_trace(&spec);
    println!(
        "trace: {} requests over {:.1}s (λ={} rps) against {addr}",
        items.len(),
        items.last().unwrap().arrival_s,
        rate_rps
    );

    // Each replay worker holds one connection (connection pool of 4).
    let conns: Vec<std::sync::Mutex<Client>> = (0..4)
        .map(|_| std::sync::Mutex::new(Client::connect(&addr).unwrap()))
        .collect();
    let next_conn = std::sync::atomic::AtomicUsize::new(0);
    let report = serve::replay_trace(&items, 4, |item| {
        let idx = next_conn.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % conns.len();
        let mut c = conns[idx].lock().unwrap();
        let r = c
            .spdm_synthetic(item.id, item.n, item.sparsity, &item.pattern, item.seed, "auto", false)
            .map_err(|e| e)?;
        if r.ok {
            Ok(())
        } else {
            Err(r.error.unwrap_or_default())
        }
    });

    println!("\n=== open-loop load report ===");
    println!("completed: {} / failed: {}", report.completed, report.failed);
    println!("wall time: {:.2}s  goodput: {:.1} rps", report.wall_s, report.throughput_rps());
    println!(
        "latency (arrival→done): p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms",
        report.p(50.0) * 1e3,
        report.p(95.0) * 1e3,
        report.p(99.0) * 1e3
    );
    let max_late = report.lateness_s.iter().copied().fold(0.0, f64::max);
    println!("max queueing lateness: {:.1} ms", max_late * 1e3);
    println!("\nserver metrics:\n{}", metrics.snapshot().render());
    assert_eq!(report.failed, 0);

    drop(conns); // close pooled connections before asking for shutdown
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.shutdown(u64::MAX).unwrap();
    server_thread.join().unwrap();
    println!("\nload_test OK");
}
