//! Open-loop load test: Poisson-arrival workload trace replayed against a
//! live serving stack — queueing delay vs service time under pressure,
//! with a shared-A pool exercising the operand-handle path (protocol v2):
//! each pooled A is registered once (`put_a`), then multiplied by
//! reference with synthetic Bs, so the report shows the store hit rate and
//! the server's conversion amortization.
//!
//!   cargo run --release --example load_test [requests] [rate_rps]

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{self, Client, ReplayOutcome, Server, ServerConfig, TraceSpec};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let rate_rps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(15.0);

    let registry = Arc::new(Registry::load("artifacts").expect("run `make artifacts` first"));
    let coord = Arc::new(Coordinator::new(
        registry,
        CoordinatorConfig { workers: 2, queue_cap: 32, ..Default::default() },
    ));
    let server = Server::bind(&ServerConfig::ephemeral(), Arc::clone(&coord)).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run().unwrap());

    // A small pool of hot As under zipfian skew — the shape of real
    // serving traffic (a few hot models dominate).
    let spec = TraceSpec {
        requests,
        rate_rps,
        sizes: vec![128, 256],
        sparsities: vec![0.98, 0.99, 0.995],
        patterns: vec!["uniform".into(), "banded".into()],
        seed: 0x10AD,
        shared_a_pool: 3,
        shared_a_zipf: 1.0,
    };
    let pool = serve::shared_pool(&spec);
    let items = serve::generate_trace(&spec);
    println!(
        "trace: {} requests over {:.1}s (λ={} rps), {} shared As (zipf {}), against {addr}",
        items.len(),
        items.last().unwrap().arrival_s,
        rate_rps,
        pool.len(),
        spec.shared_a_zipf,
    );

    // Each replay worker holds one connection (connection pool of 4);
    // slot → a_handle fills lazily on first use (a store miss).
    let conns: Vec<Mutex<Client>> = (0..4)
        .map(|_| Mutex::new(Client::connect(&addr).unwrap()))
        .collect();
    let next_conn = std::sync::atomic::AtomicUsize::new(0);
    let handles: Mutex<HashMap<usize, u64>> = Mutex::new(HashMap::new());
    let report = serve::replay_trace(&items, 4, |item| {
        let idx = next_conn.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % conns.len();
        let mut c = conns[idx].lock().unwrap();
        let slot = item.a_slot.expect("pooled trace");
        // Hold the map lock across the miss path so concurrent workers
        // cannot double-register a slot and overcount misses (the server
        // would dedup the handle, but the reported hit rate would skew).
        // Registrations happen at most pool-size times, so the brief
        // serialization is irrelevant to the measured traffic.
        let (handle, outcome) = {
            let mut map = handles.lock().unwrap();
            match map.get(&slot).copied() {
                Some(h) => (h, ReplayOutcome::store_hit()),
                None => {
                    let a = &pool[slot];
                    let r = c.put_a_synthetic(item.id, a.n, a.sparsity, &a.pattern, a.seed, "auto")?;
                    if !r.ok {
                        return Err(r.error.unwrap_or_default());
                    }
                    let h = r.a_handle.expect("put_a reply carries the handle");
                    map.insert(slot, h);
                    (h, ReplayOutcome::store_miss())
                }
            }
        };
        let r = c.spdm_handle_synthetic_b(item.id, handle, item.seed, false)?;
        if r.ok {
            Ok(match r.algo {
                Some(a) => outcome.with_algo(a),
                None => outcome,
            })
        } else {
            Err(r.error.unwrap_or_default())
        }
    });

    println!("\n=== open-loop load report ===");
    println!("completed: {} / failed: {}", report.completed, report.failed);
    println!("wall time: {:.2}s  goodput: {:.1} rps", report.wall_s, report.throughput_rps());
    println!(
        "latency (arrival→done): p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms",
        report.p(50.0) * 1e3,
        report.p(95.0) * 1e3,
        report.p(99.0) * 1e3
    );
    let max_late = report.lateness_s.iter().copied().fold(0.0, f64::max);
    println!("max queueing lateness: {:.1} ms", max_late * 1e3);
    println!(
        "operand store: {} hits / {} misses (hit rate {:.1}%)",
        report.store_hits,
        report.store_misses,
        report.store_hit_rate() * 100.0
    );
    println!("\nserver metrics:\n{}", coord.snapshot().render());
    assert_eq!(report.failed, 0);

    drop(conns); // close pooled connections before asking for shutdown
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.shutdown(u64::MAX).unwrap();
    server_thread.join().unwrap();
    println!("\nload_test OK");
}
