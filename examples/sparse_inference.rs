//! Sparse-MLP inference — the paper's deep-learning motivation (§I: SpDM as
//! "a potential faster implementation for sparse deep learning").
//!
//! Builds a 3-layer MLP whose weight matrices have been magnitude-pruned to
//! 98–99.5% sparsity, then runs batched inference where every layer is a
//! sparse-weight × dense-activation product executed through the coordinator
//! (GCOO kernels), and compares against (a) the dense baseline route and
//! (b) the CPU oracle.
//!
//!   cargo run --release --example sparse_inference

use std::sync::Arc;

use gcoospdm::coordinator::{Algo, Coordinator, CoordinatorConfig, SpdmRequest};
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::Registry;

/// Magnitude-prune a dense weight matrix to the target sparsity.
fn prune(w: &Mat, sparsity: f64) -> Mat {
    let mut mags: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = mags[((mags.len() as f64 * sparsity) as usize).min(mags.len() - 1)];
    let mut out = w.clone();
    for v in out.data.iter_mut() {
        if v.abs() < threshold {
            *v = 0.0;
        }
    }
    out
}

fn relu(m: &mut Mat) {
    for v in m.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn main() {
    let registry = Arc::new(Registry::load("artifacts").expect("run `make artifacts` first"));
    let coord = Coordinator::new(Arc::clone(&registry), CoordinatorConfig::default());

    // Model: 256 → 256 → 256 → 256 MLP, pruned per layer.
    let n = 256;
    let layer_sparsity = [0.99, 0.995, 0.98];
    let mut rng = Rng::new(2024);
    let weights: Vec<Mat> = layer_sparsity
        .iter()
        .map(|&s| {
            // He-style init scaled, then pruned.
            let mut w = Mat::randn(n, n, &mut rng);
            for v in w.data.iter_mut() {
                *v *= (2.0 / n as f32).sqrt();
            }
            prune(&w, s)
        })
        .collect();
    for (i, w) in weights.iter().enumerate() {
        println!("layer {i}: sparsity {:.4} ({} nnz)", w.sparsity(), w.nnz());
    }

    // Batch of activations (batch across columns: X is n × batch, padded to n×n).
    let x0 = Mat::randn(n, n, &mut rng);

    // --- sparse route: every layer through GCOO kernels ---
    let t0 = std::time::Instant::now();
    let mut x = x0.clone();
    let mut kernel_ms = 0.0;
    for (i, w) in weights.iter().enumerate() {
        let mut req = SpdmRequest::new(i as u64, w.clone(), x.clone());
        req.algo_hint = Some(Algo::Gcoo);
        let resp = coord.run_sync(req);
        assert!(resp.ok(), "layer {i}: {:?}", resp.error);
        kernel_ms += resp.kernel_s * 1e3;
        x = resp.c.unwrap();
        if i + 1 < weights.len() {
            relu(&mut x);
        }
    }
    let sparse_total = t0.elapsed().as_secs_f64() * 1e3;
    let sparse_out = x;

    // --- dense route: same network, dense kernels ---
    let t1 = std::time::Instant::now();
    let mut xd = x0.clone();
    let mut dense_kernel_ms = 0.0;
    for (i, w) in weights.iter().enumerate() {
        let mut req = SpdmRequest::new(100 + i as u64, w.clone(), xd.clone());
        req.algo_hint = Some(Algo::DenseXla);
        let resp = coord.run_sync(req);
        assert!(resp.ok());
        dense_kernel_ms += resp.kernel_s * 1e3;
        xd = resp.c.unwrap();
        if i + 1 < weights.len() {
            relu(&mut xd);
        }
    }
    let dense_total = t1.elapsed().as_secs_f64() * 1e3;

    // --- CPU oracle ---
    let mut xo = x0;
    for (i, w) in weights.iter().enumerate() {
        xo = w.matmul(&xo);
        if i + 1 < weights.len() {
            relu(&mut xo);
        }
    }

    println!("\nsparse route:  kernels {kernel_ms:.2} ms, end-to-end {sparse_total:.2} ms");
    println!("dense  route:  kernels {dense_kernel_ms:.2} ms, end-to-end {dense_total:.2} ms");
    println!(
        "routes agree:  sparse-vs-dense max|Δ| = {:.2e}, sparse-vs-oracle max|Δ| = {:.2e}",
        sparse_out.max_abs_diff(&xd),
        sparse_out.max_abs_diff(&xo)
    );
    assert!(sparse_out.allclose(&xo, 1e-2, 1e-2), "sparse route diverged from oracle");
    assert!(xd.allclose(&xo, 1e-2, 1e-2), "dense route diverged from oracle");
    println!("sparse_inference OK");
}
