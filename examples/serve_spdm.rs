//! End-to-end serving driver (the E2E validation run of EXPERIMENTS.md):
//! boots the full serving stack — TCP server → coordinator (2 workers,
//! bounded queue, shape-affine batching) → per-worker PJRT engines — then
//! drives a mixed synthetic workload through real client connections and
//! reports latency percentiles, throughput, routing distribution, and
//! verification results.
//!
//!   cargo run --release --example serve_spdm [requests] [clients]

use std::sync::Arc;
use std::time::Instant;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig};
use gcoospdm::ndarray::percentile;
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{Client, Server, ServerConfig};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let total_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let clients: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    // --- boot the stack ---
    let registry = Arc::new(Registry::load("artifacts").expect("run `make artifacts` first"));
    let coord = Arc::new(Coordinator::new(
        Arc::clone(&registry),
        CoordinatorConfig { workers: 2, queue_cap: 32, batch_max: 8, ..Default::default() },
    ));
    let metrics = coord.metrics();
    let server = Server::bind(&ServerConfig::ephemeral(), coord).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_thread = std::thread::spawn(move || server.run().unwrap());
    println!("server on {addr}; {clients} clients × {} requests", total_requests / clients);

    // --- drive a mixed workload: sizes, sparsities, patterns ---
    let sizes = [128usize, 200, 256, 400, 512];
    let sparsities = [0.95, 0.98, 0.99, 0.995, 0.5];
    let patterns = ["uniform", "banded", "diagonal", "power_law_rows"];
    let started = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let per_client = total_requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut lat_ms = Vec::new();
            let mut verified = 0usize;
            for i in 0..per_client {
                let id = (c * per_client + i) as u64;
                let n = sizes[(c + i) % sizes.len()];
                let s = sparsities[(c * 3 + i) % sparsities.len()];
                let pat = patterns[(c + 2 * i) % patterns.len()];
                let t0 = Instant::now();
                let r = client
                    .spdm_synthetic(id, n, s, pat, id, "auto", true)
                    .expect("request");
                assert!(r.ok, "request {id} failed: {:?}", r.error);
                if r.verified == Some(true) {
                    verified += 1;
                }
                lat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            }
            (lat_ms, verified)
        }));
    }

    let mut all_lat = Vec::new();
    let mut all_verified = 0;
    for h in handles {
        let (lat, v) = h.join().unwrap();
        all_lat.extend(lat);
        all_verified += v;
    }
    let elapsed = started.elapsed().as_secs_f64();

    // --- report ---
    println!("\n=== end-to-end serving report ===");
    println!("requests:      {}", all_lat.len());
    println!("verified OK:   {all_verified}/{}", all_lat.len());
    println!("wall time:     {elapsed:.2} s");
    println!("throughput:    {:.1} req/s", all_lat.len() as f64 / elapsed);
    println!(
        "client latency: p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms | max {:.1} ms",
        percentile(&all_lat, 50.0),
        percentile(&all_lat, 95.0),
        percentile(&all_lat, 99.0),
        percentile(&all_lat, 100.0)
    );
    let snap = metrics.snapshot();
    println!("\nserver-side metrics:\n{}", snap.render());
    assert_eq!(all_verified, all_lat.len(), "every request must verify");
    assert_eq!(snap.errors, 0);

    // --- shut down cleanly ---
    let mut ctl = Client::connect(&addr).unwrap();
    ctl.shutdown(u64::MAX).unwrap();
    server_thread.join().unwrap();
    println!("\nserve_spdm OK");
}
