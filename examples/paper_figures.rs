//! Regenerate a compact version of every paper table/figure in one run and
//! print the headline reproduction checks.
//!
//!   cargo run --release --example paper_figures
//!
//! (Full-scale sweeps: `gcoospdm figures --fig all --full`, or the
//! per-figure `cargo bench` targets.)

use gcoospdm::figures;

fn main() {
    println!("### Fig 1 — roofline ###");
    figures::fig1_roofline().print();

    println!("\n### Table I — memory consumption ###");
    figures::table1_memory().print();

    println!("\n### Fig 4 — public-corpus histogram (scaled: 60 matrices) ###");
    figures::fig4_public_hist(60, 768).print();

    println!("\n### Table III / Fig 5 — 14 selected matrices ###");
    figures::fig5_selected(768).print();

    println!("\n### Fig 6 — random-matrix histogram (scaled: 60 matrices) ###");
    figures::fig6_random_hist(60, 1024).print();

    println!("\n### Figs 7-9 — time vs sparsity ###");
    figures::fig7_9_time_vs_sparsity().print();

    println!("\n### Figs 10-12 — perf vs size ###");
    figures::fig10_12_perf_vs_size().print();

    println!("\n### Fig 13 — EO/KC breakdown ###");
    figures::fig13_breakdown().print();

    println!("\n### Fig 14 — instruction distributions ###");
    figures::fig14_instructions().print();

    println!("\n### Fig 15 — scaling behaviors ###");
    figures::fig15_scaling().print();

    println!("\nall figures regenerated; CSVs under results/");
}
