//! Quickstart: the smallest end-to-end use of the public API.
//!
//!   cargo run --release --example quickstart
//!
//! Generates a sparse matrix, runs C = A·B through the full stack
//! (dense→GCOO conversion → algorithm selection → AOT PJRT kernel), checks
//! the result against the CPU oracle, and prints the timing split.

use std::sync::Arc;

use gcoospdm::coordinator::{Coordinator, CoordinatorConfig, SpdmRequest};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::Registry;

fn main() {
    // 1. Load the AOT artifact registry (built once by `make artifacts`).
    let registry = Arc::new(Registry::load("artifacts").expect("run `make artifacts` first"));
    println!("loaded {} artifacts", registry.artifacts.len());

    // 2. Start a coordinator (owns the PJRT engines and the job queue).
    let coord = Coordinator::new(registry, CoordinatorConfig::default());

    // 3. Build a workload: a 512×512 matrix at 99% sparsity times a dense B.
    let mut rng = Rng::new(7);
    let a = gen::uniform(512, 0.99, &mut rng);
    let b = Mat::randn(512, 512, &mut rng);
    println!("A: 512x512, nnz = {}, sparsity = {:.4}", a.nnz(), a.sparsity());

    // 4. Run it. `verify` cross-checks against the CPU oracle.
    let mut req = SpdmRequest::new(1, a, b);
    req.verify = true;
    let resp = coord.run_sync(req);

    assert!(resp.ok(), "request failed: {:?}", resp.error);
    println!(
        "routed to {} ({}), n_exec = {}",
        resp.algo.as_str(),
        resp.artifact,
        resp.n_exec
    );
    println!(
        "convert (EO) {:.3} ms | kernel (KC) {:.3} ms | total {:.3} ms",
        resp.convert_s * 1e3,
        resp.kernel_s * 1e3,
        resp.total_s * 1e3
    );
    println!("verified against CPU oracle: {:?}", resp.verified);
    assert_eq!(resp.verified, Some(true));
    println!("quickstart OK");
}
