//! SpMV extension (paper §VI future work: "extend the GCOO storage
//! format"): y = A·x through the gcoo_spmv AOT kernel, verified against
//! the CPU oracle, with a power-iteration demo on a sparse graph matrix.
//!
//!   cargo run --release --example spmv

use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::{Engine, Registry};
use gcoospdm::sparse::Gcoo;

fn main() {
    let reg = Registry::load("artifacts").expect("run `make artifacts` first");
    let engine = Engine::new().expect("PJRT CPU client");
    let n = 256;

    // A sparse "graph adjacency"-like matrix (power-law rows).
    let mut rng = Rng::new(31);
    let a = gen::power_law_rows(n, 0.98, &mut rng);
    let gcoo = Gcoo::from_dense(&a, 8);
    let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
    println!("A: {n}x{n}, nnz={}, sparsity={:.4}", a.nnz(), a.sparsity());

    // Single SpMV vs oracle.
    let x: Vec<f32> = (0..n).map(|i| (i as f32 / n as f32) - 0.5).collect();
    let (y, kernel_s, artifact) = engine.run_gcoo_spmv(&reg, &padded, &x).unwrap();
    let oracle = a.matmul(&Mat::from_vec(n, 1, x.clone()));
    let max_err = y
        .iter()
        .zip(&oracle.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("spmv via {artifact}: kernel {:.3} ms, max|Δ| vs oracle = {max_err:.2e}", kernel_s * 1e3);
    assert!(max_err < 1e-3);

    // Power iteration: dominant eigenvector of (A normalized, made symmetric-ish).
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    let mut lambda = 0.0f32;
    for iter in 0..20 {
        let (mut w, _t, _a) = engine.run_gcoo_spmv(&reg, &padded, &v).unwrap();
        let norm = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm < 1e-20 {
            break;
        }
        for x in w.iter_mut() {
            *x /= norm;
        }
        lambda = norm;
        v = w;
        if iter % 5 == 4 {
            println!("iter {:>2}: |A v| = {lambda:.4}", iter + 1);
        }
    }
    // Check the Rayleigh quotient against the oracle matvec.
    let av = a.matmul(&Mat::from_vec(n, 1, v.clone()));
    let rq: f32 = v.iter().zip(&av.data).map(|(a, b)| a * b).sum();
    println!("dominant |eigenvalue| ≈ {lambda:.4} (Rayleigh {rq:.4})");
    assert!((lambda - rq.abs()).abs() / lambda.max(1e-6) < 0.2);
    println!("spmv OK");
}
