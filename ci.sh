#!/usr/bin/env bash
# CI for the gcoospdm crate: the tier-1 verify plus full target coverage.
#
#   ./ci.sh            # build + test + compile all benches/examples
#   ./ci.sh --quick    # serving fast path: the trace-vs-walker and
#                      # batched-vs-sequential and adaptive-routing
#                      # differential suites, the simgpu trace lib tests,
#                      # the operand-handle (protocol v2 + store) suites,
#                      # the tuner property suites, and the serve_hotpath
#                      # quick bench (emits BENCH_6.json)
#
# The crate is std-only (offline build; see DESIGN.md §2), so no network or
# vendored registry is required.
set -euo pipefail
cd "$(dirname "$0")/rust"

if [[ "${1:-}" == "--quick" ]]; then
  echo "== quick: trace-vs-walker differential suite (corpus sweep + engine traces + determinism) =="
  cargo test -q --test trace_differential

  echo "== quick: simgpu trace lib tests (sinks, recorder, replay, oracle) =="
  cargo test -q --lib simgpu::trace

  echo "== quick: batched-vs-sequential differential suite =="
  cargo test -q --test batch_differential

  echo "== quick: adaptive-routing differential suite (bitwise, exact flip index, trace determinism) =="
  cargo test -q --test routing_differential

  echo "== quick: operand-handle API (protocol v2 round trips + handle-vs-inline differential) =="
  cargo test -q --test handle_api

  echo "== quick: tuner invariants (EWMA bounds, sample gate, pure exploration draws) =="
  cargo test -q --lib coordinator::tuner

  echo "== quick: operand store invariants (LRU, byte budget, pins, flip/pin versioning) + protocol validation =="
  cargo test -q --lib coordinator::store
  cargo test -q --lib serve::protocol

  echo "== quick: serve_hotpath (req/s, copies avoided, batched + handle + adaptive-vs-static A/Bs) =="
  cargo bench --bench serve_hotpath -- --quick

  echo "CI quick OK"
  exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== target coverage: benches + examples compile =="
cargo build --benches --examples

echo "== perf: serve_hotpath quick mode (req/s + copies-avoided + batched A/B per PR) =="
cargo bench --bench serve_hotpath -- --quick

echo "CI OK"
