#!/usr/bin/env bash
# CI for the gcoospdm crate: the tier-1 verify plus full target coverage.
#
#   ./ci.sh            # build + test + compile all benches/examples
#
# The crate is std-only (offline build; see DESIGN.md §2), so no network or
# vendored registry is required.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== target coverage: benches + examples compile =="
cargo build --benches --examples

echo "== perf: serve_hotpath quick mode (req/s + copies-avoided per PR) =="
cargo bench --bench serve_hotpath -- --quick

echo "CI OK"
