#!/usr/bin/env bash
# CI for the gcoospdm crate: the tier-1 verify plus full target coverage.
#
#   ./ci.sh            # build + test + compile all benches/examples
#   ./ci.sh --quick    # serving fast path: the trace-vs-walker and
#                      # batched-vs-sequential and adaptive-routing
#                      # differential suites, the simgpu trace lib tests,
#                      # the operand-handle (protocol v2 + store) suites,
#                      # the cross-protocol wire differential (binary v3
#                      # vs JSON v2, frame codec + admission window), the
#                      # cluster differential (3-node sharded cluster vs
#                      # single node, bitwise + failover + stats), the
#                      # tuner property suites, the tenancy + spill
#                      # differential (3-tenant bitwise, quota isolation,
#                      # zero-reconversion promote), the family differential
#                      # (GCOO/CSR/dense/CMRS/row-split bitwise interchange
#                      # over the 9-pattern corpus + GSPL round trips of the
#                      # new encodings), the CMRS + row-split sparse lib
#                      # suites, and the serve_hotpath quick bench (emits
#                      # and validates BENCH_10.json). Any BENCH_*.json
#                      # still lacking the "provenance": "measured" stamp
#                      # is flagged loudly up front.
#
# The crate is std-only (offline build; see DESIGN.md §2), so no network or
# vendored registry is required. The toolchain-less static audit (delimiter
# balance + pub-symbol import cross-check) always runs first, so a container
# without cargo still gets a meaningful gate.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== static audit (runs without a Rust toolchain) =="
python3 ../python/scripts/static_audit.py ..

echo "== BENCH provenance scan (placeholders are flagged, not fatal) =="
python3 - <<'PYEOF'
import glob, json, sys
placeholders = []
for path in sorted(glob.glob("../BENCH_*.json")):
    try:
        doc = json.load(open(path))
    except Exception as e:
        sys.exit(f"{path} is malformed JSON: {e}")
    if doc.get("provenance") == "measured" and doc.get("generated") is True:
        print(f"  {path}: measured")
    else:
        placeholders.append(path)
        print(f"  {path}: PLACEHOLDER (no measured provenance)")
if placeholders:
    print("!! PLACEHOLDER BENCH FILES — numbers in these documents are NOT")
    print("!! measurements. Run ./ci.sh --quick on a machine with cargo to")
    print("!! regenerate the current document (BENCH_10.json); older BENCH")
    print("!! files are frozen schema placeholders (see each file's note).")
PYEOF

if ! command -v cargo >/dev/null 2>&1; then
  echo "cargo not found: static audit passed, skipping build/test stages"
  exit 0
fi

if [[ "${1:-}" == "--quick" ]]; then
  echo "== quick: trace-vs-walker differential suite (corpus sweep + engine traces + determinism) =="
  cargo test -q --test trace_differential

  echo "== quick: simgpu trace lib tests (sinks, recorder, replay, oracle) =="
  cargo test -q --lib simgpu::trace

  echo "== quick: batched-vs-sequential differential suite =="
  cargo test -q --test batch_differential

  echo "== quick: adaptive-routing differential suite (bitwise, exact flip index, trace determinism) =="
  cargo test -q --test routing_differential

  echo "== quick: operand-handle API (protocol v2 round trips + handle-vs-inline differential) =="
  cargo test -q --test handle_api

  echo "== quick: cross-protocol wire differential (binary v3 vs JSON v2 bitwise, NaN parity, admission window) =="
  cargo test -q --test wire_differential

  echo "== quick: cluster differential (3-node sharded cluster vs single node: bitwise matrix, owner-down failover, stats aggregation) =="
  cargo test -q --test cluster_differential

  echo "== quick: tenancy + spill differential (3-tenant bitwise on both planes + cluster, quota/rate backpressure, per-tenant stats, zero-reconversion promote, full-corpus spill round trip) =="
  cargo test -q --test tenant_differential

  echo "== quick: family differential (GCOO/CSR/dense/CMRS/row-split bitwise over 9 patterns x widths, CMRS + row-split GSPL round trips on both planes) =="
  cargo test -q --test family_differential

  echo "== quick: CMRS + row-split sparse lib suites (builders, padding, adversarial-pattern invariants) =="
  cargo test -q --lib sparse::cmrs
  cargo test -q --lib sparse::rowsplit
  cargo test -q --lib gen::patterns

  echo "== quick: frame codec + windowed admission + shard ring + cluster membership lib tests =="
  cargo test -q --lib serve::protocol
  cargo test -q --lib serve::cluster
  cargo test -q --lib coordinator::queue
  cargo test -q --lib coordinator::metrics
  cargo test -q --lib coordinator::shard

  echo "== quick: tenancy lib tests (token bucket, DRR no-starvation property, spill slab codec) =="
  cargo test -q --lib coordinator::tenant
  cargo test -q --lib coordinator::spill

  echo "== quick: tuner invariants (EWMA bounds, sample gate, pure exploration draws) =="
  cargo test -q --lib coordinator::tuner

  echo "== quick: operand store invariants (LRU, byte budget, pins, flip/pin versioning) =="
  cargo test -q --lib coordinator::store

  echo "== quick: serve_hotpath (req/s, copies avoided, batched + handle + adaptive + wire + cluster + tenancy/spill + family A/Bs, open-loop admission) =="
  cargo bench --bench serve_hotpath -- --quick

  echo "== quick: BENCH_10.json must exist, be well-formed, and be measured =="
  python3 - <<'PYEOF'
import json, sys
try:
    doc = json.load(open("../BENCH_10.json"))
except Exception as e:
    sys.exit(f"BENCH_10.json missing or malformed: {e}")
if doc.get("generated") is not True:
    sys.exit("BENCH_10.json still a placeholder (generated != true)")
if doc.get("provenance") != "measured":
    sys.exit("BENCH_10.json lacks the measured-provenance stamp: the bench "
             "did not produce this document (provenance != 'measured')")
names = {p.get("phase") for p in doc.get("phases", [])}
for need in ("cluster_vs_single", "binary_vs_json", "open_loop_admission",
             "tenant_fairness", "spill_promote_vs_reconvert", "family_ab"):
    if need not in names:
        sys.exit(f"BENCH_10.json lacks required phase {need}")
print("BENCH_10.json OK:", ", ".join(sorted(names)))
PYEOF

  echo "CI quick OK"
  exit 0
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== target coverage: benches + examples compile =="
cargo build --benches --examples

echo "== perf: serve_hotpath quick mode (req/s + copies-avoided + batched A/B per PR) =="
cargo bench --bench serve_hotpath -- --quick

echo "CI OK"
