"""Host-side format builders: round trips and structural invariants.

These mirror the rust sparse:: module; cross-language agreement is pinned by
rust/tests/format_fixtures.rs on fixtures written by scripts/write_fixtures.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


class TestGcooRoundTrip:
    @pytest.mark.parametrize("pattern", ["uniform", "diagonal", "banded"])
    def test_round_trip(self, pattern):
        n, p = 64, 8
        a = ref.random_sparse(n, 0.9, seed=0, pattern=pattern)
        vals, rows, cols, nnz = ref.dense_to_gcoo(a, p, cap=p * n)
        back = ref.gcoo_to_dense(vals, rows, cols, p, n)
        np.testing.assert_array_equal(a, back)

    def test_band_sorted_by_col_then_row(self):
        """The sort order is the contract the bv-reuse scan depends on."""
        n, p = 64, 8
        a = ref.random_sparse(n, 0.8, seed=1)
        vals, rows, cols, nnz = ref.dense_to_gcoo(a, p, cap=p * n)
        for gi in range(n // p):
            k = nnz[gi]
            cc, rr = cols[gi, :k], rows[gi, :k]
            key = cc.astype(np.int64) * p + rr
            assert np.all(np.diff(key) > 0), f"band {gi} not strictly (col,row)-sorted"

    def test_rows_are_band_local(self):
        n, p = 32, 8
        a = ref.random_sparse(n, 0.7, seed=2)
        _, rows, _, nnz = ref.dense_to_gcoo(a, p, cap=p * n)
        for gi in range(n // p):
            assert rows[gi, : nnz[gi]].max(initial=0) < p

    def test_nnz_conservation(self):
        n, p = 64, 8
        a = ref.random_sparse(n, 0.9, seed=3)
        _, _, _, nnz = ref.dense_to_gcoo(a, p, cap=p * n)
        assert nnz.sum() == np.count_nonzero(a)

    def test_cap_overflow_raises(self):
        n, p = 32, 8
        a = np.ones((n, n), np.float32)
        with pytest.raises(ValueError):
            ref.dense_to_gcoo(a, p, cap=4)

    def test_p_must_divide_n(self):
        a = np.zeros((30, 30), np.float32)
        with pytest.raises(ValueError):
            ref.dense_to_gcoo(a, 8, cap=64)

    @settings(max_examples=20, deadline=None)
    @given(
        logn=st.integers(3, 6),
        p_exp=st.integers(0, 3),
        sparsity=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_round_trip_property(self, logn, p_exp, sparsity, seed):
        n, p = 2**logn, 2**p_exp
        if p > n:
            p = n
        a = ref.random_sparse(n, sparsity, seed=seed)
        vals, rows, cols, _ = ref.dense_to_gcoo(a, p, cap=p * n)
        np.testing.assert_array_equal(ref.gcoo_to_dense(vals, rows, cols, p, n), a)


class TestEllRoundTrip:
    def test_round_trip(self):
        n = 64
        a = ref.random_sparse(n, 0.9, seed=4)
        vals, cols = ref.dense_to_ell(a, rowcap=n)
        np.testing.assert_array_equal(ref.ell_to_dense(vals, cols, n), a)

    def test_rowcap_overflow_raises(self):
        a = np.ones((8, 8), np.float32)
        with pytest.raises(ValueError):
            ref.dense_to_ell(a, rowcap=4)

    @settings(max_examples=20, deadline=None)
    @given(logn=st.integers(3, 6), sparsity=st.floats(0.0, 1.0),
           seed=st.integers(0, 2**31 - 1))
    def test_round_trip_property(self, logn, sparsity, seed):
        n = 2**logn
        a = ref.random_sparse(n, sparsity, seed=seed)
        vals, cols = ref.dense_to_ell(a, rowcap=n)
        np.testing.assert_array_equal(ref.ell_to_dense(vals, cols, n), a)


class TestRandomSparse:
    def test_sparsity_approximately_honored(self):
        a = ref.random_sparse(256, 0.9, seed=5)
        actual = 1.0 - np.count_nonzero(a) / a.size
        assert abs(actual - 0.9) < 0.03

    def test_deterministic(self):
        np.testing.assert_array_equal(
            ref.random_sparse(64, 0.5, seed=6), ref.random_sparse(64, 0.5, seed=6)
        )

    def test_diagonal_pattern_on_diagonal(self):
        a = ref.random_sparse(64, 0.99, seed=7, pattern="diagonal")
        r, c = np.nonzero(a)
        assert np.abs(r - c).max(initial=0) <= 2

    def test_unknown_pattern_raises(self):
        with pytest.raises(ValueError):
            ref.random_sparse(16, 0.5, pattern="nope")
