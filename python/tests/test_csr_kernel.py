"""L1 correctness: the padded-CSR (cuSPARSE-analog) kernel vs the oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.csr_spdm import csr_spdm
from compile.kernels import ref


def run_csr(a, b, rp, tb, rowcap):
    vals, cols = ref.dense_to_ell(a, rowcap)
    out = csr_spdm(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(b), rp=rp, tb=tb)
    return np.asarray(out)


def assert_matches_ref(a, b, rp, tb, rowcap, rtol=1e-4, atol=1e-4):
    got = run_csr(a, b, rp, tb, rowcap)
    want = np.asarray(ref.spdm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


class TestBasics:
    def test_identity(self):
        n = 32
        a = np.eye(n, dtype=np.float32)
        b = np.arange(n * n, dtype=np.float32).reshape(n, n)
        assert_matches_ref(a, b, rp=8, tb=16, rowcap=4)

    def test_zero(self):
        n = 32
        got = run_csr(np.zeros((n, n), np.float32), np.ones((n, n), np.float32),
                      rp=8, tb=16, rowcap=4)
        np.testing.assert_array_equal(got, np.zeros((n, n), np.float32))

    def test_rowcap_padding_invariance(self):
        n = 32
        a = ref.random_sparse(n, 0.9, seed=1)
        b = np.random.default_rng(2).standard_normal((n, n)).astype(np.float32)
        np.testing.assert_array_equal(
            run_csr(a, b, 8, 16, rowcap=16), run_csr(a, b, 8, 16, rowcap=32)
        )

    def test_skewed_rows(self):
        """One dense row among empty ones — the row-split worst case."""
        n = 32
        a = np.zeros((n, n), np.float32)
        a[7, :] = 2.0
        b = np.random.default_rng(3).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, rp=8, tb=16, rowcap=n, rtol=1e-3, atol=1e-3)


class TestSweep:
    @pytest.mark.parametrize("sparsity", [0.5, 0.9, 0.99])
    def test_uniform(self, sparsity):
        n = 64
        a = ref.random_sparse(n, sparsity, seed=4)
        b = np.random.default_rng(5).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, rp=8, tb=32, rowcap=n, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(
        logn=st.integers(4, 6),
        sparsity=st.floats(0.0, 0.99),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, logn, sparsity, seed):
        n = 2 ** logn
        a = ref.random_sparse(n, sparsity, seed=seed)
        b = np.random.default_rng(seed + 1).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, rp=8, tb=min(32, n), rowcap=n, rtol=1e-3, atol=1e-3)


class TestAgreement:
    def test_csr_agrees_with_gcoo(self):
        """Two independent kernels must agree with each other, not just ref."""
        from compile.kernels.gcoo_spdm import gcoo_spdm
        n = 64
        a = ref.random_sparse(n, 0.95, seed=6)
        b = np.random.default_rng(7).standard_normal((n, n)).astype(np.float32)
        csr_out = run_csr(a, b, rp=8, tb=32, rowcap=n)
        vals, rows, cols, _ = ref.dense_to_gcoo(a, 8, 8 * n)
        gcoo_out = np.asarray(gcoo_spdm(
            jnp.asarray(vals), jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(b),
            p=8, tb=32,
        ))
        np.testing.assert_allclose(csr_out, gcoo_out, rtol=1e-4, atol=1e-4)
