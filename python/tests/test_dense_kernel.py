"""L1 correctness: the tiled dense GEMM (cuBLAS analog) vs jnp.matmul."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.dense_gemm import dense_gemm


def assert_gemm(m, k, n, tm, tn, tk, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(dense_gemm(jnp.asarray(a), jnp.asarray(b), tm=tm, tn=tn, tk=tk))
    want = a @ b
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestBasics:
    def test_square_single_tile(self):
        assert_gemm(16, 16, 16, 16, 16, 16)

    def test_square_multi_tile(self):
        assert_gemm(64, 64, 64, 16, 16, 16)

    def test_rectangular(self):
        assert_gemm(32, 64, 16, 16, 16, 16)

    def test_tile_clamping(self):
        # tile sizes larger than the matrix are clamped, not an error
        assert_gemm(8, 8, 8, 128, 128, 128)

    def test_inner_dim_mismatch_raises(self):
        a = jnp.zeros((8, 8), jnp.float32)
        b = jnp.zeros((16, 8), jnp.float32)
        with pytest.raises(ValueError):
            dense_gemm(a, b)

    def test_indivisible_tiles_raise(self):
        a = jnp.zeros((24, 24), jnp.float32)
        with pytest.raises(ValueError):
            dense_gemm(a, a, tm=16, tn=16, tk=16)


class TestSweep:
    @settings(max_examples=10, deadline=None)
    @given(
        logm=st.integers(3, 6),
        logk=st.integers(3, 6),
        logn=st.integers(3, 6),
        logt=st.integers(3, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, logm, logk, logn, logt, seed):
        m, k, n, t = 2**logm, 2**logk, 2**logn, 2**logt
        assert_gemm(m, k, n, t, t, t, seed=seed)
