"""L1 §Perf gate: every exported variant's per-program VMEM residency must
fit the budget with double-buffering headroom, and the sparse kernels must
beat the dense kernel on bytes/FLOP at their design density."""

import pytest

from compile import model
from compile.vmem import analyze, VMEM_BUDGET


@pytest.fixture(scope="module")
def reports():
    return {v.name: (v, analyze(v)) for v in model.all_variants()}


def test_every_variant_fits_vmem(reports):
    for name, (_v, r) in reports.items():
        assert r.fits, f"{name}: {r.total_bytes} bytes exceeds {VMEM_BUDGET}"


def test_headroom_allows_double_buffering(reports):
    # ≥50% headroom ⇒ the next grid step's blocks can prefetch while the
    # current one computes.
    for name, (v, r) in reports.items():
        if v.algo == "dense_xla":
            continue
        assert r.headroom >= 0.5, f"{name}: headroom {r.headroom:.2%}"


def test_sparse_kernels_are_memory_bound_dense_is_not(reports):
    # The paper's premise (§II-A): SpDM sits deep in the memory-bound
    # region, dense GEMM near the compute-bound region.
    for v in model.all_variants():
        r = analyze(v, density=0.01)
        if v.algo in ("gcoo", "gcoo_noreuse", "csr"):
            assert r.bytes_per_flop > 5.0, f"{v.name}: {r.bytes_per_flop}"
        if v.algo == "dense_pallas":
            assert r.bytes_per_flop < 0.1, f"{v.name}: {r.bytes_per_flop}"


def test_tighter_capacity_means_lower_traffic(reports):
    # Smallest-cap artifact routing (runtime::Registry::select) is justified:
    # per-program traffic grows monotonically with cap at fixed n.
    for n in model.SIZES:
        caps = sorted(
            (v.params["cap"], analyze(v, density=0.01).bytes_per_flop)
            for v in model.all_variants()
            if v.algo == "gcoo" and v.n == n
        )
        for (c1, b1), (c2, b2) in zip(caps, caps[1:]):
            assert b1 <= b2, f"n={n}: cap {c1}->{c2} traffic {b1}->{b2}"


def test_accumulator_pressure_bounded(reports):
    # p*tb*4 accumulator bytes stay register/VMEM-friendly (≤ 256 KB).
    for name, (v, r) in reports.items():
        assert r.accum_bytes <= 256 * 1024, f"{name}: accum {r.accum_bytes}"
