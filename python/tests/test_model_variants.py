"""L2: the export table traces, lowers, and computes correctly.

Lowering every variant here would repeat `make artifacts`; instead we lower a
representative subset and *numerically execute* the smallest variant of each
algorithm against the oracle, so a broken export table fails fast in pytest
rather than at rust runtime.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from compile.aot import to_hlo_text


@pytest.fixture(scope="module")
def variants():
    return model.variants_by_name()


class TestTable:
    def test_table_is_deterministic(self):
        names = [v.name for v in model.all_variants()]
        assert names == [v.name for v in model.all_variants()]
        assert len(names) == len(set(names)), "duplicate variant names"

    def test_expected_families_present(self, variants):
        algos = {v.algo for v in variants.values()}
        assert algos == {"gcoo", "gcoo_noreuse", "gcoo_spmv", "csr", "dense_pallas", "dense_xla"}

    def test_every_size_covered(self, variants):
        for n in model.SIZES:
            for algo in ("gcoo", "csr", "dense_xla"):
                assert any(v.n == n and v.algo == algo for v in variants.values())

    def test_shapes_consistent(self, variants):
        for v in variants.values():
            for nm, dt, shape in v.in_specs:
                assert all(d > 0 for d in shape), f"{v.name}:{nm} bad shape {shape}"
            if v.algo.startswith("gcoo"):
                g = v.n // v.params["p"]
                assert v.in_specs[0][2] == (g, v.params["cap"])


class TestNumerics:
    """Execute the smallest variant of each algorithm end-to-end in jax."""

    def _small(self, variants, algo):
        cands = [v for v in variants.values() if v.algo == algo]
        return min(cands, key=lambda v: (v.n, sum(np.prod(s[2]) for s in v.in_specs)))

    def test_gcoo_smallest(self, variants):
        v = self._small(variants, "gcoo")
        n, p, cap = v.n, v.params["p"], v.params["cap"]
        # density safely under cap: cap/(p*n) with margin
        s = 1.0 - 0.5 * cap / (p * n)
        a = ref.random_sparse(n, s, seed=0)
        vals, rows, cols, _ = ref.dense_to_gcoo(a, p, cap)
        b = np.random.default_rng(1).standard_normal((n, n)).astype(np.float32)
        (got,) = v.fn(jnp.asarray(vals), jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-3, atol=1e-3)

    def test_csr_smallest(self, variants):
        v = self._small(variants, "csr")
        n, rowcap = v.n, v.params["rowcap"]
        s = 1.0 - 0.25 * rowcap / n
        a = ref.random_sparse(n, s, seed=2)
        # iid placement has row-nnz tails; clamp each row to the capacity
        for i in range(n):
            (c,) = np.nonzero(a[i])
            a[i, c[rowcap:]] = 0.0
        vals, cols = ref.dense_to_ell(a, rowcap)
        b = np.random.default_rng(3).standard_normal((n, n)).astype(np.float32)
        (got,) = v.fn(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-3, atol=1e-3)

    def test_dense_xla_smallest(self, variants):
        v = self._small(variants, "dense_xla")
        rng = np.random.default_rng(4)
        a = rng.standard_normal((v.n, v.n)).astype(np.float32)
        b = rng.standard_normal((v.n, v.n)).astype(np.float32)
        (got,) = v.fn(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(got), a @ b, rtol=1e-3, atol=1e-2)


class TestLowering:
    def test_smallest_gcoo_lowers_to_hlo_text(self, variants):
        v = min((v for v in variants.values() if v.algo == "gcoo"),
                key=lambda v: (v.n, v.params["cap"]))
        lowered = jax.jit(v.fn).lower(*v.example_args())
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_dense_xla_lowers_to_hlo_text(self, variants):
        v = min((v for v in variants.values() if v.algo == "dense_xla"),
                key=lambda v: v.n)
        text = to_hlo_text(jax.jit(v.fn).lower(*v.example_args()))
        assert text.startswith("HloModule")
        assert "dot(" in text or "dot " in text
