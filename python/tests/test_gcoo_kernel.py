"""L1 correctness: the GCOOSpDM Pallas kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path: every property the
rust coordinator relies on (padding is a no-op, reuse flag is semantically
invisible, band-local indexing) is pinned here against `ref.spdm_ref`.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gcoo_spdm import gcoo_spdm
from compile.kernels import ref


def run_gcoo(a, b, p, tb, cap, reuse=True):
    vals, rows, cols, _ = ref.dense_to_gcoo(a, p, cap)
    out = gcoo_spdm(
        jnp.asarray(vals), jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(b),
        p=p, tb=tb, reuse=reuse,
    )
    return np.asarray(out)


def assert_matches_ref(a, b, p, tb, cap, reuse=True, rtol=1e-4, atol=1e-4):
    got = run_gcoo(a, b, p, tb, cap, reuse=reuse)
    want = np.asarray(ref.spdm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


class TestBasics:
    def test_identity(self):
        n, p, tb = 32, 8, 16
        a = np.eye(n, dtype=np.float32)
        b = np.arange(n * n, dtype=np.float32).reshape(n, n)
        assert_matches_ref(a, b, p, tb, cap=64)

    def test_zero_matrix(self):
        n, p, tb = 32, 8, 16
        a = np.zeros((n, n), np.float32)
        b = np.ones((n, n), np.float32)
        got = run_gcoo(a, b, p, tb, cap=16)
        np.testing.assert_array_equal(got, np.zeros((n, n), np.float32))

    def test_single_nonzero(self):
        n, p, tb = 32, 8, 16
        a = np.zeros((n, n), np.float32)
        a[5, 17] = 3.0
        b = np.random.default_rng(1).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=16)

    def test_dense_as_sparse(self):
        """Fully dense A stored in GCOO must still be exact."""
        n, p, tb = 16, 8, 16
        rng = np.random.default_rng(2)
        a = rng.standard_normal((n, n)).astype(np.float32)
        b = rng.standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=p * n, rtol=1e-3, atol=1e-3)

    def test_column_runs_exercise_reuse(self):
        """A matrix that is a few dense columns — maximal same-col runs."""
        n, p, tb = 32, 8, 16
        a = np.zeros((n, n), np.float32)
        a[:, 3] = 1.5
        a[:, 20] = -2.0
        b = np.random.default_rng(3).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=2 * p)

    def test_diagonal_no_reuse_opportunity(self):
        """Diagonal A: every nonzero has a distinct column per band."""
        n, p, tb = 32, 8, 16
        a = np.diag(np.arange(1, n + 1).astype(np.float32))
        b = np.random.default_rng(4).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=p)


class TestFlags:
    def test_reuse_matches_noreuse(self):
        """The bv-reuse optimization must be semantically invisible."""
        n, p, tb = 64, 8, 32
        a = ref.random_sparse(n, 0.9, seed=5)
        b = np.random.default_rng(6).standard_normal((n, n)).astype(np.float32)
        got_r = run_gcoo(a, b, p, tb, cap=256, reuse=True)
        got_n = run_gcoo(a, b, p, tb, cap=256, reuse=False)
        np.testing.assert_array_equal(got_r, got_n)

    def test_cap_padding_invariance(self):
        """Extra padding capacity must not change the result."""
        n, p, tb = 32, 8, 16
        a = ref.random_sparse(n, 0.85, seed=7)
        b = np.random.default_rng(8).standard_normal((n, n)).astype(np.float32)
        small = run_gcoo(a, b, p, tb, cap=128)
        large = run_gcoo(a, b, p, tb, cap=512)
        np.testing.assert_array_equal(small, large)

    def test_tb_invariance(self):
        """Column tile width is a schedule choice, not a semantic one."""
        n, p = 64, 8
        a = ref.random_sparse(n, 0.9, seed=9)
        b = np.random.default_rng(10).standard_normal((n, n)).astype(np.float32)
        np.testing.assert_array_equal(
            run_gcoo(a, b, p, 16, cap=256), run_gcoo(a, b, p, 64, cap=256)
        )

    def test_p_invariance(self):
        """Band height is a schedule choice, not a semantic one."""
        n, tb = 64, 32
        a = ref.random_sparse(n, 0.9, seed=11)
        b = np.random.default_rng(12).standard_normal((n, n)).astype(np.float32)
        np.testing.assert_allclose(
            run_gcoo(a, b, 4, tb, cap=256), run_gcoo(a, b, 16, tb, cap=256),
            rtol=1e-5, atol=1e-5,
        )


class TestSweep:
    @pytest.mark.parametrize("pattern", ["uniform", "diagonal", "banded"])
    @pytest.mark.parametrize("sparsity", [0.5, 0.9, 0.99])
    def test_patterns(self, pattern, sparsity):
        n, p, tb = 64, 8, 32
        a = ref.random_sparse(n, sparsity, seed=13, pattern=pattern)
        b = np.random.default_rng(14).standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=p * n, rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        logn=st.integers(4, 6),
        p_exp=st.integers(1, 3),
        sparsity=st.floats(0.0, 0.995),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, logn, p_exp, sparsity, seed):
        """Property: GCOOSpDM == dense oracle for arbitrary shape/sparsity."""
        n = 2 ** logn
        p = 2 ** p_exp
        tb = min(32, n)
        a = ref.random_sparse(n, sparsity, seed=seed)
        rng = np.random.default_rng(seed + 1)
        b = rng.standard_normal((n, n)).astype(np.float32)
        assert_matches_ref(a, b, p, tb, cap=p * n, rtol=1e-3, atol=1e-3)
