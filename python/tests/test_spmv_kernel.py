"""L1 correctness: the GCOO SpMV extension kernel vs the dense oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.gcoo_spmv import gcoo_spmv
from compile.kernels import ref


def run_spmv(a, x, p, cap, reuse=True):
    vals, rows, cols, _ = ref.dense_to_gcoo(a, p, cap)
    y = gcoo_spmv(jnp.asarray(vals), jnp.asarray(rows), jnp.asarray(cols),
                  jnp.asarray(x), p=p, reuse=reuse)
    return np.asarray(y)


class TestBasics:
    def test_identity(self):
        n, p = 32, 8
        x = np.arange(n, dtype=np.float32)
        y = run_spmv(np.eye(n, dtype=np.float32), x, p, cap=p)
        np.testing.assert_allclose(y, x, rtol=1e-6)

    def test_zero(self):
        y = run_spmv(np.zeros((16, 16), np.float32), np.ones(16, np.float32), 8, cap=4)
        np.testing.assert_array_equal(y, np.zeros(16, np.float32))

    def test_dense_column_reuse_path(self):
        n, p = 32, 8
        a = np.zeros((n, n), np.float32)
        a[:, 5] = 2.0
        x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
        y = run_spmv(a, x, p, cap=2 * p)
        np.testing.assert_allclose(y, a @ x, rtol=1e-5, atol=1e-5)

    def test_reuse_matches_noreuse(self):
        n, p = 64, 8
        a = ref.random_sparse(n, 0.9, seed=1)
        x = np.random.default_rng(2).standard_normal(n).astype(np.float32)
        np.testing.assert_array_equal(
            run_spmv(a, x, p, cap=256, reuse=True),
            run_spmv(a, x, p, cap=256, reuse=False),
        )


class TestSweep:
    @pytest.mark.parametrize("pattern", ["uniform", "diagonal", "banded"])
    def test_patterns(self, pattern):
        n, p = 64, 8
        a = ref.random_sparse(n, 0.95, seed=3, pattern=pattern)
        x = np.random.default_rng(4).standard_normal(n).astype(np.float32)
        y = run_spmv(a, x, p, cap=p * n)
        np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(logn=st.integers(4, 6), sparsity=st.floats(0.0, 0.99),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, logn, sparsity, seed):
        n, p = 2 ** logn, 8
        a = ref.random_sparse(n, sparsity, seed=seed)
        x = np.random.default_rng(seed + 1).standard_normal(n).astype(np.float32)
        y = run_spmv(a, x, p, cap=p * n)
        np.testing.assert_allclose(y, a @ x, rtol=1e-3, atol=1e-3)
