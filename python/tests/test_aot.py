"""AOT exporter: HLO text properties and manifest generation.

These pin the interchange contract the rust runtime depends on:
HLO *text* beginning with `HloModule`, a tuple-wrapped single output, and a
manifest whose shapes match the variant table exactly.
"""

import json
import os

import jax
import pytest

from compile import model
from compile.aot import export_variant, to_hlo_text


@pytest.fixture(scope="module")
def small_gcoo_variant():
    return min(
        (v for v in model.all_variants() if v.algo == "gcoo"),
        key=lambda v: (v.n, v.params["cap"]),
    )


class TestHloText:
    def test_starts_with_hlomodule_and_has_entry(self, small_gcoo_variant):
        v = small_gcoo_variant
        text = to_hlo_text(jax.jit(v.fn).lower(*v.example_args()))
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_scan_stays_rolled(self, small_gcoo_variant):
        """The cap-length scan must lower to a while loop, not be unrolled —
        unrolling would blow up artifact size and compile time (L2 §Perf)."""
        v = small_gcoo_variant
        text = to_hlo_text(jax.jit(v.fn).lower(*v.example_args()))
        assert "while(" in text or "while " in text, "scan was unrolled"
        # artifact stays small because the loop is rolled
        assert len(text) < 200_000

    def test_output_is_tuple_wrapped(self, small_gcoo_variant):
        v = small_gcoo_variant
        text = to_hlo_text(jax.jit(v.fn).lower(*v.example_args()))
        # return_tuple=True ⇒ ENTRY computation root is a tuple
        assert "tuple(" in text or "(f32[" in text


class TestExport:
    def test_export_writes_file_and_entry(self, tmp_path, small_gcoo_variant):
        v = small_gcoo_variant
        entry = export_variant(v, str(tmp_path))
        path = tmp_path / entry["file"]
        assert path.exists() and path.stat().st_size > 0
        assert entry["name"] == v.name
        assert entry["algo"] == v.algo
        assert entry["inputs"][0]["shape"] == list(v.in_specs[0][2])
        assert len(entry["sha256"]) == 64

    def test_export_is_incremental(self, tmp_path, small_gcoo_variant):
        v = small_gcoo_variant
        e1 = export_variant(v, str(tmp_path))
        mtime = (tmp_path / e1["file"]).stat().st_mtime_ns
        e2 = export_variant(v, str(tmp_path))  # no force: must skip rewrite
        assert (tmp_path / e2["file"]).stat().st_mtime_ns == mtime
        assert e1["sha256"] == e2["sha256"]

    def test_force_rewrites(self, tmp_path, small_gcoo_variant):
        v = small_gcoo_variant
        export_variant(v, str(tmp_path))
        e2 = export_variant(v, str(tmp_path), force=True)
        assert len(e2["sha256"]) == 64


class TestRealManifest:
    """When `make artifacts` has run, the shipped manifest must be coherent."""

    @pytest.fixture()
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_manifest_covers_variant_table(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        expected = {v.name for v in model.all_variants()}
        assert expected <= names

    def test_manifest_files_exist_and_hash(self, manifest):
        import hashlib
        base = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for a in manifest["artifacts"][:5]:  # spot check
            p = os.path.join(base, a["file"])
            assert os.path.exists(p), a["file"]
            h = hashlib.sha256(open(p, "rb").read()).hexdigest()
            assert h == a["sha256"], a["file"]
