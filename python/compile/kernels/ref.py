"""Pure-jnp/numpy correctness oracles and host-side format builders.

Everything here is the *specification*: kernels are correct iff they match
these functions (allclose) on every generated input. The format builders
mirror the rust `sparse` module (rust/src/sparse/) — the cross-language
agreement is itself tested (python writes fixtures, rust parses and re-emits
them; see rust/tests/format_fixtures.rs).
"""

import numpy as np
import jax.numpy as jnp

__all__ = [
    "spdm_ref",
    "gcoo_to_dense",
    "ell_to_dense",
    "dense_to_gcoo",
    "dense_to_ell",
    "random_sparse",
]


def spdm_ref(a_dense, b):
    """The oracle: dense matmul of the densified sparse operand."""
    return jnp.matmul(a_dense, b)


def dense_to_gcoo(a, p, cap):
    """Dense -> padded row-band GCOO (bands of p rows, sorted by (col, row)).

    Returns (vals (g,cap) f32, rows (g,cap) i32 band-local, cols (g,cap) i32
    absolute, nnz_per_group (g,) i32). Raises if any band exceeds cap.
    Mirrors rust sparse::Gcoo::from_dense + GcooPadded.
    """
    a = np.asarray(a)
    n = a.shape[0]
    g = (n + p - 1) // p
    if g * p != n:
        raise ValueError(f"p={p} must divide n={n} (pad A to a multiple of p first)")
    vals = np.zeros((g, cap), np.float32)
    rows = np.zeros((g, cap), np.int32)
    cols = np.zeros((g, cap), np.int32)
    nnz_pg = np.zeros((g,), np.int32)
    for gi in range(g):
        band = a[gi * p:(gi + 1) * p]
        r, c = np.nonzero(band)
        order = np.lexsort((r, c))  # primary: col, secondary: row
        r, c = r[order], c[order]
        k = len(r)
        if k > cap:
            raise ValueError(f"band {gi}: nnz {k} exceeds cap {cap}")
        vals[gi, :k] = band[r, c]
        rows[gi, :k] = r
        cols[gi, :k] = c
        nnz_pg[gi] = k
    return vals, rows, cols, nnz_pg


def gcoo_to_dense(vals, rows, cols, p, n):
    """Inverse of dense_to_gcoo (padding entries are 0 and vanish)."""
    g = vals.shape[0]
    a = np.zeros((g * p, n), np.float32)
    for gi in range(g):
        for k in range(vals.shape[1]):
            v = vals[gi, k]
            if v != 0.0:
                a[gi * p + rows[gi, k], cols[gi, k]] += v
    return a


def dense_to_ell(a, rowcap):
    """Dense -> padded-CSR/ELL (vals (n,rowcap), cols (n,rowcap))."""
    a = np.asarray(a)
    n = a.shape[0]
    vals = np.zeros((n, rowcap), np.float32)
    cols = np.zeros((n, rowcap), np.int32)
    for i in range(n):
        (c,) = np.nonzero(a[i])
        if len(c) > rowcap:
            raise ValueError(f"row {i}: nnz {len(c)} exceeds rowcap {rowcap}")
        vals[i, : len(c)] = a[i, c]
        cols[i, : len(c)] = c
    return vals, cols


def ell_to_dense(vals, cols, n):
    """Inverse of dense_to_ell."""
    out = np.zeros((vals.shape[0], n), np.float32)
    for i in range(vals.shape[0]):
        for k in range(vals.shape[1]):
            if vals[i, k] != 0.0:
                out[i, cols[i, k]] += vals[i, k]
    return out


def random_sparse(n, sparsity, seed=0, pattern="uniform"):
    """Random n×n f32 sparse matrix. Patterns mirror rust gen::.

    uniform  — iid nonzero placement (the paper's random corpus)
    diagonal — nonzeros on/near the diagonal (the paper's loss case)
    banded   — nonzeros within a ±band of the diagonal
    """
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    # Nonzero values must not themselves be ~0: resample tiny magnitudes so
    # dense_to_* round-trips (np.nonzero) see exactly the intended support.
    a = np.where(np.abs(a) < 1e-3, 1.0, a).astype(np.float32)
    if pattern == "uniform":
        mask = rng.random((n, n)) < (1.0 - sparsity)
    elif pattern == "diagonal":
        mask = np.zeros((n, n), bool)
        width = max(1, int(round((1.0 - sparsity) * n)))
        for d in range(-(width // 2), width - width // 2):
            idx = np.arange(max(0, -d), min(n, n - d))
            mask[idx, idx + d] = True
    elif pattern == "banded":
        half = max(1, int(round((1.0 - sparsity) * n / 2 * 3)))
        ii, jj = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
        band = np.abs(ii - jj) <= half
        mask = band & (rng.random((n, n)) < 0.34)
    else:
        raise ValueError(f"unknown pattern {pattern!r}")
    return np.where(mask, a, 0.0).astype(np.float32)
