"""L1 Pallas kernel: GCOO sparse matrix-vector product (y = A·x).

The paper's conclusion proposes extending GCOO beyond SpDM; SpMV is the
natural first extension (GCOO descends from the SCOO *SpMV* format [31]).
Same row-band layout as `gcoo_spdm`; the C-column lane dimension collapses
to a single output column, so each program owns a `p`-row slice of y and
scans its band once. Same-column runs reuse the gathered `x[col]` scalar —
the bv-reuse optimization carried over.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gcoo_spmv", "gcoo_spmv_kernel"]


def gcoo_spmv_kernel(vals_ref, rows_ref, cols_ref, x_ref, o_ref, *, cap, p, reuse):
    """vals/rows/cols: (1, cap); x_ref: (n,); o_ref: (p,)."""

    def body(k, carry):
        acc, prev_col, prev_xv = carry
        col = cols_ref[0, k]
        row = rows_ref[0, k]
        v = vals_ref[0, k]
        if reuse:
            xv = lax.cond(col == prev_col, lambda: prev_xv, lambda: x_ref[col])
        else:
            xv = x_ref[col]
        acc = acc.at[row].add(v * xv)
        return acc, col, xv

    init = (jnp.zeros((p,), jnp.float32), jnp.int32(-1), jnp.float32(0))
    acc, _, _ = lax.fori_loop(0, cap, body, init)
    o_ref[...] = acc


def gcoo_spmv(vals, rows, cols, x, *, p, reuse=True, interpret=True):
    """y = A @ x with A in padded row-band GCOO.

    Args:
      vals: (g, cap) f32; rows: (g, cap) i32 band-local; cols: (g, cap) i32.
      x: (n,) f32.
    Returns: (g*p,) f32.
    """
    g, cap = vals.shape
    n = x.shape[0]
    kernel = partial(gcoo_spmv_kernel, cap=cap, p=p, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g * p,), jnp.float32),
        interpret=interpret,
    )(vals, rows, cols, x)
