"""L1 Pallas kernel: tiled dense GEMM — the cuBLAS analog.

Classic three-level tiling: grid ``(n/tm, n/tn, n/tk)``; each program
multiplies a ``(tm, tk)`` A tile by a ``(tk, tn)`` B tile into a ``(tm, tn)``
C accumulator. On real TPU hardware the inner ``jnp.dot`` maps onto the MXU
systolic array; under ``interpret=True`` it is the structural stand-in.

The AOT path additionally exports a plain ``jnp.matmul`` variant (XLA's own
fused GEMM) as the *vendor* dense baseline — the honest analog of cuBLAS for
this stack — so the dense baseline does not pay Pallas-interpreter overhead
in measured wall-clock comparisons. Both share the same simgpu walker.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_gemm", "dense_gemm_kernel"]


def dense_gemm_kernel(a_ref, b_ref, o_ref, *, nk):
    """a_ref: (tm, tk); b_ref: (tk, tn); o_ref: (tm, tn). k = program_id(2)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def dense_gemm(a, b, *, tm=128, tn=128, tk=128, interpret=True):
    """C = A @ B, all dense, three-level tiled.

    Tile sizes are clamped to the problem size so small matrices still lower.
    """
    m, ka = a.shape
    kb, n = b.shape
    if ka != kb:
        raise ValueError(f"inner dims mismatch: {ka} vs {kb}")
    tm, tn, tk = min(tm, m), min(tn, n), min(tk, ka)
    if m % tm or n % tn or ka % tk:
        raise ValueError(f"tiles ({tm},{tn},{tk}) must divide ({m},{n},{ka})")
    grid = (m // tm, n // tn, ka // tk)
    kernel = partial(dense_gemm_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
