"""L1 Pallas kernel: GCOOSpDM (paper Algorithm 2, TPU adaptation).

The sparse matrix ``A`` (n×n) is stored in *row-band GCOO*: bands of ``p``
consecutive rows, each band's nonzeros in COO sorted by ``(col, row)`` and
padded to a static per-band capacity ``cap`` (padding entries have value 0 and
therefore contribute nothing). See DESIGN.md §3 for the CUDA→TPU mapping and
the orientation note (Algorithm 2's output indexing implies row bands).

Grid: ``(g, n // tb)`` — one program per (row band, C column tile).
Per program:
  * the band's ``values/rows/cols`` slabs are staged into VMEM once
    (the CUDA shared-memory staging of Algorithm 2 lines 12-15);
  * a scan walks the COO entries; each entry gathers one row ``B(col, :)`` of
    the staged B stripe as a ``tb``-wide vector (the coalesced ``bv`` load,
    line 24) and accumulates ``v * bv`` into a ``(p, tb)`` accumulator
    (lines 25-26);
  * when ``reuse=True`` the scan carries the previous ``(col, bv)`` and skips
    the gather on same-column runs via ``lax.cond`` — the paper's operational
    intensity optimization (lines 28-36);
  * the accumulator is written to C exactly once (lines 38-39).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gcoo_spdm", "gcoo_spdm_kernel"]


def gcoo_spdm_kernel(vals_ref, rows_ref, cols_ref, b_ref, o_ref, *, cap, p, reuse):
    """Pallas kernel body. Refs:
    vals_ref: (1, cap) f32   — band values (zero padded)
    rows_ref: (1, cap) i32   — row within band, in [0, p)
    cols_ref: (1, cap) i32   — absolute column of A == row of B, in [0, n)
    b_ref:    (n, tb)  f32   — the B column stripe for this program
    o_ref:    (p, tb)  f32   — the C block owned by this program
    """
    tb = o_ref.shape[1]

    def body(k, carry):
        acc, prev_col, prev_brow = carry
        col = cols_ref[0, k]
        row = rows_ref[0, k]
        v = vals_ref[0, k]
        if reuse:
            # Same-column run: bv is already in registers; skip the gather.
            brow = lax.cond(col == prev_col, lambda: prev_brow, lambda: b_ref[col, :])
        else:
            brow = b_ref[col, :]
        acc = acc.at[row].add(v * brow)
        return acc, col, brow

    acc0 = jnp.zeros((p, tb), jnp.float32)
    init = (acc0, jnp.int32(-1), jnp.zeros((tb,), jnp.float32))
    acc, _, _ = lax.fori_loop(0, cap, body, init)
    o_ref[...] = acc  # single coalesced write of the C block


def gcoo_spdm(vals, rows, cols, b, *, p, tb, reuse=True, interpret=True):
    """C = A @ B with A in padded row-band GCOO.

    Args:
      vals: (g, cap) f32 — band-local COO values, zero padded.
      rows: (g, cap) i32 — band-local row indices (0..p-1).
      cols: (g, cap) i32 — absolute column indices (0..n-1).
      b:    (n, n)   f32 — dense right-hand side.
      p:    rows per band; g * p must equal A's row count.
      tb:   C column tile width; must divide b.shape[1].
      reuse: enable the same-column bv-reuse scan (paper lines 28-36).
    Returns: (g*p, n) f32 dense product.
    """
    g, cap = vals.shape
    n_rows_b, n = b.shape
    if n % tb != 0:
        raise ValueError(f"tb={tb} must divide n={n}")
    grid = (g, n // tb)
    kernel = partial(gcoo_spdm_kernel, cap=cap, p=p, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap), lambda i, j: (i, 0)),   # band values  -> VMEM
            pl.BlockSpec((1, cap), lambda i, j: (i, 0)),   # band rows    -> VMEM
            pl.BlockSpec((1, cap), lambda i, j: (i, 0)),   # band cols    -> VMEM
            pl.BlockSpec((n_rows_b, tb), lambda i, j: (0, j)),  # B stripe -> VMEM
        ],
        out_specs=pl.BlockSpec((p, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g * p, n), jnp.float32),
        interpret=interpret,  # CPU path; real-TPU lowering emits Mosaic custom-calls
    )(vals, rows, cols, b)
