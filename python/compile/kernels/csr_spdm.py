"""L1 Pallas kernel: padded-CSR (ELL) row-split SpDM — the cuSPARSE analog.

cuSPARSE's csrmm (CUDA 8 era) is a row-split kernel: each row of ``A`` walks
its nonzeros and gathers one row of ``B`` per nonzero, with **no staging of A
in fast memory and no cross-nonzero reuse of the fetched B row** — every
``bv`` fetch feeds exactly one row's FLOPs. That access structure (not
cuSPARSE's exact machine code) is what the paper's comparison measures, so
this kernel reproduces it:

  * A is stored ELL-style: each row padded to a static width ``rowcap``
    (padding value 0 ⇒ no-op), so shapes are static for AOT lowering.
  * Grid ``(n/rp, n/tb)`` — one program per (row tile, C column tile).
  * Each program loops over its ``rp`` rows × ``rowcap`` entries, gathering
    ``B(col, :)`` per entry. No prev-col carry, no COO staging — deliberately
    the naive memory schedule the paper attributes to cuSPARSE.

The matching simgpu walker (rust) replays the same trace to produce the
paper's transaction counts; this kernel provides the executable numerics.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["csr_spdm", "csr_spdm_kernel"]


def csr_spdm_kernel(vals_ref, cols_ref, b_ref, o_ref, *, rowcap, rp):
    """vals_ref/cols_ref: (rp, rowcap); b_ref: (n, tb); o_ref: (rp, tb)."""
    tb = o_ref.shape[1]

    def row_body(r, out):
        def nz_body(k, acc):
            col = cols_ref[r, k]
            v = vals_ref[r, k]
            # One B-row gather per nonzero; no reuse across entries.
            return acc + v * b_ref[col, :]

        acc = lax.fori_loop(0, rowcap, nz_body, jnp.zeros((tb,), jnp.float32))
        return out.at[r].set(acc)

    out = lax.fori_loop(0, rp, row_body, jnp.zeros((rp, tb), jnp.float32))
    o_ref[...] = out


def csr_spdm(vals, cols, b, *, rp, tb, interpret=True):
    """C = A @ B with A in padded-CSR (ELL) form.

    Args:
      vals: (n, rowcap) f32 — per-row values, zero padded.
      cols: (n, rowcap) i32 — per-row absolute column indices.
      b:    (n, n) f32.
      rp:   rows per program (row tile height).
      tb:   C column tile width.
    Returns: (n, n) f32.
    """
    n, rowcap = vals.shape
    nb, nc = b.shape
    if nc % tb != 0 or n % rp != 0:
        raise ValueError(f"rp={rp} must divide n={n} and tb={tb} must divide {nc}")
    grid = (n // rp, nc // tb)
    kernel = partial(csr_spdm_kernel, rowcap=rowcap, rp=rp)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rp, rowcap), lambda i, j: (i, 0)),
            pl.BlockSpec((rp, rowcap), lambda i, j: (i, 0)),
            pl.BlockSpec((nb, tb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rp, tb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, nc), jnp.float32),
        interpret=interpret,
    )(vals, cols, b)
