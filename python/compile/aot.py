"""AOT export: lower every L2 variant to HLO *text* + manifest.json.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run from python/:  python -m compile.aot --out-dir ../artifacts
Incremental: a variant is skipped when its .hlo.txt already exists and is
newer than every file in compile/ (Makefile also guards with a sentinel).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from compile.model import all_variants, SIZES, P, TB, RP


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(variant, out_dir: str, force: bool = False) -> dict:
    """Lower one variant; returns its manifest entry."""
    path = os.path.join(out_dir, f"{variant.name}.hlo.txt")
    entry = {
        "name": variant.name,
        "algo": variant.algo,
        "n": variant.n,
        "params": variant.params,
        "inputs": [
            {"name": nm, "dtype": dt, "shape": list(shape)}
            for nm, dt, shape in variant.in_specs
        ],
        "outputs": [{"dtype": "float32", "shape": list(variant.output_shape())}],
        "file": os.path.basename(path),
    }
    if not force and os.path.exists(path) and os.path.getsize(path) > 0:
        entry["sha256"] = _sha256(path)
        return entry
    t0 = time.time()
    lowered = jax.jit(variant.fn).lower(*variant.example_args())
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    entry["sha256"] = _sha256(path)
    print(f"  {variant.name}: {len(text)} chars in {time.time() - t0:.1f}s", flush=True)
    return entry


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="re-lower even if fresh")
    ap.add_argument("--only", default=None, help="substring filter on variant names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    variants = all_variants()
    if args.only:
        variants = [v for v in variants if args.only in v.name]
    print(f"exporting {len(variants)} variants to {args.out_dir}", flush=True)
    entries = [export_variant(v, args.out_dir, force=args.force) for v in variants]

    manifest = {
        "schema": 1,
        "generator": "python -m compile.aot",
        "jax_version": jax.__version__,
        "defaults": {"sizes": list(SIZES), "p": P, "tb": TB, "rp": RP},
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest with {len(entries)} artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
