"""L1 performance analysis: VMEM footprint and bytes/FLOP per BlockSpec.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
Pallas layer is optimized *structurally*: every variant's per-program VMEM
residency must fit the ~16 MB budget with double-buffering headroom, and the
bytes-moved-per-FLOP ratio (the paper's operational intensity lens) is
tracked analytically. Run:  python -m compile.vmem

Used by EXPERIMENTS.md §Perf; the pytest in tests/test_vmem.py pins the
budget so a regressive BlockSpec change fails CI.
"""

from dataclasses import dataclass

from compile.model import all_variants, Variant

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TPU core (v4-ish)


@dataclass
class VmemReport:
    name: str
    algo: str
    blocks_bytes: int          # resident input/output blocks per program
    accum_bytes: int           # accumulator/scratch
    total_bytes: int
    fits: bool
    headroom: float            # fraction of budget free (for double buffering)
    bytes_per_flop: float      # HBM traffic per FLOP at nominal density


def analyze(v: Variant, density: float = 0.01) -> VmemReport:
    """Static analysis of the per-program VMEM residency for one variant."""
    n = v.n
    p = v.params.get("p", 8)
    tb = v.params.get("tb", 128)
    if v.algo == "gcoo_spmv":
        cap = v.params["cap"]
        blocks = cap * 12 + n * 4          # COO slabs + the x vector
        accum = p * 4
        nnz_g = max(1.0, p * n * density)
        hbm = cap * 12 + n * 4 + p * 4
        flops = 2.0 * nnz_g
    elif v.algo.startswith("gcoo"):
        cap = v.params["cap"]
        # blocks: vals (1,cap) f32 + rows/cols (1,cap) i32 + B stripe (n,tb) f32
        blocks = cap * 4 * 3 + n * tb * 4
        accum = p * tb * 4
        # HBM per program: COO slabs + B stripe + C block; FLOPs: 2·nnz_g·tb
        nnz_g = max(1.0, p * n * density)
        hbm = cap * 12 + n * tb * 4 + p * tb * 4
        flops = 2.0 * nnz_g * tb
    elif v.algo == "csr":
        rowcap = v.params["rowcap"]
        rp = v.params.get("rp", 8)
        blocks = rp * rowcap * 8 + n * tb * 4
        accum = rp * tb * 4
        nnz_rows = max(1.0, rp * n * density)
        hbm = rp * rowcap * 8 + n * tb * 4 + rp * tb * 4
        flops = 2.0 * nnz_rows * tb
    elif v.algo == "dense_pallas":
        tm = v.params.get("tm", 128)
        tn = v.params.get("tn", 128)
        tk = v.params.get("tk", 128)
        blocks = (tm * tk + tk * tn) * 4
        accum = tm * tn * 4
        hbm = (tm * tk + tk * tn) * 4
        flops = 2.0 * tm * tn * tk
    else:  # dense_xla — XLA's own tiling; report the dot's aggregate ratio
        blocks = 0
        accum = 0
        hbm = 3 * n * n * 4
        flops = 2.0 * float(n) ** 3
    total = blocks + accum
    return VmemReport(
        name=v.name,
        algo=v.algo,
        blocks_bytes=blocks,
        accum_bytes=accum,
        total_bytes=total,
        fits=total <= VMEM_BUDGET,
        headroom=1.0 - total / VMEM_BUDGET,
        bytes_per_flop=hbm / flops,
    )


def main():
    print(f"{'variant':<40} {'vmem_kb':>9} {'fits':>5} {'headroom':>9} {'B/FLOP':>8}")
    for v in all_variants():
        r = analyze(v)
        print(
            f"{r.name:<40} {r.total_bytes / 1024:>9.1f} {str(r.fits):>5} "
            f"{r.headroom:>9.2%} {r.bytes_per_flop:>8.2f}"
        )


if __name__ == "__main__":
    main()
