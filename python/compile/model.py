"""L2: the JAX compute graphs exported to the rust runtime.

One jitted function per (algorithm × shape variant). This module is the
single source of truth for the variant table — `aot.py` lowers every entry,
`tests/` sweep them, and `artifacts/manifest.json` (consumed by the rust
artifact registry, rust/src/runtime/registry.rs) is generated from it.

Algorithms:
  gcoo         — the paper's contribution: Pallas GCOOSpDM (bv-reuse on)
  gcoo_noreuse — ablation: same kernel, same-column reuse disabled
  csr          — cuSPARSE analog: padded-CSR row-split Pallas kernel
  dense_pallas — cuBLAS analog as an explicit tiled Pallas GEMM
  dense_xla    — cuBLAS analog as XLA's own fused GEMM (jnp.matmul); the
                 vendor-optimized dense baseline for wall-clock comparisons
"""

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.gcoo_spdm import gcoo_spdm
from compile.kernels.gcoo_spmv import gcoo_spmv
from compile.kernels.csr_spdm import csr_spdm
from compile.kernels.dense_gemm import dense_gemm

# Export sizes. Pallas interpret-mode artifacts get expensive to *execute*
# past n=1024 on CPU; the simgpu layer covers the paper's n up to 14500.
SIZES = (256, 512, 1024)
P = 8        # rows per GCOO band (paper's p, adapted: accumulator height)
TB = 128     # C column tile width (lane dimension; the paper's b analog)
RP = 8       # rows per program for the CSR kernel


def gcoo_caps(n: int) -> List[int]:
    """Per-band nnz capacities exported per size (density ~1/32, 1/8, 1/2)."""
    return [P * n // 32, P * n // 8, P * n // 2]


def csr_rowcaps(n: int) -> List[int]:
    """Per-row nnz capacities exported per size."""
    return [n // 32, n // 8, n // 2]


@dataclasses.dataclass(frozen=True)
class Variant:
    """One exportable computation: metadata + the jax callable."""
    name: str
    algo: str
    n: int
    params: Dict[str, int]
    in_specs: Tuple[Tuple[str, str, Tuple[int, ...]], ...]  # (name, dtype, shape)
    fn: Callable
    out_shape: Tuple[int, ...] = None  # defaults to (n, n)

    def output_shape(self):
        return self.out_shape if self.out_shape is not None else (self.n, self.n)

    def example_args(self):
        return tuple(
            jax.ShapeDtypeStruct(shape, jnp.dtype(dt)) for _, dt, shape in self.in_specs
        )


def _gcoo_variant(n: int, cap: int, reuse: bool) -> Variant:
    g = n // P
    tag = "gcoo" if reuse else "gcoo_noreuse"

    def fn(vals, rows, cols, b):
        return (gcoo_spdm(vals, rows, cols, b, p=P, tb=TB, reuse=reuse),)

    return Variant(
        name=f"{tag}_n{n}_p{P}_tb{TB}_cap{cap}",
        algo=tag,
        n=n,
        params={"p": P, "tb": TB, "cap": cap},
        in_specs=(
            ("values", "float32", (g, cap)),
            ("rows", "int32", (g, cap)),
            ("cols", "int32", (g, cap)),
            ("b", "float32", (n, n)),
        ),
        fn=fn,
    )


def _csr_variant(n: int, rowcap: int) -> Variant:
    def fn(vals, cols, b):
        return (csr_spdm(vals, cols, b, rp=RP, tb=TB),)

    return Variant(
        name=f"csr_n{n}_rp{RP}_tb{TB}_rowcap{rowcap}",
        algo="csr",
        n=n,
        params={"rp": RP, "tb": TB, "rowcap": rowcap},
        in_specs=(
            ("values", "float32", (n, rowcap)),
            ("cols", "int32", (n, rowcap)),
            ("b", "float32", (n, n)),
        ),
        fn=fn,
    )


def _dense_pallas_variant(n: int) -> Variant:
    t = min(128, n)

    def fn(a, b):
        return (dense_gemm(a, b, tm=t, tn=t, tk=t),)

    return Variant(
        name=f"dense_pallas_n{n}",
        algo="dense_pallas",
        n=n,
        params={"tm": t, "tn": t, "tk": t},
        in_specs=(("a", "float32", (n, n)), ("b", "float32", (n, n))),
        fn=fn,
    )


def _gcoo_spmv_variant(n: int, cap: int) -> Variant:
    g = n // P

    def fn(vals, rows, cols, x):
        return (gcoo_spmv(vals, rows, cols, x, p=P),)

    return Variant(
        name=f"gcoo_spmv_n{n}_p{P}_cap{cap}",
        algo="gcoo_spmv",
        n=n,
        params={"p": P, "cap": cap},
        in_specs=(
            ("values", "float32", (g, cap)),
            ("rows", "int32", (g, cap)),
            ("cols", "int32", (g, cap)),
            ("x", "float32", (n,)),
        ),
        fn=fn,
        out_shape=(n,),
    )


def _dense_xla_variant(n: int) -> Variant:
    def fn(a, b):
        return (jnp.matmul(a, b),)

    return Variant(
        name=f"dense_xla_n{n}",
        algo="dense_xla",
        n=n,
        params={},
        in_specs=(("a", "float32", (n, n)), ("b", "float32", (n, n))),
        fn=fn,
    )


def all_variants() -> List[Variant]:
    """The full export table, deterministic order."""
    out: List[Variant] = []
    for n in SIZES:
        for cap in gcoo_caps(n):
            out.append(_gcoo_variant(n, cap, reuse=True))
        # one ablation variant per size at the middle capacity
        out.append(_gcoo_variant(n, gcoo_caps(n)[1], reuse=False))
        for rowcap in csr_rowcaps(n):
            out.append(_csr_variant(n, rowcap))
        # SpMV extension (paper future work): one variant per size
        out.append(_gcoo_spmv_variant(n, gcoo_caps(n)[1]))
        out.append(_dense_pallas_variant(n))
        out.append(_dense_xla_variant(n))
    return out


def variants_by_name() -> Dict[str, Variant]:
    return {v.name: v for v in all_variants()}
