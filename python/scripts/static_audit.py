#!/usr/bin/env python3
"""Toolchain-less static audit for the rust crate (ISSUE 9 satellite).

The growth containers don't always ship cargo, but every PR still lands
Rust that must at least be *structurally* sound. This script catches the
two classes of breakage a text edit can introduce without a compiler:

1. **Delimiter balance** — `()`, `[]`, `{}` must balance per file, after
   stripping line/block comments (nested), string literals (including
   raw strings with any `#` count and byte strings), char literals, and
   lifetimes (`'a` is not an unterminated char).
2. **Import cross-check** — every leaf imported via `use gcoospdm::...`
   in `rust/tests` and `rust/benches` must correspond to a `pub` symbol
   (`fn`/`struct`/`enum`/`trait`/`type`/`mod`/`const`/`static`, or a
   `pub use` re-export leaf/alias) declared somewhere under `rust/src`.
   This is what catches a test written against a misremembered API name.

Usage: python3 python/scripts/static_audit.py [repo_root]
Exit 0 iff both audits pass. Runs in ci.sh before any cargo step, so a
container without the toolchain still gets a meaningful gate.
"""

import os
import re
import sys

OPEN = {"(": ")", "[": "]", "{": "}"}
CLOSE = {v: k for k, v in OPEN.items()}


def strip_rust(src):
    """Replace comments/strings/chars with spaces, preserving newlines."""
    out = []
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        # Line comment (// and ///): drop to end of line.
        if c == "/" and nxt == "/":
            j = src.find("\n", i)
            if j == -1:
                j = n
            out.append(" " * (j - i))
            i = j
            continue
        # Block comment, nested per Rust.
        if c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
            continue
        # Raw (byte) string: r"..."  r#"..."#  br##"..."## etc.
        m = re.match(r'(?:b?r)(#*)"', src[i:])
        if m and (i == 0 or not (src[i - 1].isalnum() or src[i - 1] == "_")):
            close = '"' + m.group(1)
            j = src.find(close, i + m.end())
            j = n if j == -1 else j + len(close)
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
            continue
        # Plain / byte string with escapes.
        if c == '"' or (c == "b" and nxt == '"' and (i == 0 or not (src[i - 1].isalnum() or src[i - 1] == "_"))):
            j = i + (2 if c == "b" else 1)
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            out.append("".join(ch if ch == "\n" else " " for ch in src[i:j]))
            i = j
            continue
        # Char literal vs lifetime.
        if c == "'":
            if nxt == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                out.append(" " * (j + 1 - i))
                i = j + 1
                continue
            if i + 2 < n and src[i + 2] == "'" and nxt not in ("'", "\n"):
                out.append("   ")
                i += 3
                continue
            # Lifetime (or labeled loop): drop the quote alone.
            out.append(" ")
            i += 1
            continue
        out.append(c)
        i += 1
    return "".join(out)


def check_balance(path, stripped):
    errs = []
    stack = []
    line = 1
    for ch in stripped:
        if ch == "\n":
            line += 1
        elif ch in OPEN:
            stack.append((ch, line))
        elif ch in CLOSE:
            if not stack or stack[-1][0] != CLOSE[ch]:
                errs.append(f"{path}:{line}: unmatched `{ch}`")
                return errs  # later errors are cascade noise
            stack.pop()
    for ch, ln in stack:
        errs.append(f"{path}:{ln}: unclosed `{ch}`")
    return errs


PUB_DECL = re.compile(
    r"\bpub(?:\s*\(\s*[\w: ]*\))?\s+(?:unsafe\s+)?(?:async\s+)?(?:extern\s+\"[^\"]*\"\s+)?"
    r"(fn|struct|enum|trait|type|mod|const|static|union)\s+([A-Za-z_]\w*)"
)
PUB_USE = re.compile(r"\bpub\s+use\s+([^;]+);")
TEST_USE = re.compile(r"\buse\s+gcoospdm\s*::\s*([^;]+);")


def use_leaves(clause):
    """Leaf names of a use clause: `a::{B, c::D as E, self}` -> B, D/E."""
    clause = clause.strip()
    leaves = set()

    def walk(s, parent):
        s = s.strip()
        if s.endswith("}"):
            head, _, body = s.partition("{")
            body = body.rsplit("}", 1)[0]
            head_leaf = head.strip().rstrip(":").rsplit("::", 1)[-1].strip() or parent
            depth, item = 0, []
            for ch in body:
                if ch == "{":
                    depth += 1
                elif ch == "}":
                    depth -= 1
                if ch == "," and depth == 0:
                    walk("".join(item), head_leaf)
                    item = []
                else:
                    item.append(ch)
            if "".join(item).strip():
                walk("".join(item), head_leaf)
            return
        if " as " in s:
            orig, alias = s.split(" as ", 1)
            leaves.add(orig.strip().rsplit("::", 1)[-1])
            leaves.add(alias.strip())
            return
        leaf = s.rsplit("::", 1)[-1].strip()
        if leaf == "self":
            # `x::{self}` imports `x` itself
            head = s.rsplit("::", 1)[0].rsplit("::", 1)[-1].strip() or parent
            if head and head != "self":
                leaves.add(head)
        elif leaf and leaf != "*":
            leaves.add(leaf)

    walk(clause, "")
    return leaves


def collect(root, subdirs):
    files = []
    for sub in subdirs:
        d = os.path.join(root, sub)
        if not os.path.isdir(d):
            continue
        for dirpath, _, names in os.walk(d):
            files.extend(os.path.join(dirpath, f) for f in sorted(names) if f.endswith(".rs"))
    return files


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    src_files = collect(root, ["rust/src"])
    consumer_files = collect(root, ["rust/tests", "rust/benches", "rust/examples"])
    if not src_files:
        sys.exit(f"static_audit: no rust sources under {root}")

    errors = []

    # Audit 1: delimiter balance over sources AND consumers.
    stripped_by_file = {}
    for path in src_files + consumer_files:
        with open(path, encoding="utf-8") as fh:
            stripped = strip_rust(fh.read())
        stripped_by_file[path] = stripped
        errors.extend(check_balance(os.path.relpath(path, root), stripped))

    # Audit 2: pub symbols vs `use gcoospdm::` leaves.
    declared = set()
    for path in src_files:
        stripped = stripped_by_file[path]
        for m in PUB_DECL.finditer(stripped):
            declared.add(m.group(2))
        for m in PUB_USE.finditer(stripped):
            declared |= use_leaves(m.group(1))
        # file-backed modules are implicitly declared by their path
        declared.add(os.path.splitext(os.path.basename(path))[0])
        declared.add(os.path.basename(os.path.dirname(path)))

    imported = 0
    for path in consumer_files:
        rel = os.path.relpath(path, root)
        for m in TEST_USE.finditer(stripped_by_file[path]):
            for leaf in use_leaves(m.group(1)):
                imported += 1
                if leaf not in declared:
                    errors.append(f"{rel}: `use gcoospdm::...::{leaf}` has no pub declaration in rust/src")

    if errors:
        print("static_audit: FAIL", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        sys.exit(1)
    print(f"static_audit: OK — {len(src_files) + len(consumer_files)} files balanced, "
          f"{imported} crate imports resolved against {len(declared)} pub symbols")


if __name__ == "__main__":
    main()
