"""Write cross-language format fixtures consumed by rust/tests/format_fixtures.rs.

The matrix is defined by a closed-form rule (no RNG) so rust can reconstruct
it exactly:  a[i,j] = ((i + 2j) % 5) + 1  if (i*31 + j*17) % 7 == 0 else 0.

Usage: cd python && python scripts/write_fixtures.py ../rust/tests_fixtures
"""

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import ref  # noqa: E402


def rule_matrix(n):
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(n):
            if (i * 31 + j * 17) % 7 == 0:
                a[i, j] = float((i + 2 * j) % 5 + 1)
    return a


def main():
    # Default to the location rust/tests/format_fixtures.rs reads, relative
    # to this script (works from any cwd).
    repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    out_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(repo, "rust", "tests_fixtures")
    os.makedirs(out_dir, exist_ok=True)
    n, p = 32, 8
    a = rule_matrix(n)
    cap = p * n
    vals, rows, cols, nnz = ref.dense_to_gcoo(a, p, cap)
    # Trim each band to its nnz for a compact fixture (padding is implied).
    bands = []
    for gi in range(n // p):
        k = int(nnz[gi])
        bands.append(
            {
                "vals": [float(v) for v in vals[gi, :k]],
                "rows": [int(r) for r in rows[gi, :k]],
                "cols": [int(c) for c in cols[gi, :k]],
            }
        )
    evals, ecols = ref.dense_to_ell(a, rowcap=n)
    ell_rows = []
    for i in range(n):
        k = int(np.count_nonzero(evals[i]))
        ell_rows.append(
            {"vals": [float(v) for v in evals[i, :k]], "cols": [int(c) for c in ecols[i, :k]]}
        )
    fixture = {
        "n": n,
        "p": p,
        "rule": "a[i,j] = ((i+2j)%5)+1 if (i*31+j*17)%7==0 else 0",
        "nnz": int(nnz.sum()),
        "gcoo_bands": bands,
        "ell_rows": ell_rows,
    }
    path = os.path.join(out_dir, "format_fixture.json")
    with open(path, "w") as f:
        json.dump(fixture, f, indent=1)
    print(f"wrote {path} (nnz={fixture['nnz']})")


if __name__ == "__main__":
    main()
