//! gcoospdm — reproduction of "Efficient Sparse-Dense Matrix-Matrix
//! Multiplication on GPUs Using the Customized Sparse Storage Format"
//! (Shi, Wang, Chu; 2020) as a three-layer rust + JAX/Pallas system.
//!
//! Layer map (see DESIGN.md):
//! * build path (python, once): Pallas kernels + JAX graphs → `artifacts/`
//! * request path (this crate): [`runtime`] loads the AOT artifacts and
//!   executes them (reference CPU kernels offline, PJRT in the full build —
//!   DESIGN.md §2), [`coordinator`] routes/batches SpDM jobs onto them,
//!   [`serve`] exposes the TCP serving loop.
//! * experiments: [`simgpu`] replays kernel memory traces on the paper's
//!   three GPUs (Table II) to regenerate every figure; [`gen`] provides
//!   the workloads; [`roofline`] / [`autotune`] the analysis layers.
//!
//! Substrate modules ([`rng`], [`json`], [`exec`], [`bench`], [`prop`],
//! [`ndarray`]) exist because the build environment is fully offline —
//! see DESIGN.md §2 for the substitution table.

pub mod ndarray;
pub mod rng;
pub mod json;
pub mod exec;
pub mod bench;
pub mod prop;
pub mod sparse;
pub mod gen;
pub mod simgpu;
pub mod roofline;
pub mod convert;
pub mod autotune;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod figures;
pub mod cli;
pub mod config;
