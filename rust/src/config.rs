//! Configuration-file substrate: a TOML-subset parser and the typed
//! [`SystemConfig`] the launcher consumes (`gcoospdm serve --config x.toml`).
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! ("…"), integer, float, and boolean values, `#` comments. That covers
//! deployment configuration without pulling a dependency into the offline
//! build.

use std::collections::HashMap;

use crate::coordinator::{CoordinatorConfig, SelectorPolicy};

/// Parsed config document: section → key → raw value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    sections: HashMap<String, HashMap<String, Value>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        match self.get(section, key)? {
            Value::Int(x) if *x >= 0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().to_string();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let dup = doc
            .sections
            .entry(section.clone())
            .or_default()
            .insert(key.clone(), value);
        if dup.is_some() {
            return Err(format!("line {}: duplicate key {key:?}", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value, String> {
    if let Some(rest) = v.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match v {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {v:?}"))
}

/// Full launcher configuration with defaults.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub artifacts_dir: String,
    pub server_addr: String,
    pub coordinator: CoordinatorConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            artifacts_dir: "artifacts".into(),
            server_addr: "127.0.0.1:7077".into(),
            coordinator: CoordinatorConfig::default(),
        }
    }
}

impl SystemConfig {
    /// Load from a TOML-subset file; unset keys keep defaults.
    pub fn from_file(path: &str) -> Result<SystemConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<SystemConfig, String> {
        let doc = parse(text)?;
        let mut cfg = SystemConfig::default();
        if let Some(s) = doc.get_str("runtime", "artifacts_dir") {
            cfg.artifacts_dir = s.to_string();
        }
        if let Some(s) = doc.get_str("server", "addr") {
            cfg.server_addr = s.to_string();
        }
        let c = &mut cfg.coordinator;
        if let Some(x) = doc.get_usize("coordinator", "workers") {
            if x == 0 {
                return Err("coordinator.workers must be positive".into());
            }
            c.workers = x;
        }
        if let Some(x) = doc.get_usize("coordinator", "queue_cap") {
            c.queue_cap = x.max(1);
        }
        if let Some(x) = doc.get_usize("coordinator", "batch_max") {
            c.batch_max = x.max(1);
        }
        if let Some(x) = doc.get_usize("coordinator", "gcoo_p") {
            c.gcoo_p = x.max(1);
        }
        if let Some(x) = doc.get_usize("coordinator", "convert_threads") {
            c.convert_threads = x.max(1);
        }
        if let Some(x) = doc.get_f64("selector", "gcoo_crossover") {
            if !(0.0..=1.0).contains(&x) {
                return Err(format!("selector.gcoo_crossover {x} out of [0,1]"));
            }
            c.policy.gcoo_crossover = x;
        }
        if let Some(x) = doc.get_usize("selector", "min_sparse_n") {
            c.policy.min_sparse_n = x;
        }
        Ok(cfg)
    }
}

/// Example config shipped in the docs.
pub const EXAMPLE: &str = r#"# gcoospdm deployment configuration
[runtime]
artifacts_dir = "artifacts"

[server]
addr = "127.0.0.1:7077"

[coordinator]
workers = 2
queue_cap = 64
batch_max = 8
gcoo_p = 8
convert_threads = 4

[selector]
gcoo_crossover = 0.98   # paper's sparse-vs-dense break-even
min_sparse_n = 256
"#;

#[allow(unused)]
fn _assert_selector_policy_used(_p: SelectorPolicy) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example_config() {
        let cfg = SystemConfig::from_str(EXAMPLE).unwrap();
        assert_eq!(cfg.server_addr, "127.0.0.1:7077");
        assert_eq!(cfg.coordinator.workers, 2);
        assert_eq!(cfg.coordinator.policy.gcoo_crossover, 0.98);
        assert_eq!(cfg.coordinator.policy.min_sparse_n, 256);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = SystemConfig::from_str("[server]\naddr = \"0.0.0.0:9\"\n").unwrap();
        assert_eq!(cfg.server_addr, "0.0.0.0:9");
        assert_eq!(cfg.coordinator.workers, CoordinatorConfig::default().workers);
    }

    #[test]
    fn value_types() {
        let doc = parse("a = 1\nb = 1.5\nc = true\nd = \"x y\"\n[s]\ne = -3\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("", "b"), Some(&Value::Float(1.5)));
        assert_eq!(doc.get("", "c"), Some(&Value::Bool(true)));
        assert_eq!(doc.get_str("", "d"), Some("x y"));
        assert_eq!(doc.get("s", "e"), Some(&Value::Int(-3)));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse("# top\n\na = 1  # trailing\ns = \"ha#sh\"\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get_str("", "s"), Some("ha#sh"));
    }

    #[test]
    fn errors_are_precise() {
        assert!(parse("[open\n").unwrap_err().contains("line 1"));
        assert!(parse("novalue\n").unwrap_err().contains("line 1"));
        assert!(parse("a = \n").unwrap_err().contains("line 1"));
        assert!(parse("a = 1\na = 2\n").unwrap_err().contains("duplicate"));
        assert!(parse("a = \"open\n").is_err());
    }

    #[test]
    fn validation_rejects_bad_values() {
        assert!(SystemConfig::from_str("[coordinator]\nworkers = 0\n").is_err());
        assert!(SystemConfig::from_str("[selector]\ngcoo_crossover = 1.5\n").is_err());
    }
}
