//! Set-associative LRU cache with sector granularity — used for both the
//! shared L2 and the per-SM L1/texture caches.
//!
//! Addresses are byte addresses; a lookup touches one 32-byte sector inside
//! a 128-byte line. A hit requires the *sector* to be present (sectored
//! fill, as on Maxwell/Pascal): a miss on a resident line fills just that
//! sector. LRU is per-set over lines.

use super::device::{LINE, SECTOR};


#[derive(Clone, Debug)]
struct LineState {
    tag: u64,
    sectors: u8, // bitmask of valid sectors
    last_use: u64,
}

/// One cache level.
pub struct Cache {
    sets: Vec<Vec<LineState>>, // per-set vector of ways
    ways: usize,
    set_count: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// Build with `bytes` capacity and `ways` associativity.
    pub fn new(bytes: usize, ways: usize) -> Self {
        let lines = (bytes / LINE).max(1);
        let set_count = (lines / ways).max(1);
        Cache {
            sets: vec![Vec::with_capacity(ways); set_count],
            ways,
            set_count,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one sector; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.tick += 1;
        let line_addr = addr / LINE as u64;
        let sector_idx = ((addr % LINE as u64) / SECTOR as u64) as u8;
        let sector_bit = 1u8 << sector_idx;
        let set_idx = (line_addr % self.set_count as u64) as usize;
        let tag = line_addr / self.set_count as u64;
        let set = &mut self.sets[set_idx];

        if let Some(line) = set.iter_mut().find(|l| l.tag == tag) {
            line.last_use = self.tick;
            if line.sectors & sector_bit != 0 {
                self.hits += 1;
                return true;
            }
            // sector miss on resident line: fill the sector
            line.sectors |= sector_bit;
            self.misses += 1;
            return false;
        }
        // line miss: allocate (evict LRU if full)
        if set.len() >= self.ways {
            let lru = set
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| l.last_use)
                .map(|(i, _)| i)
                .unwrap();
            set.swap_remove(lru);
        }
        set.push(LineState { tag, sectors: sector_bit, last_use: self.tick });
        self.misses += 1;
        false
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Capacity in bytes (for assertions).
    pub fn capacity(&self) -> usize {
        self.set_count * self.ways * LINE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(64 * 1024, 8);
        assert!(!c.access(0x1000)); // cold miss
        assert!(c.access(0x1000)); // hit
        assert!(c.access(0x1008)); // same sector
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn sectored_fill_misses_per_sector() {
        let mut c = Cache::new(64 * 1024, 8);
        assert!(!c.access(0x0)); // sector 0
        assert!(!c.access(0x20)); // sector 1 of the same line: still a miss
        assert!(c.access(0x0));
        assert!(c.access(0x20));
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn capacity_eviction() {
        // 2 lines total, 1 way, 2 sets. Lines mapping to the same set evict
        // each other.
        let mut c = Cache::new(2 * LINE, 1);
        assert_eq!(c.capacity(), 2 * LINE);
        let a = 0u64;
        let b = (2 * LINE) as u64; // same set as a (set index = line % 2)
        assert!(!c.access(a));
        assert!(!c.access(b)); // evicts a
        assert!(!c.access(a)); // miss again
    }

    #[test]
    fn lru_keeps_hot_line() {
        // 1 set, 2 ways.
        let mut c = Cache::new(2 * LINE, 2);
        let a = 0u64;
        let b = LINE as u64 * 1; // set 0 if set_count == 1
        let d = LINE as u64 * 2;
        assert!(!c.access(a));
        assert!(!c.access(b));
        assert!(c.access(a)); // a now MRU
        assert!(!c.access(d)); // evicts b (LRU)
        assert!(c.access(a), "hot line evicted by LRU");
    }

    #[test]
    fn streaming_large_working_set_mostly_misses() {
        let mut c = Cache::new(64 * 1024, 8);
        for i in 0..10_000u64 {
            c.access(i * SECTOR as u64 * 7); // stride past capacity
        }
        assert!(c.misses > 9_000);
    }

    #[test]
    fn working_set_within_capacity_hits_on_second_pass() {
        let mut c = Cache::new(64 * 1024, 8);
        let sectors = 64 * 1024 / SECTOR;
        for i in 0..sectors as u64 {
            c.access(i * SECTOR as u64);
        }
        c.reset_stats();
        for i in 0..sectors as u64 {
            c.access(i * SECTOR as u64);
        }
        let hit_rate = c.hits as f64 / (c.hits + c.misses) as f64;
        assert!(hit_rate > 0.95, "hit rate {hit_rate}");
    }
}
