//! Bottleneck cost model: transaction counts → estimated kernel time.
//!
//! Roofline-style: the kernel takes as long as its most saturated resource
//! (compute, DRAM, L2, shared memory), plus a fixed launch overhead. This is
//! the same modeling lens the paper uses (§II-A "algorithms for SpDM are
//! generally memory-bound … one should design the algorithm to increase r").

use super::device::{DeviceConfig, SECTOR};
use super::mem::Counters;

/// Per-resource times and the winning bottleneck.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub time_s: f64,
    pub t_compute: f64,
    pub t_dram: f64,
    pub t_l2: f64,
    pub t_shm: f64,
    pub bottleneck: &'static str,
}

/// A shared-memory transaction serves up to a 128-byte warp access.
const SHM_TRANSACTION_BYTES: f64 = 128.0;

pub fn estimate_time(counters: &Counters, flops: u64, dev: &DeviceConfig) -> KernelEstimate {
    let t_compute = flops as f64 / dev.peak_flops();
    let t_dram = (counters.dram as f64 * SECTOR as f64) / dev.dram_bw();
    let t_l2 = (counters.l2 as f64 * SECTOR as f64) / dev.l2_bw();
    let t_shm = (counters.shm as f64 * SHM_TRANSACTION_BYTES) / dev.shm_bw();
    let (bottleneck, t_max) = [
        ("compute", t_compute),
        ("dram", t_dram),
        ("l2", t_l2),
        ("shm", t_shm),
    ]
    .into_iter()
    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    .unwrap();
    KernelEstimate {
        time_s: t_max + dev.launch_overhead_s,
        t_compute,
        t_dram,
        t_l2,
        t_shm,
        bottleneck,
    }
}

/// Operational intensity r = FLOPs per byte of DRAM traffic (§II-A).
pub fn operational_intensity(counters: &Counters, flops: u64) -> f64 {
    let bytes = (counters.dram as f64) * SECTOR as f64;
    if bytes == 0.0 {
        f64::INFINITY
    } else {
        flops as f64 / bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::{GTX980, P100, TITANX};

    #[test]
    fn compute_bound_when_no_traffic() {
        let c = Counters::default();
        let e = estimate_time(&c, 1_000_000_000, &TITANX);
        assert_eq!(e.bottleneck, "compute");
        assert!((e.time_s - (1e9 / TITANX.peak_flops() + TITANX.launch_overhead_s)).abs() < 1e-12);
    }

    #[test]
    fn dram_bound_when_traffic_heavy() {
        let c = Counters { dram: 1 << 30, l2: 1 << 30, shm: 0, l1_tex: 0 };
        let e = estimate_time(&c, 1000, &GTX980);
        assert_eq!(e.bottleneck, "dram");
        assert!(e.t_dram > e.t_l2, "same sectors, slower bus");
    }

    #[test]
    fn faster_memory_helps_memory_bound_kernels() {
        let c = Counters { dram: 1 << 28, l2: 1 << 28, shm: 100, l1_tex: 100 };
        let slow = estimate_time(&c, 1000, &GTX980).time_s;
        let fast = estimate_time(&c, 1000, &P100).time_s;
        assert!(fast < slow, "P100 HBM must beat GTX980 GDDR5");
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let c = Counters { dram: 1, l2: 1, shm: 1, l1_tex: 0 };
        let e = estimate_time(&c, 10, &TITANX);
        assert!(e.time_s >= TITANX.launch_overhead_s);
    }

    #[test]
    fn operational_intensity_formula() {
        let c = Counters { dram: 100, ..Default::default() };
        assert!((operational_intensity(&c, 6400) - 2.0).abs() < 1e-12);
        assert!(operational_intensity(&Counters::default(), 10).is_infinite());
    }
}
