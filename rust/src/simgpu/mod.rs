//! simgpu — an abstract, **trace-driven** GPU execution model for the three
//! SpDM algorithms (GCOOSpDM, cuSPARSE-like CSR row-split, tiled dense
//! GEMM), on device configurations taken from the paper's Table II.
//!
//! Role in the reproduction (DESIGN.md §2, §Tracing): the paper's
//! evaluation hardware (GTX 980 / Titan X / P100, CUDA 8, nvprof) does not
//! exist here. Every figure that compares kernels *on those GPUs* is
//! regenerated from **traced execution**: the per-block warp transaction
//! streams live in [`trace`]'s `emit_*_block` emitters — shared with the
//! instrumented reference kernels in `runtime::engine`, which can run
//! under a [`TraceSink`] and emit the same events while computing real
//! products. A sectored LRU L2 and per-SM L1/tex caches classify the
//! replayed events into the four transaction classes nvprof reports
//! (Fig 14), and a bottleneck cost model turns counts into estimated
//! kernel time (Figs 4–13, 15). [`TraceOracle`] packages the pipeline as
//! the deterministic "measured" oracle that autotuning and `put_a`
//! registration refinement consult.
//!
//! The walkers ([`gcoo_walk`], [`csr_walk`], [`gemm_walk`]) are thin
//! adapters: pick a sampled launch-order block window, stream the emitters
//! through a [`ReplaySink`], scale counters to the full grid. The
//! pre-inversion hand-derived streams survive as `hand_*` walkers — the
//! differential baseline (`tests/trace_differential.rs`) until an
//! engine-emitted trace corpus replaces them.
//!
//! What this model is *not*: a cycle-accurate GPU. It does not model warp
//! scheduling, instruction latency hiding or DRAM row effects. The paper's
//! claims live at the level of memory-traffic asymmetry between algorithms,
//! which is exactly what the model captures.

mod device;
mod cache;
mod mem;
mod structure;
mod walkers;
mod cost;
pub mod trace;

pub use device::{DeviceConfig, GTX980, TITANX, P100, ALL_DEVICES};
pub use cache::Cache;
pub use mem::{MemorySystem, Counters, Space};
pub use structure::{SparseStructure, GcooStructure, SyntheticUniform, BandEntries};
pub use walkers::{
    gcoo_walk, csr_walk, gemm_walk, cmrs_walk, rowsplit_walk, hand_gcoo_walk, hand_csr_walk,
    hand_gemm_walk, record_gcoo, record_csr, record_gemm, record_cmrs, record_rowsplit,
    WalkConfig,
};
pub use cost::{KernelEstimate, estimate_time, operational_intensity};
pub use trace::{
    NullSink, ReplaySink, Trace, TraceEvent, TraceOracle, TraceRecorder, TraceSink,
};

/// Operational intensity of a simulated kernel run (FLOPs / DRAM byte).
pub fn estimate_r(rep: &KernelReport) -> f64 {
    cost::operational_intensity(&rep.counters, rep.flops)
}

use crate::sparse::Gcoo;

/// One simulated kernel execution: counts + estimated time.
#[derive(Clone, Debug)]
pub struct KernelReport {
    pub algo: &'static str,
    pub device: &'static str,
    pub counters: Counters,
    pub flops: u64,
    pub estimate: KernelEstimate,
}

impl KernelReport {
    pub fn time_s(&self) -> f64 {
        self.estimate.time_s
    }

    /// Effective GFLOPS by the paper's Eq. (2): 2·n³·(1−s)/T.
    pub fn effective_gflops(&self, n: usize, sparsity: f64) -> f64 {
        2.0 * (n as f64).powi(3) * (1.0 - sparsity) / self.time_s() / 1e9
    }
}

/// Simulate GCOOSpDM on `dev` for structure `s` (dense operand n×n).
pub fn simulate_gcoo(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
    reuse: bool,
) -> KernelReport {
    let (counters, flops) = gcoo_walk(s, dev, cfg, reuse);
    let estimate = estimate_time(&counters, flops, dev);
    KernelReport { algo: if reuse { "gcoo" } else { "gcoo_noreuse" }, device: dev.name, counters, flops, estimate }
}

/// Simulate the cuSPARSE-like CSR row-split kernel.
pub fn simulate_csr(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
) -> KernelReport {
    let (counters, flops) = csr_walk(s, dev, cfg);
    let estimate = estimate_time(&counters, flops, dev);
    KernelReport { algo: "csr", device: dev.name, counters, flops, estimate }
}

/// Simulate the dense tiled GEMM (cuBLAS stand-in) at size n.
pub fn simulate_dense(n: usize, dev: &DeviceConfig, cfg: &WalkConfig) -> KernelReport {
    let (counters, flops) = gemm_walk(n, dev, cfg);
    let estimate = estimate_time(&counters, flops, dev);
    KernelReport { algo: "dense", device: dev.name, counters, flops, estimate }
}

/// Simulate CMRS (round-robin interleaved strips) for structure `s`.
pub fn simulate_cmrs(s: &dyn SparseStructure, dev: &DeviceConfig, cfg: &WalkConfig) -> KernelReport {
    let (counters, flops) = cmrs_walk(s, dev, cfg);
    let estimate = estimate_time(&counters, flops, dev);
    KernelReport { algo: "cmrs", device: dev.name, counters, flops, estimate }
}

/// Simulate row-split (warp-per-segment nnz split) for structure `s` at
/// segment capacity `cap`.
pub fn simulate_rowsplit(
    s: &dyn SparseStructure,
    cap: usize,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
) -> KernelReport {
    let (counters, flops) = rowsplit_walk(s, cap, dev, cfg);
    let estimate = estimate_time(&counters, flops, dev);
    KernelReport { algo: "rowsplit", device: dev.name, counters, flops, estimate }
}

/// Convenience: simulate all three algorithms on a real GCOO matrix.
pub fn simulate_all(gcoo: &Gcoo, dev: &DeviceConfig, cfg: &WalkConfig) -> [KernelReport; 3] {
    let s = GcooStructure::new(gcoo);
    [
        simulate_gcoo(&s, dev, cfg, true),
        simulate_csr(&s, dev, cfg),
        simulate_dense(gcoo.n_cols, dev, cfg),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;
    use crate::sparse::Gcoo;

    fn small_gcoo(n: usize, s: f64, seed: u64) -> Gcoo {
        let mut rng = Rng::new(seed);
        Gcoo::from_dense(&gen::uniform(n, s, &mut rng), 8)
    }

    #[test]
    fn headline_gcoo_beats_csr_on_uniform() {
        // The paper's core claim at moderate-high sparsity on random matrices.
        let gcoo = small_gcoo(512, 0.99, 1);
        let cfg = WalkConfig::default();
        let s = GcooStructure::new(&gcoo);
        let g = simulate_gcoo(&s, &TITANX, &cfg, true);
        let c = simulate_csr(&s, &TITANX, &cfg);
        assert!(
            g.time_s() < c.time_s(),
            "gcoo {} vs csr {}",
            g.time_s(),
            c.time_s()
        );
    }

    #[test]
    fn dense_constant_in_sparsity_sparse_decreasing() {
        let cfg = WalkConfig::default();
        let d1 = simulate_dense(512, &P100, &cfg);
        let g_low = simulate_gcoo(&GcooStructure::new(&small_gcoo(512, 0.9, 2)), &P100, &cfg, true);
        let g_high = simulate_gcoo(&GcooStructure::new(&small_gcoo(512, 0.995, 2)), &P100, &cfg, true);
        assert!(g_high.time_s() < g_low.time_s(), "sparser must be faster");
        assert!(d1.time_s() > 0.0);
    }

    #[test]
    fn reports_have_positive_counts() {
        let gcoo = small_gcoo(256, 0.95, 3);
        for rep in simulate_all(&gcoo, &GTX980, &WalkConfig::default()) {
            assert!(rep.flops > 0, "{}: no flops", rep.algo);
            assert!(rep.counters.total_mem_transactions() > 0, "{}: no traffic", rep.algo);
            assert!(rep.time_s() > 0.0);
        }
    }

    #[test]
    fn effective_gflops_uses_paper_equation() {
        let gcoo = small_gcoo(256, 0.9, 4);
        let rep = simulate_gcoo(&GcooStructure::new(&gcoo), &TITANX, &WalkConfig::default(), true);
        let g = rep.effective_gflops(256, 0.9);
        let manual = 2.0 * 256f64.powi(3) * 0.1 / rep.time_s() / 1e9;
        assert!((g - manual).abs() / manual < 1e-9);
    }
}
