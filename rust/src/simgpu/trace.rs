//! Instruction traces: the event stream instrumented kernels emit, the
//! sinks that consume it, and the replayer that feeds it through the
//! existing [`MemorySystem`]/[`Cache`](super::Cache)/cost stack.
//!
//! ## Event vocabulary
//!
//! Three warp-level memory events plus a FLOP tally — exactly the
//! operations [`MemorySystem`] accepts, so a trace carries everything the
//! model needs and nothing it doesn't:
//!
//! * **Gather** — one warp access at explicit per-lane byte addresses
//!   (`MemorySystem::warp_access`): scattered loads, bank-conflict-prone
//!   shared stores.
//! * **Contig** — one warp access of `lanes` consecutive 4-byte words from
//!   `base` (`MemorySystem::warp_load_contiguous`): coalesced global
//!   loads/stores, conflict-free shared staging.
//! * **Broadcasts** — `count` shared-memory broadcast transactions
//!   (adjacent broadcasts coalesce into one event when recorded).
//!
//! Every memory event carries the absolute thread-block id `blk`; consumers
//! derive the SM as `blk % sms`, which is how the walkers always assigned
//! blocks to L1/tex caches.
//!
//! ## Sink dispatch
//!
//! [`TraceSink`] is a generic (monomorphized) trait, so instrumented
//! kernels pay nothing when tracing is off: [`NullSink`] reports
//! `active() == false`, every method is an inlined no-op, and emission
//! sites are guarded by `if sink.active()` — the serving hot path compiles
//! to the exact pre-instrumentation code, with no allocation. The two live
//! sinks are [`TraceRecorder`] (materialize a [`Trace`] for storage or
//! later replay) and [`ReplaySink`] (stream events straight into a
//! [`MemorySystem`] without materializing them — what the figure sweeps
//! use at n = 14000, where a stored csr trace would be gigabytes).
//!
//! ## Replay pipeline
//!
//! `kernel → TraceSink → MemorySystem (coalescer → L1/tex → L2 → DRAM)
//! → Counters → cost::estimate_time`. [`Trace::replay`] runs a recorded
//! stream through a fresh memory system and scales counters from the
//! traced window to the full grid, identically to how the hand walkers
//! sampled; [`TraceOracle`] packages the pipeline as the cost oracle the
//! autotuner and `put_a`'s registration refinement consult.

use super::device::DeviceConfig;
pub use super::device::WARP;
use super::mem::{Counters, MemorySystem, Space};
use super::structure::SparseStructure;
use super::walkers::WalkConfig;

/// Disjoint byte-address regions of the modeled global memory (shared by
/// the instrumented kernels and the legacy hand walkers).
pub const A_VALS: u64 = 0;
pub const A_ROWS: u64 = 1 << 40;
pub const A_COLS: u64 = 2 << 40;
pub const B_BASE: u64 = 3 << 40;
pub const C_BASE: u64 = 4 << 40;
pub const ROWPTR: u64 = 5 << 40;

/// Effective column-ILP of the cuSPARSE-era csrmm: lanes covering adjacent
/// C columns share memory sectors, partially re-coalescing its scattered
/// loads (see the csr emitter docs).
pub const ILP_COLS: usize = 4;

/// Thread-block width the instrumented reference kernels model — the
/// paper's b, matching `WalkConfig::default().b` so engine-emitted traces
/// line up with the default walker geometry.
pub const TRACE_BLOCK_THREADS: usize = 128;

/// Dense GEMM tile geometry (64×64 C tiles, k-depth 16, 8×8 register tile
/// per thread) — shared by the gemm emitter and the legacy walker.
pub const GEMM_TILE: usize = 64;
pub const GEMM_TK: usize = 16;
pub const GEMM_RT: usize = 8;

/// One recorded warp-level event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// Scattered warp access at per-lane byte addresses.
    Gather { space: Space, blk: u32, addrs: Vec<u64> },
    /// Coalesced warp access: `lanes` consecutive 4-byte words from `base`.
    Contig { space: Space, blk: u32, base: u64, lanes: u8 },
    /// `count` shared-memory broadcast transactions.
    Broadcasts { count: u64 },
}

/// A materialized instruction trace: the event stream of `traced_blocks`
/// thread blocks out of a `total_blocks` grid, plus the kernel's exact
/// FLOP count (FLOPs are determined by nnz/n, never sampled).
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub flops: u64,
    pub total_blocks: usize,
    pub traced_blocks: usize,
    /// Inner-loop sampling factor `(full, sampled)` beyond block sampling —
    /// the csr kernel traces `sampled` of `full` C columns per block. (1, 1)
    /// for kernels that trace every inner iteration. Kept as a ratio so
    /// replay applies the *same float arithmetic* the walkers use (folding
    /// it into block counts would diverge when `full % sampled != 0`).
    pub col_sample: (usize, usize),
}

impl Default for Trace {
    fn default() -> Trace {
        Trace { events: Vec::new(), flops: 0, total_blocks: 0, traced_blocks: 0, col_sample: (1, 1) }
    }
}

impl Trace {
    /// Grid scale factor: traced window → full grid (× inner-loop sample).
    pub fn scale(&self) -> f64 {
        (self.total_blocks as f64 / self.traced_blocks.max(1) as f64)
            * (self.col_sample.0 as f64 / self.col_sample.1.max(1) as f64)
    }

    /// Replay the stream through a fresh memory system on `dev` and return
    /// the grid-scaled counters plus the exact FLOP count — the same
    /// construction and scaling the walkers use, so a recorded trace and a
    /// streamed [`ReplaySink`] run produce identical counters.
    pub fn replay(&self, dev: &DeviceConfig) -> (Counters, u64) {
        let mut ms = MemorySystem::new(dev, dev.sms.min(self.traced_blocks.max(1)));
        self.replay_into(&mut ms, dev.sms);
        (ms.counters.scale(self.scale()), self.flops)
    }

    /// Apply every event to an existing memory system (`sms` maps block
    /// ids to SMs, as `blk % sms`).
    pub fn replay_into(&self, ms: &mut MemorySystem, sms: usize) {
        let sms = sms.max(1);
        for ev in &self.events {
            match ev {
                TraceEvent::Gather { space, blk, addrs } => {
                    ms.warp_access(*space, addrs, *blk as usize % sms);
                }
                TraceEvent::Contig { space, blk, base, lanes } => {
                    ms.warp_load_contiguous(*space, *base, *lanes as usize, *blk as usize % sms);
                }
                TraceEvent::Broadcasts { count } => ms.shared_broadcasts(*count),
            }
        }
    }
}

/// Consumer of instrumented-kernel events. Generic dispatch: callers are
/// monomorphized per sink type, so the [`NullSink`] instantiation folds
/// every call away and leaves the hot path untouched.
pub trait TraceSink {
    /// Whether events are wanted at all — emission sites gate on this so
    /// the disabled path never builds address vectors.
    fn active(&self) -> bool;
    /// Declare the grid: total blocks launched, blocks actually traced.
    fn grid(&mut self, total_blocks: usize, traced_blocks: usize);
    /// Declare an inner-loop sampling factor beyond block sampling (the
    /// csr kernel traces `sampled` of `full` C columns per block); default
    /// no-op — streaming consumers apply their own scale.
    fn inner_sample(&mut self, _full: usize, _sampled: usize) {}
    fn gather(&mut self, space: Space, blk: usize, addrs: &[u64]);
    fn contig(&mut self, space: Space, blk: usize, base: u64, lanes: usize);
    fn broadcasts(&mut self, count: u64);
    fn flops(&mut self, count: u64);
}

/// The disabled sink: zero-overhead by construction.
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline(always)]
    fn active(&self) -> bool {
        false
    }
    #[inline(always)]
    fn grid(&mut self, _total_blocks: usize, _traced_blocks: usize) {}
    #[inline(always)]
    fn gather(&mut self, _space: Space, _blk: usize, _addrs: &[u64]) {}
    #[inline(always)]
    fn contig(&mut self, _space: Space, _blk: usize, _base: u64, _lanes: usize) {}
    #[inline(always)]
    fn broadcasts(&mut self, _count: u64) {}
    #[inline(always)]
    fn flops(&mut self, _count: u64) {}
}

/// Record events into a [`Trace`]. Adjacent broadcast events coalesce, so
/// the per-entry broadcast chatter of a GCOO scan stays one event per run.
#[derive(Default)]
pub struct TraceRecorder {
    pub trace: Trace,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    /// Consume the recorder, yielding the finished trace.
    pub fn finish(self) -> Trace {
        self.trace
    }
}

impl TraceSink for TraceRecorder {
    fn active(&self) -> bool {
        true
    }
    fn grid(&mut self, total_blocks: usize, traced_blocks: usize) {
        self.trace.total_blocks = total_blocks;
        self.trace.traced_blocks = traced_blocks;
    }
    fn inner_sample(&mut self, full: usize, sampled: usize) {
        self.trace.col_sample = (full, sampled.max(1));
    }
    fn gather(&mut self, space: Space, blk: usize, addrs: &[u64]) {
        self.trace.events.push(TraceEvent::Gather { space, blk: blk as u32, addrs: addrs.to_vec() });
    }
    fn contig(&mut self, space: Space, blk: usize, base: u64, lanes: usize) {
        self.trace.events.push(TraceEvent::Contig {
            space,
            blk: blk as u32,
            base,
            lanes: lanes.min(WARP) as u8,
        });
    }
    fn broadcasts(&mut self, count: u64) {
        if let Some(TraceEvent::Broadcasts { count: last }) = self.trace.events.last_mut() {
            *last += count;
        } else {
            self.trace.events.push(TraceEvent::Broadcasts { count });
        }
    }
    fn flops(&mut self, count: u64) {
        self.trace.flops += count;
    }
}

/// Stream events straight into a [`MemorySystem`], never materializing
/// them — the walkers' and figure sweeps' sink (a stored csr trace at the
/// paper's n = 14000 would be gigabytes; this one is O(1) memory).
pub struct ReplaySink<'a> {
    ms: &'a mut MemorySystem,
    sms: usize,
    pub flops: u64,
    pub total_blocks: usize,
    pub traced_blocks: usize,
}

impl<'a> ReplaySink<'a> {
    pub fn new(ms: &'a mut MemorySystem, sms: usize) -> ReplaySink<'a> {
        ReplaySink { ms, sms: sms.max(1), flops: 0, total_blocks: 0, traced_blocks: 0 }
    }
}

impl TraceSink for ReplaySink<'_> {
    fn active(&self) -> bool {
        true
    }
    fn grid(&mut self, total_blocks: usize, traced_blocks: usize) {
        self.total_blocks = total_blocks;
        self.traced_blocks = traced_blocks;
    }
    fn gather(&mut self, space: Space, blk: usize, addrs: &[u64]) {
        self.ms.warp_access(space, addrs, blk % self.sms);
    }
    fn contig(&mut self, space: Space, blk: usize, base: u64, lanes: usize) {
        self.ms.warp_load_contiguous(space, base, lanes, blk % self.sms);
    }
    fn broadcasts(&mut self, count: u64) {
        self.ms.shared_broadcasts(count);
    }
    fn flops(&mut self, count: u64) {
        self.flops += count;
    }
}

// ---------------------------------------------------------------- emitters

// The per-block emitters below are the single source of the three
// kernels' warp-level transaction streams: the instrumented reference
// kernels in runtime/engine.rs and the walker adapters both call them, so
// kernel and model can no longer drift. Bodies are exact transcriptions of
// the hand walkers they replace (rust/tests/trace_differential.rs pins the
// equivalence against the retained `hand_*` baselines), quirks included.

/// One GCOOSpDM thread block (paper Algorithm 2): stage the band's COO
/// into shared memory in `bt`-sized chunks, scan entries (3 shared
/// broadcasts per entry per warp; one texture-path B-row load per *new*
/// column when `reuse`), then the single C write of p rows × bt columns.
///
/// `cols` are the band's entry columns in stored (col, row)-sorted order;
/// `n_rows` bounds the C rows written (the matrix height); `m` is the B/C
/// column count *and* row stride (equal to n for a square B, `w·n` for a
/// fused wide-B batch).
#[allow(clippy::too_many_arguments)]
pub fn emit_gcoo_block<S: TraceSink>(
    sink: &mut S,
    blk: usize,
    cols: &[u32],
    gi: usize,
    jb: usize,
    p: usize,
    bt: usize,
    reuse: bool,
    n_rows: usize,
    m: usize,
) {
    let nnz_b = cols.len();
    let warps = bt / WARP;
    let col_base = (jb * bt) as u64;

    // --- stage COO chunks into shared memory (lines 12-15) ---
    let chunks = nnz_b.div_ceil(bt).max(1);
    for ch in 0..chunks {
        let chunk_len = bt.min(nnz_b.saturating_sub(ch * bt)).max(1);
        let cwarps = chunk_len.div_ceil(WARP);
        for w in 0..cwarps {
            let off = ((ch * bt + w * WARP) * 4) as u64;
            let lanes = chunk_len.saturating_sub(w * WARP).min(WARP);
            for base in [A_VALS, A_ROWS, A_COLS] {
                sink.contig(Space::GlobalL2, blk, base + off, lanes);
                // store to shared: conflict-free (consecutive words)
                sink.contig(Space::Shared, blk, off, lanes);
            }
        }
    }

    // --- scan entries (lines 20-36) ---
    let mut prev_col: Option<u32> = None;
    for &col in cols.iter().take(nnz_b) {
        // every thread reads (val, row, col) from shared: broadcast
        sink.broadcasts(3 * warps as u64);
        let is_run = reuse && prev_col == Some(col);
        if !is_run {
            // B(col, col_base + t) for t in 0..bt — texture path, coalesced
            for w in 0..warps {
                let base = B_BASE + ((col as u64) * m as u64 + col_base + (w * WARP) as u64) * 4;
                let lanes = m.saturating_sub(jb * bt + w * WARP).min(WARP);
                if lanes > 0 {
                    sink.contig(Space::GlobalTex, blk, base, lanes);
                }
            }
        }
        prev_col = Some(col);
    }

    // --- single C write (lines 38-39): p rows × bt columns ---
    for r in 0..p {
        let row = gi * p + r;
        if row >= n_rows {
            break;
        }
        for w in 0..warps {
            let base = C_BASE + ((row as u64) * m as u64 + col_base + (w * WARP) as u64) * 4;
            let lanes = m.saturating_sub(jb * bt + w * WARP).min(WARP);
            if lanes > 0 {
                sink.contig(Space::GlobalL2, blk, base, lanes);
            }
        }
    }
}

/// One CMRS thread block: the same staged-scan hardware walk as
/// [`emit_gcoo_block`] with run detection on — the *stored entry order* is
/// what differs. `cols` are the strip's entry columns in round-robin
/// interleaved order, so same-column runs (and hence B-load reuse) rarely
/// survive the interleave: CMRS trades GCOO's reuse for never letting one
/// heavy row serialize a strip's scan. Delegating keeps one source of
/// truth for the block walk; the cost divergence comes entirely from the
/// order of `cols`.
#[allow(clippy::too_many_arguments)]
pub fn emit_cmrs_block<S: TraceSink>(
    sink: &mut S,
    blk: usize,
    cols: &[u32],
    si: usize,
    jb: usize,
    p: usize,
    bt: usize,
    n_rows: usize,
    m: usize,
) {
    emit_gcoo_block(sink, blk, cols, si, jb, p, bt, true, n_rows, m);
}

/// One row-split thread block (nnz-split SpMM, Yang/Buluç/Owens): one
/// *warp* per segment, `bt / WARP` segments per block, the block covering
/// a `bt`-wide C column tile. Per segment: the owning-row load, the
/// segment's entries streamed with coalesced A loads in WARP-chunks
/// (row-split's layout win over scattered csrmm), then per entry 2 shared
/// broadcasts (val + col fan-out to the lanes) and a texture-path B row
/// tile, and finally one coalesced C stripe write for the segment's row.
///
/// `segs` holds this block's segments as (owning row, stored entry
/// columns); `seg0` is the global slab index of `segs[0]` (A addresses);
/// `m` is the B/C column count and row stride.
#[allow(clippy::too_many_arguments)]
pub fn emit_rowsplit_block<S: TraceSink>(
    sink: &mut S,
    blk: usize,
    segs: &[(u32, Vec<u32>)],
    seg0: usize,
    cap: usize,
    jb: usize,
    bt: usize,
    m: usize,
) {
    let col_chunks = bt / WARP;
    let col_base = jb * bt;
    for (w, (row, cols)) in segs.iter().enumerate() {
        let seg_base = ((seg0 + w) * cap) as u64;
        // Owning-row load (the seg_rows array, one lane).
        sink.contig(Space::GlobalL2, blk, A_ROWS + 4 * (seg0 + w) as u64, 1);
        // Stream the segment's entries: coalesced val + col loads.
        let len = cols.len();
        let mut off = 0usize;
        while off < len.max(1) {
            let lanes = len.saturating_sub(off).min(WARP).max(1);
            sink.contig(Space::GlobalL2, blk, A_VALS + 4 * (seg_base + off as u64), lanes);
            sink.contig(Space::GlobalL2, blk, A_COLS + 4 * (seg_base + off as u64), lanes);
            off += WARP;
        }
        // Scan: each entry fans (val, col) out to the lanes, then loads
        // the B row's column tile through the texture path.
        for &col in cols {
            sink.broadcasts(2);
            for cc in 0..col_chunks {
                let lanes = m.saturating_sub(col_base + cc * WARP).min(WARP);
                if lanes > 0 {
                    let base =
                        B_BASE + ((col as u64) * m as u64 + (col_base + cc * WARP) as u64) * 4;
                    sink.contig(Space::GlobalTex, blk, base, lanes);
                }
            }
        }
        // One coalesced C stripe write for the segment's row.
        for cc in 0..col_chunks {
            let lanes = m.saturating_sub(col_base + cc * WARP).min(WARP);
            if lanes > 0 {
                let base =
                    C_BASE + ((*row as u64) * m as u64 + (col_base + cc * WARP) as u64) * 4;
                sink.contig(Space::GlobalL2, blk, base, lanes);
            }
        }
    }
}

/// One cuSPARSE-like scalar-row csrmm thread block. One *thread* per row:
/// at step (j, k) the 32 lanes touch 32 different A entries and 32
/// different B addresses (stride-m apart) — every load scattered through
/// the generic L2 path, no shared staging, no texture path. `ILP_COLS`
/// adjacent C columns per thread partially re-coalesce the scatter (one
/// representative lane per [`ILP_COLS`]).
///
/// `rows[t]` is thread t's row's sorted column list (empty past the matrix
/// edge); the C-column loop is sampled at `j_samples` columns of stride
/// `j_stride` (the caller scales counters by m / j_samples).
pub fn emit_csr_block<S: TraceSink>(
    sink: &mut S,
    blk: usize,
    rows: &[Vec<u32>],
    bt: usize,
    m: usize,
    j_samples: usize,
    j_stride: usize,
) {
    let warps = bt / WARP;
    // Per-row offsets into the A arrays (prefix sums of row lengths).
    let mut offs = vec![0u64; bt];
    for t in 1..bt {
        offs[t] = offs[t - 1] + rows[t - 1].len() as u64;
    }
    let mut addr_buf: Vec<u64> = Vec::with_capacity(WARP);
    for jj in 0..j_samples {
        let j = (jj * j_stride) as u64;
        for w in 0..warps {
            let lanes: Vec<usize> =
                (0..WARP).filter(|&t| !rows[w * WARP + t].is_empty()).collect();
            if lanes.is_empty() {
                continue;
            }
            if jj == 0 {
                // row_ptr loads: scattered across lanes
                addr_buf.clear();
                addr_buf.extend(
                    lanes.iter().map(|&t| ROWPTR + 4 * (blk * bt + w * WARP + t) as u64),
                );
                sink.gather(Space::GlobalL2, blk, &addr_buf);
            }
            let max_k = lanes.iter().map(|&t| rows[w * WARP + t].len()).max().unwrap_or(0);
            for k in 0..max_k {
                let act: Vec<usize> = lanes
                    .iter()
                    .copied()
                    .filter(|&t| k < rows[w * WARP + t].len())
                    .collect();
                if act.is_empty() {
                    break;
                }
                let rep = act.iter().copied().step_by(ILP_COLS);
                // A val + col: scattered gathers
                addr_buf.clear();
                addr_buf.extend(
                    rep.clone().map(|t| A_VALS + 4 * (offs[w * WARP + t] + k as u64)),
                );
                sink.gather(Space::GlobalL2, blk, &addr_buf);
                addr_buf.clear();
                addr_buf.extend(
                    rep.clone().map(|t| A_COLS + 4 * (offs[w * WARP + t] + k as u64)),
                );
                sink.gather(Space::GlobalL2, blk, &addr_buf);
                // B(col_t, j): stride-m scatter — the slow path.
                addr_buf.clear();
                addr_buf.extend(rep.map(|t| {
                    let col = rows[w * WARP + t][k] as u64;
                    B_BASE + (col * m as u64 + j) * 4
                }));
                sink.gather(Space::GlobalL2, blk, &addr_buf);
            }
            // C(r, j) write: scattered (stride m)
            addr_buf.clear();
            addr_buf.extend(
                lanes
                    .iter()
                    .map(|&t| C_BASE + ((blk * bt + w * WARP + t) as u64 * m as u64 + j) * 4),
            );
            sink.gather(Space::GlobalL2, blk, &addr_buf);
        }
    }
}

/// One tiled dense GEMM thread block (cuBLAS stand-in): 64×64 C tile,
/// k-loop staging 64×16 A / 16×64 B panels through shared memory, 8×8
/// register tile per thread. `n_i`/`n_k`/`n_j` are the C-rows / inner /
/// C-cols dimensions (all n for square, `n_j = w·n` for a wide-B batch).
pub fn emit_gemm_block<S: TraceSink>(
    sink: &mut S,
    blk: usize,
    ti: usize,
    tj: usize,
    n_i: usize,
    n_k: usize,
    n_j: usize,
) {
    let tile = GEMM_TILE;
    let tk = GEMM_TK;
    let warps_per_tile_row = tile / WARP;
    let ksteps = n_k.div_ceil(tk);
    for ks in 0..ksteps {
        // stage A (tile×tk) and B (tk×tile) via tex path + shared stores
        for r in 0..tile.min(n_i - ti * tile) {
            let base = B_BASE / 2 + (((ti * tile + r) * n_k + ks * tk) * 4) as u64; // A region
            sink.contig(Space::GlobalTex, blk, base, tk);
            sink.gather(Space::Shared, blk, &[(r * tk * 4) as u64]);
        }
        for r in 0..tk.min(n_k.saturating_sub(ks * tk)) {
            for w in 0..warps_per_tile_row {
                let base = B_BASE + (((ks * tk + r) * n_j + tj * tile + w * WARP) * 4) as u64;
                sink.contig(Space::GlobalTex, blk, base, WARP);
                let addrs: Vec<u64> =
                    (0..WARP).map(|t| ((r * tile + w * WARP + t) * 4) as u64).collect();
                sink.gather(Space::Shared, blk, &addrs);
            }
        }
        // inner products: each thread owns an RT×RT register tile, so a
        // shared operand is reused RT times once loaded — 2 broadcast
        // transactions per warp-level MAC bundle.
        let inner_warp_ops = (tile * tile * tk) / (WARP * GEMM_RT);
        sink.broadcasts(2 * inner_warp_ops as u64);
    }
    // C tile write
    for r in 0..tile.min(n_i - ti * tile) {
        for w in 0..warps_per_tile_row {
            let base = C_BASE + (((ti * tile + r) * n_j + tj * tile + w * WARP) * 4) as u64;
            sink.contig(Space::GlobalL2, blk, base, WARP);
        }
    }
}

// ----------------------------------------------------------------- oracle

/// The trace-derived cost oracle: one place that turns (algorithm family,
/// structure) into an estimated kernel time by traced execution through
/// the memory model. The autotuner's measured-refinement stage and
/// `put_a`'s registration refinement (coordinator/store.rs) both consult
/// this — deterministic for a fixed [`WalkConfig`] seed, so refinement
/// rankings are reproducible run-to-run.
#[derive(Clone, Copy, Debug)]
pub struct TraceOracle {
    pub dev: &'static DeviceConfig,
    pub cfg: WalkConfig,
}

impl TraceOracle {
    pub fn new(dev: &'static DeviceConfig, cfg: WalkConfig) -> TraceOracle {
        TraceOracle { dev, cfg }
    }

    /// Estimated GCOOSpDM kernel time for structure `s`.
    pub fn gcoo_time(&self, s: &dyn SparseStructure, reuse: bool) -> f64 {
        super::simulate_gcoo(s, self.dev, &self.cfg, reuse).time_s()
    }

    /// Estimated cuSPARSE-like csrmm kernel time for structure `s`.
    pub fn csr_time(&self, s: &dyn SparseStructure) -> f64 {
        super::simulate_csr(s, self.dev, &self.cfg).time_s()
    }

    /// Estimated dense tiled-GEMM kernel time at size n.
    pub fn dense_time(&self, n: usize) -> f64 {
        super::simulate_dense(n, self.dev, &self.cfg).time_s()
    }

    /// Estimated CMRS kernel time for structure `s` (strip height = the
    /// structure's band height p).
    pub fn cmrs_time(&self, s: &dyn SparseStructure) -> f64 {
        super::simulate_cmrs(s, self.dev, &self.cfg).time_s()
    }

    /// Estimated row-split kernel time for structure `s` at segment
    /// capacity `cap`.
    pub fn rowsplit_time(&self, s: &dyn SparseStructure, cap: usize) -> f64 {
        super::simulate_rowsplit(s, cap, self.dev, &self.cfg).time_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::TITANX;
    use crate::simgpu::structure::SyntheticUniform;
    use crate::simgpu::{
        simulate_cmrs, simulate_csr, simulate_dense, simulate_gcoo, simulate_rowsplit,
    };

    /// A fixed little event script exercising every sink method.
    fn sample_events(sink: &mut impl TraceSink) {
        sink.grid(4, 2);
        sink.contig(Space::GlobalL2, 0, 0, 32);
        sink.gather(Space::GlobalL2, 1, &[0, 4096, 8192]);
        sink.contig(Space::GlobalTex, 1, 1 << 20, 16);
        sink.broadcasts(5);
        sink.broadcasts(7);
        sink.gather(Space::Shared, 0, &[0, 4, 8, 12]);
        sink.flops(1000);
    }

    #[test]
    fn null_sink_is_inactive() {
        let mut s = NullSink;
        assert!(!s.active());
        sample_events(&mut s); // all no-ops
    }

    #[test]
    fn recorder_captures_grid_flops_and_coalesces_broadcasts() {
        let mut r = TraceRecorder::new();
        assert!(r.active());
        sample_events(&mut r);
        let t = r.finish();
        assert_eq!((t.total_blocks, t.traced_blocks, t.flops), (4, 2, 1000));
        let bcasts: Vec<u64> = t
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Broadcasts { count } => Some(*count),
                _ => None,
            })
            .collect();
        assert_eq!(bcasts, vec![12], "adjacent broadcasts must merge into one event");
        assert_eq!(t.scale(), 2.0);
    }

    #[test]
    fn recorded_replay_matches_direct_streaming() {
        let mut r = TraceRecorder::new();
        sample_events(&mut r);
        let (replayed, flops) = r.trace.replay(&TITANX);
        // The same events streamed straight into a memory system built the
        // way replay() builds one.
        let mut ms = MemorySystem::new(&TITANX, TITANX.sms.min(2));
        {
            let mut s = ReplaySink::new(&mut ms, TITANX.sms);
            sample_events(&mut s);
            assert_eq!(s.flops, 1000);
        }
        assert_eq!(replayed, ms.counters.scale(2.0));
        assert_eq!(flops, 1000);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut r = TraceRecorder::new();
        sample_events(&mut r);
        let t = r.finish();
        assert_eq!(t.replay(&TITANX), t.replay(&TITANX));
    }

    #[test]
    fn replay_scales_counters_by_grid_ratio() {
        let mut r = TraceRecorder::new();
        r.grid(10, 2);
        r.contig(Space::GlobalL2, 0, 0, 32); // 128 B = 4 cold sectors
        let (c, _) = r.trace.replay(&TITANX);
        assert_eq!(c.l2, 20, "4 sectors × scale 5");
        assert_eq!(c.dram, 20);
    }

    #[test]
    fn oracle_matches_the_public_simulators() {
        let s = SyntheticUniform::new(256, 0.98, 8, 9);
        let cfg = WalkConfig::default();
        let oracle = TraceOracle::new(&TITANX, cfg);
        assert_eq!(oracle.gcoo_time(&s, true), simulate_gcoo(&s, &TITANX, &cfg, true).time_s());
        assert_eq!(oracle.gcoo_time(&s, false), simulate_gcoo(&s, &TITANX, &cfg, false).time_s());
        assert_eq!(oracle.csr_time(&s), simulate_csr(&s, &TITANX, &cfg).time_s());
        assert_eq!(oracle.dense_time(256), simulate_dense(256, &TITANX, &cfg).time_s());
        assert_eq!(oracle.cmrs_time(&s), simulate_cmrs(&s, &TITANX, &cfg).time_s());
        assert_eq!(
            oracle.rowsplit_time(&s, 16),
            simulate_rowsplit(&s, 16, &TITANX, &cfg).time_s()
        );
    }

    #[test]
    fn gcoo_emitter_handles_empty_band() {
        // An empty band still stages one (degenerate) chunk — the walker
        // quirk the differential suite depends on.
        let mut r = TraceRecorder::new();
        r.grid(1, 1);
        emit_gcoo_block(&mut r, 0, &[], 0, 0, 8, 128, true, 64, 64);
        let t = r.finish();
        assert!(!t.events.is_empty(), "degenerate staging chunk + C write expected");
        let (c, _) = t.replay(&TITANX);
        assert!(c.l1_tex == 0, "no entries → no B loads");
        assert!(c.shm > 0, "staging stores still hit shared");
    }
}
