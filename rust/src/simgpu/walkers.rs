//! Algorithm walkers: thin adapters over **traced execution**.
//!
//! Since the trace-driven inversion, the per-block warp transaction
//! streams live in [`super::trace`]'s `emit_*_block` emitters — the same
//! code the instrumented reference kernels in `runtime/engine.rs` run
//! under a [`TraceSink`]. A walker now just picks a *sampled contiguous
//! window* of thread blocks (in launch order, so cache locality between
//! neighboring blocks is modeled), streams each block's events through a
//! [`ReplaySink`] into a [`MemorySystem`], and scales the counters to the
//! full grid. FLOP counts are exact (determined by nnz / n, never
//! sampled).
//!
//! The pre-inversion hand-derived walkers are retained as
//! [`hand_gcoo_walk`]/[`hand_csr_walk`]/[`hand_gemm_walk`]: they are the
//! differential baseline (`rust/tests/trace_differential.rs` pins the
//! traced adapters to them exactly) and will be deleted once an
//! engine-emitted trace corpus replaces them as the fixture of record —
//! see DESIGN.md §Tracing for the deprecation plan.

use super::device::{DeviceConfig, WARP};
use super::mem::{Counters, MemorySystem, Space};
use super::structure::SparseStructure;
use super::trace::{
    emit_cmrs_block, emit_csr_block, emit_gcoo_block, emit_gemm_block, emit_rowsplit_block,
    ReplaySink, Trace, TraceRecorder, TraceSink, A_COLS, A_ROWS, A_VALS, B_BASE, C_BASE,
    GEMM_TILE, GEMM_TK, ILP_COLS, ROWPTR,
};

/// Walker parameters.
#[derive(Clone, Copy, Debug)]
pub struct WalkConfig {
    /// Threads per block (the paper's b). Must be a multiple of 32.
    pub b: usize,
    /// How many thread blocks to simulate (contiguous window of the grid).
    pub sample_blocks: usize,
    /// Window start selection seed.
    pub seed: u64,
}

impl Default for WalkConfig {
    fn default() -> Self {
        WalkConfig { b: 128, sample_blocks: 64, seed: 0x51A5 }
    }
}

/// Pick a contiguous launch-order window [start, start+len) of the grid.
fn window(total_blocks: usize, cfg: &WalkConfig) -> (usize, usize) {
    let len = cfg.sample_blocks.min(total_blocks);
    let max_start = total_blocks - len;
    // Deterministic mid-grid start (avoids cold-start edge bias at block 0
    // while staying reproducible).
    let start = if max_start == 0 { 0 } else { (cfg.seed as usize) % max_start };
    (start, len)
}

// ------------------------------------------------------- traced adapters

/// GCOOSpDM (paper Algorithm 2). Grid: g bands × ⌈n/b⌉ column tiles,
/// launch order band-major (blockIdx.x = band). Per-block stream emitted
/// by [`emit_gcoo_block`], replayed through the memory model.
pub fn gcoo_walk(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
    reuse: bool,
) -> (Counters, u64) {
    let n = s.n();
    let g = s.num_bands();
    let col_tiles = n.div_ceil(cfg.b);
    let total_blocks = g * col_tiles;
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    {
        let mut sink = ReplaySink::new(&mut ms, dev.sms);
        for blk in start..start + len {
            // launch order: band index fastest (blockIdx.x), as in Algorithm 2.
            let band = s.band(blk % g);
            emit_gcoo_block(&mut sink, blk, &band.cols, blk % g, blk / g, s.p(), cfg.b, reuse, n, n);
        }
    }
    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * s.nnz() * n as u64; // exact: every nonzero × every C column
    (ms.counters.scale(scale), flops)
}

/// cuSPARSE-like scalar-row csrmm (CUDA-8 era): one thread per row, every
/// load scattered through the generic L2 path. Per-block stream emitted by
/// [`emit_csr_block`]. Sampling: a contiguous window of row blocks × a
/// strided sample of C columns; counters scale to the full (blocks × n)
/// space.
pub fn csr_walk(s: &dyn SparseStructure, dev: &DeviceConfig, cfg: &WalkConfig) -> (Counters, u64) {
    let n = s.n();
    let total_blocks = n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    // Sample the kernel's outer loop over C columns with a stride.
    let j_samples = 16usize.min(n);
    let j_stride = (n / j_samples).max(1);
    {
        let mut sink = ReplaySink::new(&mut ms, dev.sms);
        for blk in start..start + len {
            // The block's row structures (host-side bookkeeping, not traffic).
            let rows: Vec<Vec<u32>> = (0..cfg.b)
                .map(|t| {
                    let r = blk * cfg.b + t;
                    if r < n { s.row_cols(r) } else { Vec::new() }
                })
                .collect();
            emit_csr_block(&mut sink, blk, &rows, cfg.b, n, j_samples, j_stride);
        }
    }
    // Scale: sampled blocks → all blocks, sampled columns → all n columns.
    let scale = (total_blocks as f64 / len as f64) * (n as f64 / j_samples as f64);
    let flops = 2 * s.nnz() * n as u64;
    (ms.counters.scale(scale), flops)
}

/// Strip `si`'s entry columns in CMRS round-robin interleaved order,
/// derived from the band's (col,row)-sorted entries: collecting per
/// band-local row preserves each row's ascending columns, then the
/// occurrence-index sweep interleaves across rows — the same order
/// `Cmrs::from_dense` stores, so walker and engine traces agree.
fn cmrs_strip_cols(s: &dyn SparseStructure, si: usize) -> Vec<u32> {
    let band = s.band(si);
    let mut per_row: Vec<Vec<u32>> = vec![Vec::new(); s.p()];
    for (r, c) in band.rows.iter().zip(&band.cols) {
        per_row[*r as usize].push(*c);
    }
    let deepest = per_row.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = Vec::with_capacity(band.len());
    for idx in 0..deepest {
        for list in &per_row {
            if let Some(&c) = list.get(idx) {
                out.push(c);
            }
        }
    }
    out
}

/// CMRS (strips = bands of p rows, round-robin interleaved). Grid matches
/// GCOO's: g strips × ⌈n/b⌉ column tiles, strip index fastest. Per-block
/// stream emitted by [`emit_cmrs_block`] over the interleaved entry order.
pub fn cmrs_walk(s: &dyn SparseStructure, dev: &DeviceConfig, cfg: &WalkConfig) -> (Counters, u64) {
    let n = s.n();
    let g = s.num_bands();
    let total_blocks = g * n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    {
        let mut sink = ReplaySink::new(&mut ms, dev.sms);
        for blk in start..start + len {
            let cols = cmrs_strip_cols(s, blk % g);
            emit_cmrs_block(&mut sink, blk, &cols, blk % g, blk / g, s.p(), cfg.b, n, n);
        }
    }
    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * s.nnz() * n as u64;
    (ms.counters.scale(scale), flops)
}

/// The structure's rows cut into `cap`-entry segments in row order —
/// the same segmentation `RowSplit::from_dense` produces.
fn rowsplit_segments(s: &dyn SparseStructure, cap: usize) -> Vec<(u32, Vec<u32>)> {
    let cap = cap.max(1);
    let mut out = Vec::new();
    for i in 0..s.n() {
        for chunk in s.row_cols(i).chunks(cap) {
            out.push((i as u32, chunk.to_vec()));
        }
    }
    out
}

/// Row-split / nnz-split SpMM (Yang, Buluç & Owens): one warp per
/// segment, ⌈segs/warps⌉ segment blocks × ⌈n/b⌉ column tiles, segment
/// block fastest. Per-block stream emitted by [`emit_rowsplit_block`].
pub fn rowsplit_walk(
    s: &dyn SparseStructure,
    cap: usize,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
) -> (Counters, u64) {
    let n = s.n();
    let segs = rowsplit_segments(s, cap);
    let warps = cfg.b / WARP;
    let seg_blocks = segs.len().div_ceil(warps).max(1);
    let total_blocks = seg_blocks * n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    {
        let mut sink = ReplaySink::new(&mut ms, dev.sms);
        for blk in start..start + len {
            let sb = blk % seg_blocks;
            let jb = blk / seg_blocks;
            let lo = (sb * warps).min(segs.len());
            let hi = (lo + warps).min(segs.len());
            emit_rowsplit_block(&mut sink, blk, &segs[lo..hi], lo, cap, jb, cfg.b, n);
        }
    }
    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * s.nnz() * n as u64;
    (ms.counters.scale(scale), flops)
}

/// Tiled dense GEMM (cuBLAS stand-in): 64×64 C tiles, k-loop staging 64×16
/// A/B tiles through shared memory. Per-block stream emitted by
/// [`emit_gemm_block`]. Compute-bound at large n, which yields the
/// constant-in-sparsity line of Figs 7–9.
pub fn gemm_walk(n: usize, dev: &DeviceConfig, cfg: &WalkConfig) -> (Counters, u64) {
    let tiles = n.div_ceil(GEMM_TILE);
    let total_blocks = tiles * tiles;
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    {
        let mut sink = ReplaySink::new(&mut ms, dev.sms);
        for blk in start..start + len {
            emit_gemm_block(&mut sink, blk, blk % tiles, blk / tiles, n, n, n);
        }
    }
    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * (n as u64).pow(3);
    (ms.counters.scale(scale), flops)
}

// ----------------------------------------------------------- recording

/// Record the sampled GCOOSpDM window as a materialized [`Trace`]
/// (replayable on any device; `Trace::replay` reproduces [`gcoo_walk`]'s
/// counters exactly).
pub fn record_gcoo(s: &dyn SparseStructure, cfg: &WalkConfig, reuse: bool) -> Trace {
    let n = s.n();
    let g = s.num_bands();
    let total_blocks = g * n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut rec = TraceRecorder::new();
    rec.grid(total_blocks, len);
    for blk in start..start + len {
        let band = s.band(blk % g);
        emit_gcoo_block(&mut rec, blk, &band.cols, blk % g, blk / g, s.p(), cfg.b, reuse, n, n);
    }
    rec.flops(2 * s.nnz() * n as u64);
    rec.finish()
}

/// Record the sampled csrmm window. The C-column sampling is carried in
/// the trace's `col_sample` ratio, so `Trace::replay` applies exactly the
/// combined scale factor [`csr_walk`] computes.
pub fn record_csr(s: &dyn SparseStructure, cfg: &WalkConfig) -> Trace {
    let n = s.n();
    let total_blocks = n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let j_samples = 16usize.min(n);
    let j_stride = (n / j_samples).max(1);
    let mut rec = TraceRecorder::new();
    rec.grid(total_blocks, len);
    rec.inner_sample(n, j_samples);
    for blk in start..start + len {
        let rows: Vec<Vec<u32>> = (0..cfg.b)
            .map(|t| {
                let r = blk * cfg.b + t;
                if r < n { s.row_cols(r) } else { Vec::new() }
            })
            .collect();
        emit_csr_block(&mut rec, blk, &rows, cfg.b, n, j_samples, j_stride);
    }
    rec.flops(2 * s.nnz() * n as u64);
    rec.finish()
}

/// Record the sampled CMRS window as a materialized [`Trace`]
/// (`Trace::replay` reproduces [`cmrs_walk`]'s counters exactly).
pub fn record_cmrs(s: &dyn SparseStructure, cfg: &WalkConfig) -> Trace {
    let n = s.n();
    let g = s.num_bands();
    let total_blocks = g * n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut rec = TraceRecorder::new();
    rec.grid(total_blocks, len);
    for blk in start..start + len {
        let cols = cmrs_strip_cols(s, blk % g);
        emit_cmrs_block(&mut rec, blk, &cols, blk % g, blk / g, s.p(), cfg.b, n, n);
    }
    rec.flops(2 * s.nnz() * n as u64);
    rec.finish()
}

/// Record the sampled row-split window as a materialized [`Trace`].
pub fn record_rowsplit(s: &dyn SparseStructure, cap: usize, cfg: &WalkConfig) -> Trace {
    let n = s.n();
    let segs = rowsplit_segments(s, cap);
    let warps = cfg.b / WARP;
    let seg_blocks = segs.len().div_ceil(warps).max(1);
    let total_blocks = seg_blocks * n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let mut rec = TraceRecorder::new();
    rec.grid(total_blocks, len);
    for blk in start..start + len {
        let sb = blk % seg_blocks;
        let jb = blk / seg_blocks;
        let lo = (sb * warps).min(segs.len());
        let hi = (lo + warps).min(segs.len());
        emit_rowsplit_block(&mut rec, blk, &segs[lo..hi], lo, cap, jb, cfg.b, n);
    }
    rec.flops(2 * s.nnz() * n as u64);
    rec.finish()
}

/// Record the sampled dense-GEMM window as a [`Trace`].
pub fn record_gemm(n: usize, cfg: &WalkConfig) -> Trace {
    let tiles = n.div_ceil(GEMM_TILE);
    let total_blocks = tiles * tiles;
    let (start, len) = window(total_blocks, cfg);
    let mut rec = TraceRecorder::new();
    rec.grid(total_blocks, len);
    for blk in start..start + len {
        emit_gemm_block(&mut rec, blk, blk % tiles, blk / tiles, n, n, n);
    }
    rec.flops(2 * (n as u64).pow(3));
    rec.finish()
}

// ------------------------------------------------ legacy hand walkers
//
// Pre-inversion hand-derived transaction streams, kept verbatim as the
// differential baseline for the traced adapters above. Do not extend:
// new algorithm families get emitters in `trace.rs`, not hand walkers.

/// Legacy hand-derived GCOOSpDM walker (differential baseline only).
pub fn hand_gcoo_walk(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
    reuse: bool,
) -> (Counters, u64) {
    let n = s.n();
    let g = s.num_bands();
    let col_tiles = n.div_ceil(cfg.b);
    let total_blocks = g * col_tiles;
    let (start, len) = window(total_blocks, cfg);
    let warps = cfg.b / WARP;
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));

    for blk in start..start + len {
        // launch order: band index fastest (blockIdx.x), as in Algorithm 2.
        let gi = blk % g;
        let jb = blk / g;
        let sm = blk % dev.sms;
        let band = s.band(gi);
        let nnz_b = band.len();
        let col_base = (jb * cfg.b) as u64;

        // --- stage COO chunks into shared memory (lines 12-15) ---
        let chunks = nnz_b.div_ceil(cfg.b).max(1);
        for ch in 0..chunks {
            let chunk_len = cfg.b.min(nnz_b.saturating_sub(ch * cfg.b)).max(1);
            let cwarps = chunk_len.div_ceil(WARP);
            for w in 0..cwarps {
                let off = ((ch * cfg.b + w * WARP) * 4) as u64;
                let lanes = chunk_len.saturating_sub(w * WARP).min(WARP);
                for base in [A_VALS, A_ROWS, A_COLS] {
                    ms.warp_load_contiguous(Space::GlobalL2, base + off, lanes, sm);
                    // store to shared: conflict-free (consecutive words)
                    ms.warp_load_contiguous(Space::Shared, off, lanes, sm);
                }
            }
        }

        // --- scan entries (lines 20-36) ---
        let mut prev_col: Option<u32> = None;
        for k in 0..nnz_b {
            let col = band.cols[k];
            // every thread reads (val, row, col) from shared: broadcast
            for _ in 0..warps {
                ms.shared_broadcast(); // sVals[j]
                ms.shared_broadcast(); // sCols[j]
                ms.shared_broadcast(); // sRows[j]
            }
            let is_run = reuse && prev_col == Some(col);
            if !is_run {
                // B(col, col_base + t) for t in 0..b — texture path, coalesced
                for w in 0..warps {
                    let base = B_BASE + ((col as u64) * n as u64 + col_base + (w * WARP) as u64) * 4;
                    let lanes = n.saturating_sub(jb * cfg.b + w * WARP).min(WARP);
                    if lanes > 0 {
                        ms.warp_load_contiguous(Space::GlobalTex, base, lanes, sm);
                    }
                }
            }
            prev_col = Some(col);
        }

        // --- single C write (lines 38-39): p rows × b columns ---
        for r in 0..s.p() {
            let row = gi * s.p() + r;
            if row >= n {
                break;
            }
            for w in 0..warps {
                let base = C_BASE + ((row as u64) * n as u64 + col_base + (w * WARP) as u64) * 4;
                let lanes = n.saturating_sub(jb * cfg.b + w * WARP).min(WARP);
                if lanes > 0 {
                    ms.warp_load_contiguous(Space::GlobalL2, base, lanes, sm);
                }
            }
        }
    }

    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * s.nnz() * n as u64; // exact: every nonzero × every C column
    (ms.counters.scale(scale), flops)
}

/// Legacy hand-derived csrmm walker (differential baseline only).
pub fn hand_csr_walk(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
) -> (Counters, u64) {
    let n = s.n();
    let total_blocks = n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let warps = cfg.b / WARP;
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));

    // Sample the kernel's outer loop over C columns with a stride.
    let j_samples = 16usize.min(n);
    let j_stride = (n / j_samples).max(1);

    for blk in start..start + len {
        let sm = blk % dev.sms;
        // The block's row structures (host-side bookkeeping, not traffic).
        let rows: Vec<Vec<u32>> = (0..cfg.b)
            .map(|t| {
                let r = blk * cfg.b + t;
                if r < n { s.row_cols(r) } else { Vec::new() }
            })
            .collect();
        // Per-row offsets into the A arrays (prefix sums of row lengths).
        let mut offs = vec![0u64; cfg.b];
        for t in 1..cfg.b {
            offs[t] = offs[t - 1] + rows[t - 1].len() as u64;
        }
        let mut addr_buf: Vec<u64> = Vec::with_capacity(WARP);
        for jj in 0..j_samples {
            let j = (jj * j_stride) as u64;
            for w in 0..warps {
                let lanes: Vec<usize> =
                    (0..WARP).filter(|&t| !rows[w * WARP + t].is_empty()).collect();
                if lanes.is_empty() {
                    continue;
                }
                if jj == 0 {
                    // row_ptr loads: scattered across lanes
                    addr_buf.clear();
                    addr_buf.extend(
                        lanes.iter().map(|&t| ROWPTR + 4 * (blk * cfg.b + w * WARP + t) as u64),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                }
                let max_k = lanes.iter().map(|&t| rows[w * WARP + t].len()).max().unwrap_or(0);
                for k in 0..max_k {
                    let act: Vec<usize> = lanes
                        .iter()
                        .copied()
                        .filter(|&t| k < rows[w * WARP + t].len())
                        .collect();
                    if act.is_empty() {
                        break;
                    }
                    // Partial coalescing: csrmm processes ILP_COLS C
                    // columns per thread, so ILP_COLS lanes' 4-byte loads
                    // share one 32-byte sector; modeled by issuing one
                    // representative lane per ILP_COLS. Calibrated so the
                    // simulated cuSPARSE/GCOO gap matches the paper's
                    // measured 1.5-2x average on uniform matrices.
                    let rep = act.iter().copied().step_by(ILP_COLS);
                    // A val + col: scattered gathers
                    addr_buf.clear();
                    addr_buf.extend(
                        rep.clone().map(|t| A_VALS + 4 * (offs[w * WARP + t] + k as u64)),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                    addr_buf.clear();
                    addr_buf.extend(
                        rep.clone().map(|t| A_COLS + 4 * (offs[w * WARP + t] + k as u64)),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                    // B(col_t, j): stride-n scatter — the slow path.
                    addr_buf.clear();
                    addr_buf.extend(rep.map(|t| {
                        let col = rows[w * WARP + t][k] as u64;
                        B_BASE + (col * n as u64 + j) * 4
                    }));
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                }
                // C(r, j) write: scattered (stride n)
                addr_buf.clear();
                addr_buf.extend(
                    lanes
                        .iter()
                        .map(|&t| C_BASE + ((blk * cfg.b + w * WARP + t) as u64 * n as u64 + j) * 4),
                );
                ms.warp_access(Space::GlobalL2, &addr_buf, sm);
            }
        }
    }

    // Scale: sampled blocks → all blocks, sampled columns → all n columns.
    let scale = (total_blocks as f64 / len as f64) * (n as f64 / j_samples as f64);
    let flops = 2 * s.nnz() * n as u64;
    (ms.counters.scale(scale), flops)
}

/// Legacy hand-derived dense-GEMM walker (differential baseline only).
pub fn hand_gemm_walk(n: usize, dev: &DeviceConfig, cfg: &WalkConfig) -> (Counters, u64) {
    let tile = GEMM_TILE;
    let tk = GEMM_TK;
    let tiles = n.div_ceil(tile);
    let total_blocks = tiles * tiles;
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    let warps_per_tile_row = tile / WARP;

    for blk in start..start + len {
        let ti = blk % tiles;
        let tj = blk / tiles;
        let sm = blk % dev.sms;
        let ksteps = n.div_ceil(tk);
        for ks in 0..ksteps {
            // stage A (tile×tk) and B (tk×tile) via tex path + shared stores
            for r in 0..tile.min(n - ti * tile) {
                let base = B_BASE / 2 + (((ti * tile + r) * n + ks * tk) * 4) as u64; // A region
                ms.warp_load_contiguous(Space::GlobalTex, base, tk, sm);
                ms.warp_access(Space::Shared, &[(r * tk * 4) as u64], sm);
            }
            for r in 0..tk.min(n.saturating_sub(ks * tk)) {
                for w in 0..warps_per_tile_row {
                    let base =
                        B_BASE + (((ks * tk + r) * n + tj * tile + w * WARP) * 4) as u64;
                    ms.warp_load_contiguous(Space::GlobalTex, base, WARP, sm);
                    let addrs: Vec<u64> =
                        (0..WARP).map(|t| ((r * tile + w * WARP + t) * 4) as u64).collect();
                    ms.warp_access(Space::Shared, &addrs, sm);
                }
            }
            // inner products: each thread owns an RT×RT register tile
            // (register blocking à la cuBLAS/MAGMA), so a shared-memory
            // operand is reused RT times once loaded — shared traffic is
            // MACs / (WARP · RT) warp-transactions per operand.
            const RT: usize = 8;
            let inner_warp_ops = (tile * tile * tk) / (WARP * RT);
            for _ in 0..inner_warp_ops {
                ms.shared_broadcast(); // A operand
                ms.shared_broadcast(); // B operand
            }
        }
        // C tile write
        for r in 0..tile.min(n - ti * tile) {
            for w in 0..warps_per_tile_row {
                let base = C_BASE + (((ti * tile + r) * n + tj * tile + w * WARP) * 4) as u64;
                ms.warp_load_contiguous(Space::GlobalL2, base, WARP, sm);
            }
        }
    }

    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * (n as u64).pow(3);
    (ms.counters.scale(scale), flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::TITANX;
    use crate::simgpu::structure::SyntheticUniform;

    fn synth(n: usize, s: f64) -> SyntheticUniform {
        SyntheticUniform::new(n, s, 8, 9)
    }

    #[test]
    fn gcoo_flops_exact() {
        let s = synth(512, 0.99);
        let (_c, flops) = gcoo_walk(&s, &TITANX, &WalkConfig::default(), true);
        assert_eq!(flops, 2 * s.nnz() * 512);
    }

    #[test]
    fn traced_adapters_match_hand_walkers() {
        // The inversion's core invariant, in-module smoke form (the full
        // corpus sweep lives in rust/tests/trace_differential.rs).
        let s = synth(256, 0.98);
        let cfg = WalkConfig::default();
        assert_eq!(gcoo_walk(&s, &TITANX, &cfg, true), hand_gcoo_walk(&s, &TITANX, &cfg, true));
        assert_eq!(gcoo_walk(&s, &TITANX, &cfg, false), hand_gcoo_walk(&s, &TITANX, &cfg, false));
        assert_eq!(csr_walk(&s, &TITANX, &cfg), hand_csr_walk(&s, &TITANX, &cfg));
        assert_eq!(gemm_walk(256, &TITANX, &cfg), hand_gemm_walk(256, &TITANX, &cfg));
    }

    #[test]
    fn recorded_traces_replay_to_walker_counters() {
        let s = synth(256, 0.98);
        let cfg = WalkConfig::default();
        assert_eq!(record_gcoo(&s, &cfg, true).replay(&TITANX), gcoo_walk(&s, &TITANX, &cfg, true));
        assert_eq!(record_csr(&s, &cfg).replay(&TITANX), csr_walk(&s, &TITANX, &cfg));
        assert_eq!(record_gemm(256, &cfg).replay(&TITANX), gemm_walk(256, &TITANX, &cfg));
        assert_eq!(record_cmrs(&s, &cfg).replay(&TITANX), cmrs_walk(&s, &TITANX, &cfg));
        assert_eq!(
            record_rowsplit(&s, 16, &cfg).replay(&TITANX),
            rowsplit_walk(&s, 16, &TITANX, &cfg)
        );
    }

    #[test]
    fn cmrs_interleave_destroys_column_runs() {
        // dense-columns structure has long same-col runs: GCOO with reuse
        // skips most B loads, while CMRS's round-robin interleave breaks
        // the runs apart — its tex traffic must sit well above GCOO's.
        use crate::gen;
        use crate::rng::Rng;
        use crate::simgpu::structure::GcooStructure;
        use crate::sparse::Gcoo;
        let mut rng = Rng::new(11);
        let a = gen::dense_columns(256, 0.95, &mut rng);
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        let cfg = WalkConfig::default();
        let (gcoo, _) = gcoo_walk(&st, &TITANX, &cfg, true);
        let (cmrs, _) = cmrs_walk(&st, &TITANX, &cfg);
        assert!(
            cmrs.l1_tex > gcoo.l1_tex,
            "interleave should lose reuse: cmrs.tex={} gcoo.tex={}",
            cmrs.l1_tex,
            gcoo.l1_tex
        );
    }

    #[test]
    fn rowsplit_flops_exact_and_segments_bound_work() {
        let s = synth(512, 0.99);
        let (c, flops) = rowsplit_walk(&s, 16, &TITANX, &WalkConfig::default());
        assert_eq!(flops, 2 * s.nnz() * 512);
        assert!(c.total_mem_transactions() > 0);
        // Smaller capacity → more segments → more blocks, never a panic.
        let (c1, _) = rowsplit_walk(&s, 1, &TITANX, &WalkConfig::default());
        assert!(c1.total_mem_transactions() > 0);
    }

    #[test]
    fn reuse_reduces_tex_traffic() {
        // dense-columns structure has long same-col runs; with reuse the
        // texture transactions must drop markedly.
        use crate::gen;
        use crate::rng::Rng;
        use crate::sparse::Gcoo;
        use crate::simgpu::structure::GcooStructure;
        let mut rng = Rng::new(10);
        let a = gen::dense_columns(256, 0.95, &mut rng);
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        let cfg = WalkConfig::default();
        let (with, _) = gcoo_walk(&st, &TITANX, &cfg, true);
        let (without, _) = gcoo_walk(&st, &TITANX, &cfg, false);
        assert!(
            with.l1_tex * 2 < without.l1_tex,
            "reuse should at least halve tex transactions: {} vs {}",
            with.l1_tex,
            without.l1_tex
        );
    }

    #[test]
    fn reuse_no_help_on_diagonal() {
        use crate::ndarray::Mat;
        use crate::sparse::Gcoo;
        use crate::simgpu::structure::GcooStructure;
        let st = GcooStructure::new(&Gcoo::from_dense(&Mat::eye(256), 8));
        let cfg = WalkConfig::default();
        let (with, _) = gcoo_walk(&st, &TITANX, &cfg, true);
        let (without, _) = gcoo_walk(&st, &TITANX, &cfg, false);
        assert_eq!(with.l1_tex, without.l1_tex, "diagonal has no runs to reuse");
    }

    #[test]
    fn csr_l2_dominates_its_mix() {
        // Fig 14: n_l2 takes the great majority in cuSPARSE.
        let s = synth(1024, 0.995);
        let (c, _) = csr_walk(&s, &TITANX, &WalkConfig::default());
        assert!(c.l2 > 10 * c.shm.max(1), "l2={} shm={}", c.l2, c.shm);
        assert!(c.l1_tex == 0, "csr path must not use the tex path");
    }

    #[test]
    fn gcoo_mix_is_spread() {
        // Fig 14: GCOO splits across l2 / shm / tex.
        let s = synth(1024, 0.995);
        let (c, _) = gcoo_walk(&s, &TITANX, &WalkConfig::default(), true);
        assert!(c.shm > 0 && c.l1_tex > 0 && c.l2 > 0);
        // shared memory carries a significant share
        assert!(c.shm * 20 > c.l2, "shm={} l2={}", c.shm, c.l2);
    }

    #[test]
    fn gcoo_dram_under_csr_dram() {
        // The paper's headline mechanism: fewer slow-memory transactions.
        let s = synth(1024, 0.99);
        let cfg = WalkConfig::default();
        let (g, _) = gcoo_walk(&s, &TITANX, &cfg, true);
        let (c, _) = csr_walk(&s, &TITANX, &cfg);
        assert!(
            g.l2 < c.l2,
            "gcoo should move traffic off L2: gcoo.l2={} csr.l2={}",
            g.l2,
            c.l2
        );
    }

    #[test]
    fn gemm_flops_cubed() {
        let (_c, flops) = gemm_walk(256, &TITANX, &WalkConfig::default());
        assert_eq!(flops, 2 * 256u64.pow(3));
    }

    #[test]
    fn sampling_window_fits_grid() {
        // tiny grid: fewer blocks than sample — must simulate all without panic
        let s = synth(64, 0.9);
        let cfg = WalkConfig { sample_blocks: 10_000, ..Default::default() };
        let (c, _) = gcoo_walk(&s, &TITANX, &cfg, true);
        assert!(c.total_mem_transactions() > 0);
    }

    #[test]
    fn counters_scale_with_n() {
        // quadratic-ish growth in total transactions with n (Fig 14 upper).
        let cfg = WalkConfig::default();
        let (c1, _) = csr_walk(&synth(512, 0.995), &TITANX, &cfg);
        let (c2, _) = csr_walk(&synth(1024, 0.995), &TITANX, &cfg);
        let ratio = c2.l2 as f64 / c1.l2 as f64;
        assert!(ratio > 2.5, "l2 growth ratio {ratio} (expected ~4x for 2x n)");
    }
}
