//! Algorithm walkers: replay each kernel's warp-level memory trace.
//!
//! Each walker executes a *sampled contiguous window* of thread blocks (in
//! launch order, so cache locality between neighboring blocks is modeled)
//! through a [`MemorySystem`] and scales the counters to the full grid.
//! FLOP counts are exact (they are determined by nnz / n, not by the cache).
//!
//! Address map (byte addresses, disjoint regions):
//!   A arrays  @ 0x0000_0000_0000  (vals), +1<<40 (rows), +2<<40 (cols)
//!   B matrix  @ 3<<40,  C matrix @ 4<<40, row_ptr @ 5<<40

use super::device::{DeviceConfig, WARP};
use super::mem::{Counters, MemorySystem, Space};
use super::structure::SparseStructure;

/// Effective column-ILP of the cuSPARSE-era csrmm: lanes covering adjacent
/// C columns share memory sectors, partially re-coalescing its scattered
/// loads (see csr_walk docs).
const ILP_COLS: usize = 4;

const A_VALS: u64 = 0;
const A_ROWS: u64 = 1 << 40;
const A_COLS: u64 = 2 << 40;
const B_BASE: u64 = 3 << 40;
const C_BASE: u64 = 4 << 40;
const ROWPTR: u64 = 5 << 40;

/// Walker parameters.
#[derive(Clone, Copy, Debug)]
pub struct WalkConfig {
    /// Threads per block (the paper's b). Must be a multiple of 32.
    pub b: usize,
    /// How many thread blocks to simulate (contiguous window of the grid).
    pub sample_blocks: usize,
    /// Window start selection seed.
    pub seed: u64,
}

impl Default for WalkConfig {
    fn default() -> Self {
        WalkConfig { b: 128, sample_blocks: 64, seed: 0x51A5 }
    }
}

/// Pick a contiguous launch-order window [start, start+len) of the grid.
fn window(total_blocks: usize, cfg: &WalkConfig) -> (usize, usize) {
    let len = cfg.sample_blocks.min(total_blocks);
    let max_start = total_blocks - len;
    // Deterministic mid-grid start (avoids cold-start edge bias at block 0
    // while staying reproducible).
    let start = if max_start == 0 { 0 } else { (cfg.seed as usize) % max_start };
    (start, len)
}

/// GCOOSpDM (paper Algorithm 2). Grid: g bands × ⌈n/b⌉ column tiles,
/// launch order band-major (blockIdx.x = band). Per block:
///   stage the band's COO into shared memory in b-sized chunks (coalesced
///   global reads + shared stores), then scan entries: shared broadcast
///   reads, one texture-path B row load per *new* column (reuse skips
///   repeats when `reuse`), accumulate in registers, single C write.
pub fn gcoo_walk(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
    reuse: bool,
) -> (Counters, u64) {
    let n = s.n();
    let g = s.num_bands();
    let col_tiles = n.div_ceil(cfg.b);
    let total_blocks = g * col_tiles;
    let (start, len) = window(total_blocks, cfg);
    let warps = cfg.b / WARP;
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));

    for blk in start..start + len {
        // launch order: band index fastest (blockIdx.x), as in Algorithm 2.
        let gi = blk % g;
        let jb = blk / g;
        let sm = blk % dev.sms;
        let band = s.band(gi);
        let nnz_b = band.len();
        let col_base = (jb * cfg.b) as u64;

        // --- stage COO chunks into shared memory (lines 12-15) ---
        let chunks = nnz_b.div_ceil(cfg.b).max(1);
        for ch in 0..chunks {
            let chunk_len = cfg.b.min(nnz_b.saturating_sub(ch * cfg.b)).max(1);
            let cwarps = chunk_len.div_ceil(WARP);
            for w in 0..cwarps {
                let off = ((ch * cfg.b + w * WARP) * 4) as u64;
                let lanes = chunk_len.saturating_sub(w * WARP).min(WARP);
                for base in [A_VALS, A_ROWS, A_COLS] {
                    ms.warp_load_contiguous(Space::GlobalL2, base + off, lanes, sm);
                    // store to shared: conflict-free (consecutive words)
                    ms.warp_load_contiguous(Space::Shared, off, lanes, sm);
                }
            }
        }

        // --- scan entries (lines 20-36) ---
        let mut prev_col: Option<u32> = None;
        for k in 0..nnz_b {
            let col = band.cols[k];
            // every thread reads (val, row, col) from shared: broadcast
            for _ in 0..warps {
                ms.shared_broadcast(); // sVals[j]
                ms.shared_broadcast(); // sCols[j]
                ms.shared_broadcast(); // sRows[j]
            }
            let is_run = reuse && prev_col == Some(col);
            if !is_run {
                // B(col, col_base + t) for t in 0..b — texture path, coalesced
                for w in 0..warps {
                    let base = B_BASE + ((col as u64) * n as u64 + col_base + (w * WARP) as u64) * 4;
                    let lanes = n.saturating_sub(jb * cfg.b + w * WARP).min(WARP);
                    if lanes > 0 {
                        ms.warp_load_contiguous(Space::GlobalTex, base, lanes, sm);
                    }
                }
            }
            prev_col = Some(col);
        }

        // --- single C write (lines 38-39): p rows × b columns ---
        for r in 0..s.p() {
            let row = gi * s.p() + r;
            if row >= n {
                break;
            }
            for w in 0..warps {
                let base = C_BASE + ((row as u64) * n as u64 + col_base + (w * WARP) as u64) * 4;
                let lanes = n.saturating_sub(jb * cfg.b + w * WARP).min(WARP);
                if lanes > 0 {
                    ms.warp_load_contiguous(Space::GlobalL2, base, lanes, sm);
                }
            }
        }
    }

    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * s.nnz() * n as u64; // exact: every nonzero × every C column
    (ms.counters.scale(scale), flops)
}

/// cuSPARSE-like scalar-row csrmm (CUDA-8 era). One *thread* per row:
/// thread t of a warp owns row `base + t` and, for each C column j, walks
/// its nonzeros serially. The warp-level consequence — the behavior the
/// paper profiles as cuSPARSE's weakness — is that every load is
/// **scattered**: at step (j, k) the 32 lanes touch 32 different A entries
/// and 32 different B addresses `B(col_t, j)` (stride-n apart), so one
/// memory operation costs up to 32 sectors through the generic L2 path
/// (no shared staging, no texture path, no bv reuse).
///
/// Sampling: a contiguous window of row blocks × a strided sample of C
/// columns; counters scale to the full (blocks × n) space.
pub fn csr_walk(
    s: &dyn SparseStructure,
    dev: &DeviceConfig,
    cfg: &WalkConfig,
) -> (Counters, u64) {
    let n = s.n();
    let total_blocks = n.div_ceil(cfg.b);
    let (start, len) = window(total_blocks, cfg);
    let warps = cfg.b / WARP;
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));

    // Sample the kernel's outer loop over C columns with a stride.
    let j_samples = 16usize.min(n);
    let j_stride = (n / j_samples).max(1);

    for blk in start..start + len {
        let sm = blk % dev.sms;
        // The block's row structures (host-side bookkeeping, not traffic).
        let rows: Vec<Vec<u32>> = (0..cfg.b)
            .map(|t| {
                let r = blk * cfg.b + t;
                if r < n { s.row_cols(r) } else { Vec::new() }
            })
            .collect();
        // Per-row offsets into the A arrays (prefix sums of row lengths).
        let mut offs = vec![0u64; cfg.b];
        for t in 1..cfg.b {
            offs[t] = offs[t - 1] + rows[t - 1].len() as u64;
        }
        let mut addr_buf: Vec<u64> = Vec::with_capacity(WARP);
        for jj in 0..j_samples {
            let j = (jj * j_stride) as u64;
            for w in 0..warps {
                let lanes: Vec<usize> =
                    (0..WARP).filter(|&t| !rows[w * WARP + t].is_empty()).collect();
                if lanes.is_empty() {
                    continue;
                }
                if jj == 0 {
                    // row_ptr loads: scattered across lanes
                    addr_buf.clear();
                    addr_buf.extend(
                        lanes.iter().map(|&t| ROWPTR + 4 * (blk * cfg.b + w * WARP + t) as u64),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                }
                let max_k = lanes.iter().map(|&t| rows[w * WARP + t].len()).max().unwrap_or(0);
                for k in 0..max_k {
                    let act: Vec<usize> = lanes
                        .iter()
                        .copied()
                        .filter(|&t| k < rows[w * WARP + t].len())
                        .collect();
                    if act.is_empty() {
                        break;
                    }
                    // Partial coalescing: csrmm processes ILP_COLS C
                    // columns per thread, so ILP_COLS lanes' 4-byte loads
                    // share one 32-byte sector; modeled by issuing one
                    // representative lane per ILP_COLS. Calibrated so the
                    // simulated cuSPARSE/GCOO gap matches the paper's
                    // measured 1.5-2x average on uniform matrices.
                    let rep = act.iter().copied().step_by(ILP_COLS);
                    // A val + col: scattered gathers
                    addr_buf.clear();
                    addr_buf.extend(
                        rep.clone().map(|t| A_VALS + 4 * (offs[w * WARP + t] + k as u64)),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                    addr_buf.clear();
                    addr_buf.extend(
                        rep.clone().map(|t| A_COLS + 4 * (offs[w * WARP + t] + k as u64)),
                    );
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                    // B(col_t, j): stride-n scatter — the slow path.
                    addr_buf.clear();
                    addr_buf.extend(rep.map(|t| {
                        let col = rows[w * WARP + t][k] as u64;
                        B_BASE + (col * n as u64 + j) * 4
                    }));
                    ms.warp_access(Space::GlobalL2, &addr_buf, sm);
                }
                // C(r, j) write: scattered (stride n)
                addr_buf.clear();
                addr_buf.extend(
                    lanes
                        .iter()
                        .map(|&t| C_BASE + ((blk * cfg.b + w * WARP + t) as u64 * n as u64 + j) * 4),
                );
                ms.warp_access(Space::GlobalL2, &addr_buf, sm);
            }
        }
    }

    // Scale: sampled blocks → all blocks, sampled columns → all n columns.
    let scale = (total_blocks as f64 / len as f64) * (n as f64 / j_samples as f64);
    let flops = 2 * s.nnz() * n as u64;
    (ms.counters.scale(scale), flops)
}

/// Tiled dense GEMM (cuBLAS stand-in): 64×64 C tiles, k-loop staging 64×16
/// A/B tiles through shared memory. Compute-bound at large n, which yields
/// the constant-in-sparsity line of Figs 7–9.
pub fn gemm_walk(n: usize, dev: &DeviceConfig, cfg: &WalkConfig) -> (Counters, u64) {
    let tile = 64usize;
    let tk = 16usize;
    let tiles = n.div_ceil(tile);
    let total_blocks = tiles * tiles;
    let (start, len) = window(total_blocks, cfg);
    let mut ms = MemorySystem::new(dev, dev.sms.min(len.max(1)));
    let warps_per_tile_row = tile / WARP;

    for blk in start..start + len {
        let ti = blk % tiles;
        let tj = blk / tiles;
        let sm = blk % dev.sms;
        let ksteps = n.div_ceil(tk);
        for ks in 0..ksteps {
            // stage A (tile×tk) and B (tk×tile) via tex path + shared stores
            for r in 0..tile.min(n - ti * tile) {
                let base = B_BASE / 2 + (((ti * tile + r) * n + ks * tk) * 4) as u64; // A region
                ms.warp_load_contiguous(Space::GlobalTex, base, tk, sm);
                ms.warp_access(Space::Shared, &[(r * tk * 4) as u64], sm);
            }
            for r in 0..tk.min(n.saturating_sub(ks * tk)) {
                for w in 0..warps_per_tile_row {
                    let base =
                        B_BASE + (((ks * tk + r) * n + tj * tile + w * WARP) * 4) as u64;
                    ms.warp_load_contiguous(Space::GlobalTex, base, WARP, sm);
                    let addrs: Vec<u64> =
                        (0..WARP).map(|t| ((r * tile + w * WARP + t) * 4) as u64).collect();
                    ms.warp_access(Space::Shared, &addrs, sm);
                }
            }
            // inner products: each thread owns an RT×RT register tile
            // (register blocking à la cuBLAS/MAGMA), so a shared-memory
            // operand is reused RT times once loaded — shared traffic is
            // MACs / (WARP · RT) warp-transactions per operand.
            const RT: usize = 8;
            let inner_warp_ops = (tile * tile * tk) / (WARP * RT);
            for _ in 0..inner_warp_ops {
                ms.shared_broadcast(); // A operand
                ms.shared_broadcast(); // B operand
            }
        }
        // C tile write
        for r in 0..tile.min(n - ti * tile) {
            for w in 0..warps_per_tile_row {
                let base = C_BASE + (((ti * tile + r) * n + tj * tile + w * WARP) * 4) as u64;
                ms.warp_load_contiguous(Space::GlobalL2, base, WARP, sm);
            }
        }
    }

    let scale = total_blocks as f64 / len as f64;
    let flops = 2 * (n as u64).pow(3);
    (ms.counters.scale(scale), flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::TITANX;
    use crate::simgpu::structure::SyntheticUniform;

    fn synth(n: usize, s: f64) -> SyntheticUniform {
        SyntheticUniform::new(n, s, 8, 9)
    }

    #[test]
    fn gcoo_flops_exact() {
        let s = synth(512, 0.99);
        let (_c, flops) = gcoo_walk(&s, &TITANX, &WalkConfig::default(), true);
        assert_eq!(flops, 2 * s.nnz() * 512);
    }

    #[test]
    fn reuse_reduces_tex_traffic() {
        // dense-columns structure has long same-col runs; with reuse the
        // texture transactions must drop markedly.
        use crate::gen;
        use crate::rng::Rng;
        use crate::sparse::Gcoo;
        use crate::simgpu::structure::GcooStructure;
        let mut rng = Rng::new(10);
        let a = gen::dense_columns(256, 0.95, &mut rng);
        let st = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        let cfg = WalkConfig::default();
        let (with, _) = gcoo_walk(&st, &TITANX, &cfg, true);
        let (without, _) = gcoo_walk(&st, &TITANX, &cfg, false);
        assert!(
            with.l1_tex * 2 < without.l1_tex,
            "reuse should at least halve tex transactions: {} vs {}",
            with.l1_tex,
            without.l1_tex
        );
    }

    #[test]
    fn reuse_no_help_on_diagonal() {
        use crate::ndarray::Mat;
        use crate::sparse::Gcoo;
        use crate::simgpu::structure::GcooStructure;
        let st = GcooStructure::new(&Gcoo::from_dense(&Mat::eye(256), 8));
        let cfg = WalkConfig::default();
        let (with, _) = gcoo_walk(&st, &TITANX, &cfg, true);
        let (without, _) = gcoo_walk(&st, &TITANX, &cfg, false);
        assert_eq!(with.l1_tex, without.l1_tex, "diagonal has no runs to reuse");
    }

    #[test]
    fn csr_l2_dominates_its_mix() {
        // Fig 14: n_l2 takes the great majority in cuSPARSE.
        let s = synth(1024, 0.995);
        let (c, _) = csr_walk(&s, &TITANX, &WalkConfig::default());
        assert!(c.l2 > 10 * c.shm.max(1), "l2={} shm={}", c.l2, c.shm);
        assert!(c.l1_tex == 0, "csr path must not use the tex path");
    }

    #[test]
    fn gcoo_mix_is_spread() {
        // Fig 14: GCOO splits across l2 / shm / tex.
        let s = synth(1024, 0.995);
        let (c, _) = gcoo_walk(&s, &TITANX, &WalkConfig::default(), true);
        assert!(c.shm > 0 && c.l1_tex > 0 && c.l2 > 0);
        // shared memory carries a significant share
        assert!(c.shm * 20 > c.l2, "shm={} l2={}", c.shm, c.l2);
    }

    #[test]
    fn gcoo_dram_under_csr_dram() {
        // The paper's headline mechanism: fewer slow-memory transactions.
        let s = synth(1024, 0.99);
        let cfg = WalkConfig::default();
        let (g, _) = gcoo_walk(&s, &TITANX, &cfg, true);
        let (c, _) = csr_walk(&s, &TITANX, &cfg);
        assert!(
            g.l2 < c.l2,
            "gcoo should move traffic off L2: gcoo.l2={} csr.l2={}",
            g.l2,
            c.l2
        );
    }

    #[test]
    fn gemm_flops_cubed() {
        let (_c, flops) = gemm_walk(256, &TITANX, &WalkConfig::default());
        assert_eq!(flops, 2 * 256u64.pow(3));
    }

    #[test]
    fn sampling_window_fits_grid() {
        // tiny grid: fewer blocks than sample — must simulate all without panic
        let s = synth(64, 0.9);
        let cfg = WalkConfig { sample_blocks: 10_000, ..Default::default() };
        let (c, _) = gcoo_walk(&s, &TITANX, &cfg, true);
        assert!(c.total_mem_transactions() > 0);
    }

    #[test]
    fn counters_scale_with_n() {
        // quadratic-ish growth in total transactions with n (Fig 14 upper).
        let cfg = WalkConfig::default();
        let (c1, _) = csr_walk(&synth(512, 0.995), &TITANX, &cfg);
        let (c2, _) = csr_walk(&synth(1024, 0.995), &TITANX, &cfg);
        let ratio = c2.l2 as f64 / c1.l2 as f64;
        assert!(ratio > 2.5, "l2 growth ratio {ratio} (expected ~4x for 2x n)");
    }
}
