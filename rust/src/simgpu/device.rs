//! Device configurations — Table II of the paper, plus the microarchitectural
//! constants the memory model needs (L2 size, line/sector geometry, warp
//! width). L2 sizes and shared-memory bandwidth follow the public Maxwell /
//! Pascal specifications for the three cards.

/// Parameters of one simulated GPU.
#[derive(Clone, Copy, Debug)]
pub struct DeviceConfig {
    pub name: &'static str,
    pub sms: usize,
    pub cores_per_sm: usize,
    /// Peak single-precision TFLOPS (Table II).
    pub peak_tflops: f64,
    /// DRAM bandwidth in GB/s (Table II).
    pub mem_bw_gbps: f64,
    /// L2 cache size in bytes.
    pub l2_bytes: usize,
    /// Per-SM L1/texture cache size in bytes.
    pub l1_bytes: usize,
    /// L2 aggregate bandwidth relative to DRAM (Maxwell/Pascal ≈ 2–3×).
    pub l2_bw_ratio: f64,
    /// Shared-memory bytes/cycle per SM (128B = 32 banks × 4B).
    pub shm_bytes_per_cycle: f64,
    /// Kernel launch + tail latency in seconds (measured µs-scale on all
    /// three cards; gives cuBLAS its small-n advantage, §IV-B).
    pub launch_overhead_s: f64,
}

impl DeviceConfig {
    /// Core clock implied by Table II: peak = sms·cores·2·clock.
    pub fn clock_ghz(&self) -> f64 {
        self.peak_tflops * 1e12 / (self.sms as f64 * self.cores_per_sm as f64 * 2.0) / 1e9
    }

    /// Aggregate shared-memory bandwidth in bytes/s.
    pub fn shm_bw(&self) -> f64 {
        self.sms as f64 * self.shm_bytes_per_cycle * self.clock_ghz() * 1e9
    }

    /// L2 bandwidth in bytes/s.
    pub fn l2_bw(&self) -> f64 {
        self.mem_bw_gbps * 1e9 * self.l2_bw_ratio
    }

    pub fn dram_bw(&self) -> f64 {
        self.mem_bw_gbps * 1e9
    }

    pub fn peak_flops(&self) -> f64 {
        self.peak_tflops * 1e12
    }
}

/// GTX 980 (Maxwell GM204): 16 SMs × 128 cores, 4.981 TFLOPS, 224 GB/s.
pub const GTX980: DeviceConfig = DeviceConfig {
    name: "GTX980",
    sms: 16,
    cores_per_sm: 128,
    peak_tflops: 4.981,
    mem_bw_gbps: 224.0,
    l2_bytes: 2 * 1024 * 1024,
    l1_bytes: 24 * 1024,
    l2_bw_ratio: 2.5,
    shm_bytes_per_cycle: 128.0,
    launch_overhead_s: 5e-6,
};

/// Titan X Pascal (GP102): 28 SMs × 128 cores, 10.97 TFLOPS, 433 GB/s.
pub const TITANX: DeviceConfig = DeviceConfig {
    name: "TitanX",
    sms: 28,
    cores_per_sm: 128,
    peak_tflops: 10.97,
    mem_bw_gbps: 433.0,
    l2_bytes: 3 * 1024 * 1024,
    l1_bytes: 48 * 1024,
    l2_bw_ratio: 2.5,
    shm_bytes_per_cycle: 128.0,
    launch_overhead_s: 5e-6,
};

/// Tesla P100 (GP100): 56 SMs × 64 cores, 9.5 TFLOPS, 732 GB/s HBM2.
pub const P100: DeviceConfig = DeviceConfig {
    name: "P100",
    sms: 56,
    cores_per_sm: 64,
    peak_tflops: 9.5,
    mem_bw_gbps: 732.0,
    l2_bytes: 4 * 1024 * 1024,
    l1_bytes: 24 * 1024,
    l2_bw_ratio: 2.5,
    shm_bytes_per_cycle: 128.0,
    launch_overhead_s: 5e-6,
};

pub const ALL_DEVICES: [&DeviceConfig; 3] = [&GTX980, &TITANX, &P100];

/// Warp width (threads issuing one coalesced access).
pub const WARP: usize = 32;
/// DRAM/L2 sector granularity in bytes (the unit nvprof transactions count).
pub const SECTOR: usize = 32;
/// L2/L1 cache line in bytes.
pub const LINE: usize = 128;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        assert_eq!(GTX980.sms * GTX980.cores_per_sm, 2048);
        assert_eq!(TITANX.sms * TITANX.cores_per_sm, 3584);
        assert_eq!(P100.sms * P100.cores_per_sm, 3584);
        assert!((GTX980.peak_tflops - 4.981).abs() < 1e-9);
        assert!((TITANX.mem_bw_gbps - 433.0).abs() < 1e-9);
        assert!((P100.mem_bw_gbps - 732.0).abs() < 1e-9);
    }

    #[test]
    fn implied_clocks_plausible() {
        // All three cards clock between 1.0 and 1.5 GHz.
        for dev in ALL_DEVICES {
            let ghz = dev.clock_ghz();
            assert!((1.0..1.6).contains(&ghz), "{}: {ghz}", dev.name);
        }
    }

    #[test]
    fn bandwidth_orderings() {
        // P100 HBM2 out-bandwidths both GDDR5 cards; paper attributes its
        // better cuSPARSE showing to exactly this.
        assert!(P100.dram_bw() > TITANX.dram_bw());
        assert!(TITANX.dram_bw() > GTX980.dram_bw());
        for dev in ALL_DEVICES {
            assert!(dev.l2_bw() > dev.dram_bw());
            assert!(dev.shm_bw() > dev.l2_bw(), "{}", dev.name);
        }
    }
}
