//! Sparse-structure providers for the walkers.
//!
//! Walkers only need *where* the nonzeros are, not their values. Two
//! providers: [`GcooStructure`] adapts a real [`Gcoo`] matrix; and
//! [`SyntheticUniform`] generates uniform-random structure lazily per band /
//! row, which lets the figure sweeps reach the paper's n = 14000 without
//! ever materializing an n² dense matrix.

use crate::rng::Rng;
use crate::sparse::{Csr, Gcoo};

/// One band's entries, (col, row)-sorted, rows band-local.
#[derive(Clone, Debug, Default)]
pub struct BandEntries {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
}

impl BandEntries {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Structural view of a square sparse matrix, band- and row-addressable.
pub trait SparseStructure {
    /// Square dimension.
    fn n(&self) -> usize;
    /// Band height (GCOO p).
    fn p(&self) -> usize;
    fn num_bands(&self) -> usize {
        self.n().div_ceil(self.p())
    }
    /// Band `gi`'s entries, (col, row)-sorted.
    fn band(&self, gi: usize) -> BandEntries;
    /// Column indices of row `i` (sorted).
    fn row_cols(&self, i: usize) -> Vec<u32>;
    /// Total nonzeros.
    fn nnz(&self) -> u64;
}

/// Adapter over a real GCOO matrix (plus a CSR view for row access).
pub struct GcooStructure {
    bands: Vec<BandEntries>,
    rows: Vec<Vec<u32>>,
    n: usize,
    p: usize,
    nnz: u64,
}

impl GcooStructure {
    pub fn new(gcoo: &Gcoo) -> Self {
        let g = gcoo.num_groups();
        let mut bands = Vec::with_capacity(g);
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); gcoo.n_rows];
        for gi in 0..g {
            let mut be = BandEntries::default();
            for (r, c, _v) in gcoo.group(gi) {
                be.rows.push(r);
                be.cols.push(c);
                rows[gi * gcoo.p + r as usize].push(c);
            }
            bands.push(be);
        }
        for r in rows.iter_mut() {
            r.sort_unstable();
        }
        GcooStructure { bands, rows, n: gcoo.n_cols, p: gcoo.p, nnz: gcoo.nnz() as u64 }
    }

    pub fn from_csr(csr: &Csr, p: usize) -> Self {
        Self::new(&Gcoo::from_csr(csr, p))
    }
}

impl SparseStructure for GcooStructure {
    fn n(&self) -> usize {
        self.n
    }
    fn p(&self) -> usize {
        self.p
    }
    fn band(&self, gi: usize) -> BandEntries {
        self.bands[gi].clone()
    }
    fn row_cols(&self, i: usize) -> Vec<u32> {
        self.rows[i].clone()
    }
    fn nnz(&self) -> u64 {
        self.nnz
    }
}

/// Lazily-generated uniform structure: entry (i, j) is nonzero with
/// probability `density`, realized deterministically per (seed, band).
/// Band and row views are *consistent in distribution* (not element-wise
/// identical — the walkers never cross-reference them).
pub struct SyntheticUniform {
    pub n: usize,
    pub p: usize,
    pub density: f64,
    pub seed: u64,
}

impl SyntheticUniform {
    pub fn new(n: usize, sparsity: f64, p: usize, seed: u64) -> Self {
        SyntheticUniform { n, p, density: 1.0 - sparsity, seed }
    }

    /// Deterministic draw of k ≈ Binomial(cells, density) via normal approx.
    fn draw_count(&self, cells: usize, rng: &mut Rng) -> usize {
        let mean = cells as f64 * self.density;
        let sd = (cells as f64 * self.density * (1.0 - self.density)).sqrt();
        let x = mean + sd * rng.normal();
        x.round().clamp(0.0, cells as f64) as usize
    }
}

impl SparseStructure for SyntheticUniform {
    fn n(&self) -> usize {
        self.n
    }

    fn p(&self) -> usize {
        self.p
    }

    fn band(&self, gi: usize) -> BandEntries {
        let band_rows = ((gi + 1) * self.p).min(self.n) - gi * self.p;
        let cells = band_rows * self.n;
        let mut rng = Rng::new(self.seed ^ (gi as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let k = self.draw_count(cells, &mut rng);
        // Sample k distinct cells in (col, row) order: cell id = col*band_rows+row.
        let ids = rng.sample_indices(cells, k);
        let mut be = BandEntries { rows: Vec::with_capacity(k), cols: Vec::with_capacity(k) };
        for id in ids {
            be.cols.push((id / band_rows) as u32);
            be.rows.push((id % band_rows) as u32);
        }
        be
    }

    fn row_cols(&self, i: usize) -> Vec<u32> {
        let mut rng = Rng::new(self.seed ^ 0xABCD ^ (i as u64).wrapping_mul(0xD129_0E2B_53F1_76C5));
        let k = self.draw_count(self.n, &mut rng);
        rng.sample_indices(self.n, k).into_iter().map(|x| x as u32).collect()
    }

    fn nnz(&self) -> u64 {
        (self.n as f64 * self.n as f64 * self.density).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::ndarray::Mat;

    #[test]
    fn gcoo_structure_matches_matrix() {
        let mut rng = Rng::new(1);
        let a = gen::uniform(64, 0.9, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let s = GcooStructure::new(&gcoo);
        assert_eq!(s.n(), 64);
        assert_eq!(s.nnz(), a.nnz() as u64);
        let total: usize = (0..s.num_bands()).map(|gi| s.band(gi).len()).sum();
        assert_eq!(total as u64, s.nnz());
        let row_total: usize = (0..64).map(|i| s.row_cols(i).len()).sum();
        assert_eq!(row_total as u64, s.nnz());
    }

    #[test]
    fn gcoo_structure_band_sorted() {
        let mut rng = Rng::new(2);
        let a = gen::uniform(32, 0.8, &mut rng);
        let s = GcooStructure::new(&Gcoo::from_dense(&a, 8));
        for gi in 0..s.num_bands() {
            let be = s.band(gi);
            for k in 1..be.len() {
                assert!(
                    (be.cols[k - 1], be.rows[k - 1]) < (be.cols[k], be.rows[k]),
                    "band {gi} unsorted at {k}"
                );
            }
        }
    }

    #[test]
    fn diagonal_band_has_no_col_runs() {
        let s = GcooStructure::new(&Gcoo::from_dense(&Mat::eye(32), 8));
        for gi in 0..4 {
            let be = s.band(gi);
            for k in 1..be.len() {
                assert_ne!(be.cols[k - 1], be.cols[k]);
            }
        }
    }

    #[test]
    fn synthetic_counts_near_expectation() {
        let s = SyntheticUniform::new(2048, 0.99, 8, 7);
        let total: usize = (0..s.num_bands()).map(|gi| s.band(gi).len()).sum();
        let expect = 2048.0 * 2048.0 * 0.01;
        let rel = (total as f64 - expect).abs() / expect;
        assert!(rel < 0.1, "total {total} vs expected {expect}");
    }

    #[test]
    fn synthetic_band_sorted_and_in_range() {
        let s = SyntheticUniform::new(256, 0.95, 8, 3);
        let be = s.band(5);
        assert!(!be.is_empty());
        for k in 0..be.len() {
            assert!(be.rows[k] < 8);
            assert!(be.cols[k] < 256);
            if k > 0 {
                assert!((be.cols[k - 1], be.rows[k - 1]) < (be.cols[k], be.rows[k]));
            }
        }
    }

    #[test]
    fn synthetic_deterministic() {
        let s1 = SyntheticUniform::new(128, 0.9, 8, 42);
        let s2 = SyntheticUniform::new(128, 0.9, 8, 42);
        assert_eq!(s1.band(3).cols, s2.band(3).cols);
        assert_eq!(s1.row_cols(17), s2.row_cols(17));
    }

    #[test]
    fn synthetic_last_partial_band() {
        let s = SyntheticUniform::new(30, 0.5, 8, 1);
        assert_eq!(s.num_bands(), 4);
        let be = s.band(3); // 6 rows only
        assert!(be.rows.iter().all(|&r| r < 6));
    }
}
