//! The memory system: warp coalescer → per-SM L1/tex → shared L2 → DRAM,
//! plus shared-memory transaction accounting.
//!
//! Counters correspond 1:1 to the nvprof metrics the paper profiles in
//! Fig 14: `dram` (dram_read/write_transactions), `l2` (l2_read/write_
//! transactions), `shm` (shared_load/store_transactions) and `l1_tex`
//! (tex_cache_transactions / unified L1 on Maxwell+Pascal).

use super::cache::Cache;
use super::device::{DeviceConfig, SECTOR, WARP};

/// Which path a global access takes. cuSPARSE's csrmm-era loads went
/// through L2 (generic global path, L1 bypassed for global loads on
/// Maxwell/Pascal); GCOOSpDM's B gathers use the read-only/texture path,
/// which is why the paper sees `tex_l1_trans` only for GCOOSpDM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Space {
    /// Global memory via L2 only (generic load/store path).
    GlobalL2,
    /// Global memory via the per-SM texture/read-only L1, then L2.
    GlobalTex,
    /// Shared memory (on-SM scratchpad).
    Shared,
}

/// Transaction counters (the Fig-14 y-axis).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    pub dram: u64,
    pub l2: u64,
    pub shm: u64,
    pub l1_tex: u64,
}

impl Counters {
    pub fn total_mem_transactions(&self) -> u64 {
        self.dram + self.l2 + self.shm + self.l1_tex
    }

    pub fn scale(&self, factor: f64) -> Counters {
        Counters {
            dram: (self.dram as f64 * factor).round() as u64,
            l2: (self.l2 as f64 * factor).round() as u64,
            shm: (self.shm as f64 * factor).round() as u64,
            l1_tex: (self.l1_tex as f64 * factor).round() as u64,
        }
    }

    /// Fractions [dram, l2, shm, l1_tex] of all memory transactions — the
    /// Fig-14 transaction-class mix. Sums to 1.0 whenever any transaction
    /// was counted ([0;4] for an empty run).
    pub fn shares(&self) -> [f64; 4] {
        let total = self.total_mem_transactions();
        if total == 0 {
            return [0.0; 4];
        }
        let t = total as f64;
        [
            self.dram as f64 / t,
            self.l2 as f64 / t,
            self.shm as f64 / t,
            self.l1_tex as f64 / t,
        ]
    }
}

/// Memory system of one simulated device.
pub struct MemorySystem {
    l2: Cache,
    /// One L1/tex cache per SM that the sampled thread blocks run on.
    l1s: Vec<Cache>,
    pub counters: Counters,
    l1_bytes: usize,
}

impl MemorySystem {
    pub fn new(dev: &DeviceConfig, sampled_sms: usize) -> Self {
        MemorySystem {
            l2: Cache::new(dev.l2_bytes, 16),
            l1s: (0..sampled_sms.max(1)).map(|_| Cache::new(dev.l1_bytes, 4)).collect(),
            counters: Counters::default(),
            l1_bytes: dev.l1_bytes,
        }
    }

    /// Issue one warp-wide access: `addrs` are the per-thread byte
    /// addresses (up to WARP of them), `sm` the SM the block runs on.
    /// The coalescer collapses them to unique sectors, then each sector
    /// traverses the hierarchy.
    pub fn warp_access(&mut self, space: Space, addrs: &[u64], sm: usize) {
        debug_assert!(addrs.len() <= WARP);
        match space {
            Space::Shared => {
                // Bank-conflict model: broadcast (all same address) = 1
                // transaction; otherwise one transaction per distinct bank
                // conflict group. With distinct banks it is also 1; we count
                // conflict groups = max #addresses mapping to one bank.
                let mut bank_counts = [0u8; 32];
                let mut distinct = Vec::with_capacity(addrs.len());
                for &a in addrs {
                    if !distinct.contains(&a) {
                        distinct.push(a);
                    }
                }
                for &a in &distinct {
                    bank_counts[((a / 4) % 32) as usize] += 1;
                }
                let conflict_groups = bank_counts.iter().copied().max().unwrap_or(1).max(1);
                self.counters.shm += conflict_groups as u64;
            }
            Space::GlobalL2 => {
                for sector in coalesce(addrs) {
                    self.counters.l2 += 1;
                    if !self.l2.access(sector) {
                        self.counters.dram += 1;
                    }
                }
            }
            Space::GlobalTex => {
                let l1_idx = sm % self.l1s.len();
                let l1 = &mut self.l1s[l1_idx];
                for sector in coalesce(addrs) {
                    self.counters.l1_tex += 1;
                    if !l1.access(sector) {
                        self.counters.l2 += 1;
                        if !self.l2.access(sector) {
                            self.counters.dram += 1;
                        }
                    }
                }
            }
        }
    }

    /// Contiguous warp load: `threads` consecutive 4-byte words from `base`.
    /// Fast path (perf: no per-thread address vector / sort): a contiguous
    /// span covers the sector range [base/S, (base+4t-1)/S] directly.
    pub fn warp_load_contiguous(&mut self, space: Space, base: u64, threads: usize, sm: usize) {
        let threads = threads.min(WARP);
        if threads == 0 {
            return;
        }
        match space {
            Space::Shared => {
                // consecutive words spread over banks: conflict-free
                self.counters.shm += 1;
            }
            Space::GlobalL2 => {
                let first = base / SECTOR as u64;
                let last = (base + 4 * threads as u64 - 1) / SECTOR as u64;
                for s in first..=last {
                    self.counters.l2 += 1;
                    if !self.l2.access(s * SECTOR as u64) {
                        self.counters.dram += 1;
                    }
                }
            }
            Space::GlobalTex => {
                let l1_idx = sm % self.l1s.len();
                let first = base / SECTOR as u64;
                let last = (base + 4 * threads as u64 - 1) / SECTOR as u64;
                for s in first..=last {
                    let addr = s * SECTOR as u64;
                    self.counters.l1_tex += 1;
                    if !self.l1s[l1_idx].access(addr) {
                        self.counters.l2 += 1;
                        if !self.l2.access(addr) {
                            self.counters.dram += 1;
                        }
                    }
                }
            }
        }
    }

    /// Shared-memory broadcast (all lanes read one address): exactly one
    /// transaction, no bank conflicts (perf fast path for the GCOO scan).
    #[inline]
    pub fn shared_broadcast(&mut self) {
        self.counters.shm += 1;
    }

    /// `count` broadcasts at once — how replayed traces apply a coalesced
    /// `Broadcasts` event (semantically `count` × [`Self::shared_broadcast`]).
    #[inline]
    pub fn shared_broadcasts(&mut self, count: u64) {
        self.counters.shm += count;
    }

    /// Reset only the counters (keep cache state warm).
    pub fn reset_counters(&mut self) {
        self.counters = Counters::default();
    }

    /// For tests: L1 capacity actually configured.
    pub fn l1_capacity(&self) -> usize {
        self.l1_bytes
    }
}

/// Collapse per-thread addresses to unique sector addresses.
fn coalesce(addrs: &[u64]) -> Vec<u64> {
    let mut sectors: Vec<u64> = addrs.iter().map(|a| a / SECTOR as u64 * SECTOR as u64).collect();
    sectors.sort_unstable();
    sectors.dedup();
    sectors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::TITANX;

    #[test]
    fn coalesced_warp_is_four_sectors() {
        // 32 threads × 4B consecutive = 128B = 4 sectors of 32B.
        let mut ms = MemorySystem::new(&TITANX, 1);
        ms.warp_load_contiguous(Space::GlobalL2, 0, 32, 0);
        assert_eq!(ms.counters.l2, 4);
        assert_eq!(ms.counters.dram, 4); // all cold
    }

    #[test]
    fn scattered_warp_is_32_sectors() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        let addrs: Vec<u64> = (0..32u64).map(|t| t * 4096).collect();
        ms.warp_access(Space::GlobalL2, &addrs, 0);
        assert_eq!(ms.counters.l2, 32);
    }

    #[test]
    fn l2_hit_suppresses_dram() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        ms.warp_load_contiguous(Space::GlobalL2, 0, 32, 0);
        let dram_before = ms.counters.dram;
        ms.warp_load_contiguous(Space::GlobalL2, 0, 32, 0);
        assert_eq!(ms.counters.dram, dram_before, "second pass must hit L2");
        assert_eq!(ms.counters.l2, 8);
    }

    #[test]
    fn tex_path_counts_l1_and_filters_l2() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        ms.warp_load_contiguous(Space::GlobalTex, 0, 32, 0);
        assert_eq!(ms.counters.l1_tex, 4);
        assert_eq!(ms.counters.l2, 4);
        ms.warp_load_contiguous(Space::GlobalTex, 0, 32, 0);
        assert_eq!(ms.counters.l1_tex, 8);
        assert_eq!(ms.counters.l2, 4, "L1 hit must not reach L2");
    }

    #[test]
    fn shared_broadcast_is_one_transaction() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        let addrs = vec![0x100u64; 32];
        ms.warp_access(Space::Shared, &addrs, 0);
        assert_eq!(ms.counters.shm, 1);
    }

    #[test]
    fn shared_conflict_free_is_one_transaction() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        let addrs: Vec<u64> = (0..32u64).map(|t| t * 4).collect(); // distinct banks
        ms.warp_access(Space::Shared, &addrs, 0);
        assert_eq!(ms.counters.shm, 1);
    }

    #[test]
    fn shared_bank_conflicts_serialize() {
        let mut ms = MemorySystem::new(&TITANX, 1);
        // stride 8B = 2 words: banks 0,2,4,…,30 each hit twice → 2-way conflict
        let addrs: Vec<u64> = (0..32u64).map(|t| t * 8).collect();
        ms.warp_access(Space::Shared, &addrs, 0);
        assert_eq!(ms.counters.shm, 2);
        // stride 128B = 32 words: all 32 threads on bank 0 → fully serialized
        let worst: Vec<u64> = (0..32u64).map(|t| t * 128).collect();
        ms.warp_access(Space::Shared, &worst, 0);
        assert_eq!(ms.counters.shm, 2 + 32);
    }

    #[test]
    fn shares_sum_to_one() {
        let c = Counters { dram: 1, l2: 2, shm: 3, l1_tex: 4 };
        let s = c.shares();
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(Counters::default().shares(), [0.0; 4]);
    }

    #[test]
    fn bulk_broadcasts_match_repeated_single() {
        let mut a = MemorySystem::new(&TITANX, 1);
        let mut b = MemorySystem::new(&TITANX, 1);
        for _ in 0..7 {
            a.shared_broadcast();
        }
        b.shared_broadcasts(7);
        assert_eq!(a.counters, b.counters);
    }

    #[test]
    fn counters_scale() {
        let c = Counters { dram: 10, l2: 20, shm: 30, l1_tex: 40 };
        let s = c.scale(2.5);
        assert_eq!(s, Counters { dram: 25, l2: 50, shm: 75, l1_tex: 100 });
    }

    #[test]
    fn per_sm_l1s_are_independent() {
        let mut ms = MemorySystem::new(&TITANX, 2);
        ms.warp_load_contiguous(Space::GlobalTex, 0, 32, 0);
        let l2_after_first = ms.counters.l2;
        // Same data from a different SM: L1 cold there, but L2 is warm.
        ms.warp_load_contiguous(Space::GlobalTex, 0, 32, 1);
        assert_eq!(ms.counters.l2, l2_after_first + 4);
        assert_eq!(ms.counters.dram, 4, "L2 absorbed the second SM's miss");
    }
}
