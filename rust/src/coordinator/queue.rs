//! Bounded MPMC job queue with blocking backpressure and affinity-keyed
//! batch dequeue.
//!
//! `push` blocks when the queue is full (producers feel backpressure instead
//! of OOMing the coordinator); `pop_batch` removes up to `max` jobs that the
//! caller's affinity predicate groups with the head job. The coordinator
//! keys the predicate on the A operand (`pool::batch_affine`: handle
//! equality for registered operands, the content signature otherwise), so
//! a dequeued batch provably shares one A operand and the worker executes
//! it **fused**: at most one A conversion (none when the operand is
//! registered — the store's cached slabs serve the whole batch), one wide
//! kernel over the stacked Bs, one warm compiled executable (see
//! `pool.rs` and DESIGN.md §Batching).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    deque: VecDeque<T>,
    closed: bool,
}

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.deque.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.deque.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push; Err(item) when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.deque.len() >= self.cap {
            return Err(item);
        }
        g.deque.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking single pop; None when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.deque.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop the head plus up to `max - 1` additional jobs for which
    /// `affine(head, candidate)` holds (scanning the whole queue, preserving
    /// relative order of the rest). None when closed and drained.
    pub fn pop_batch(&self, max: usize, affine: impl Fn(&T, &T) -> bool) -> Option<Vec<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.deque.is_empty() {
                let head = g.deque.pop_front().unwrap();
                let mut batch = vec![head];
                let mut i = 0;
                while i < g.deque.len() && batch.len() < max {
                    if affine(&batch[0], &g.deque[i]) {
                        let item = g.deque.remove(i).unwrap();
                        batch.push(item);
                    } else {
                        i += 1;
                    }
                }
                self.not_full.notify_all();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            assert!(q.push(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1);
        q.close();
        assert!(!q.push(2), "push after close fails");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0);
        let q2 = Arc::clone(&q);
        let handle = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(0));
        assert!(handle.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_batch_groups_affine_jobs() {
        let q = BoundedQueue::new(16);
        // (shape, id)
        for item in [(256, 0), (512, 1), (256, 2), (256, 3), (512, 4)] {
            q.push(item);
        }
        let batch = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(batch, vec![(256, 0), (256, 2), (256, 3)]);
        let rest = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(rest, vec![(512, 1), (512, 4)]);
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..6 {
            q.push((1, i));
        }
        let batch = q.pop_batch(4, |_, _| true).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 1000;
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(p * 1000 + i);
                }
            }));
        }
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            let c = Arc::clone(&consumed);
            consumers.push(std::thread::spawn(move || {
                while q.pop().is_some() {
                    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(std::sync::atomic::Ordering::Relaxed), total);
    }
}
