//! Bounded MPMC job queue with blocking backpressure, affinity-keyed
//! batch dequeue, and optional weighted-fair tenant lanes.
//!
//! `push` blocks when the queue is full (producers feel backpressure instead
//! of OOMing the coordinator); `pop_batch` removes up to `max` jobs that the
//! caller's affinity predicate groups with the head job. The coordinator
//! keys the predicate on the A operand (`pool::batch_affine`: handle
//! equality for registered operands, the content signature otherwise), so
//! a dequeued batch provably shares one A operand and the worker executes
//! it **fused**: at most one A conversion (none when the operand is
//! registered — the store's cached slabs serve the whole batch), one wide
//! kernel over the stacked Bs, one warm compiled executable (see
//! `pool.rs` and DESIGN.md §Batching).
//!
//! `pop_batch_windowed` extends the instant grouping with a **time-window
//! admission policy**: a partial batch is held open for a bounded window
//! (measured on an injected [`Clock`], so tests script the exact
//! fuse-vs-timeout decision) and late-arriving affine singles fuse into it.
//! Window ≤ 0 delegates to `pop_batch` with **zero clock reads** — today's
//! behavior bit-for-bit. Admission timing changes batching choices, never
//! results (DESIGN.md §Wire).
//!
//! **Lanes** ([`BoundedQueue::with_lanes`]) add deficit-round-robin
//! scheduling across per-tenant sub-queues: each lane carries a signed
//! deficit topped up by its quantum (= tenant weight) at every scan visit,
//! a lane is served only when its deficit is positive, and a served batch
//! is charged item-per-item (the deficit may go negative, which makes the
//! lane skip turns until it recovers — surplus-style DRR, so full-width
//! fusion and long-run weighted fairness coexist). Affine collection never
//! crosses a lane: fusion happens only within a tenant. A queue built with
//! [`BoundedQueue::new`] has no lanes and behaves exactly as before —
//! single deque, FIFO heads, unchanged clock accounting.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};

use super::tenant::DEFAULT_TENANT;
use super::tuner::Clock;

/// How a windowed batch left the queue (surfaced in `Metrics`/`/stats`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowOutcome {
    /// Window disabled (≤ 0): instant `pop_batch` semantics.
    Disabled,
    /// The batch reached `max` width — inside the window or instantly.
    Filled,
    /// The window elapsed (or the queue closed) with a partial batch.
    TimedOut,
}

/// Move every job affine to `batch[0]` from the deque into `batch` (up to
/// `max` total), scanning the whole deque and preserving the relative
/// order of the rest. Shared by `pop_batch` and `pop_batch_windowed` so
/// the two admission policies provably group by the same predicate.
fn collect_affine<T>(
    deque: &mut VecDeque<T>,
    batch: &mut Vec<T>,
    max: usize,
    affine: &impl Fn(&T, &T) -> bool,
) {
    let mut i = 0;
    while i < deque.len() && batch.len() < max {
        if affine(&batch[0], &deque[i]) {
            let item = deque.remove(i).unwrap();
            batch.push(item);
        } else {
            i += 1;
        }
    }
}

struct Lane<T> {
    items: VecDeque<T>,
    /// Signed DRR deficit: topped up by `quantum` at each scan visit,
    /// charged one per served item. Bounded below by `-(batch max)`.
    deficit: i64,
    quantum: i64,
}

struct Inner<T> {
    /// Laneless (pre-tenancy) storage; unused when lanes exist.
    deque: VecDeque<T>,
    /// Per-tenant sub-queues; empty ⇒ laneless mode.
    lanes: Vec<Lane<T>>,
    /// DRR round-robin cursor: index the next scan starts from.
    cursor: usize,
    closed: bool,
}

impl<T> Inner<T> {
    fn total(&self) -> usize {
        self.deque.len() + self.lanes.iter().map(|l| l.items.len()).sum::<usize>()
    }

    /// Deficit-round-robin lane election. Scans from the cursor; every
    /// non-empty lane visited is topped up by its quantum, the first one
    /// whose deficit turns positive wins, and empty lanes forfeit their
    /// deficit (classic DRR reset — an idle tenant cannot hoard credit).
    /// Terminates because each full rotation raises every backlogged
    /// lane's deficit by its quantum ≥ 1. Call only when `total() > 0`.
    fn drr_pick(&mut self) -> usize {
        let n = self.lanes.len();
        debug_assert!(n > 0);
        loop {
            let mut any_backlogged = false;
            for step in 0..n {
                let i = (self.cursor + step) % n;
                if self.lanes[i].items.is_empty() {
                    self.lanes[i].deficit = 0;
                    continue;
                }
                any_backlogged = true;
                self.lanes[i].deficit += self.lanes[i].quantum;
                if self.lanes[i].deficit > 0 {
                    self.cursor = (i + 1) % n;
                    return i;
                }
            }
            if !any_backlogged {
                // Defensive: callers guarantee a backlogged lane exists.
                return 0;
            }
        }
    }

    /// Serve one batch from the elected lane: FIFO head plus affine
    /// followers from the *same lane only*, charged against its deficit.
    fn drr_serve(&mut self, max: usize, affine: &impl Fn(&T, &T) -> bool) -> (usize, Vec<T>) {
        let li = self.drr_pick();
        let head = self.lanes[li].items.pop_front().unwrap();
        let mut batch = vec![head];
        collect_affine(&mut self.lanes[li].items, &mut batch, max, affine);
        self.lanes[li].deficit -= batch.len() as i64;
        (li, batch)
    }
}

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    /// Lane name → index; empty in laneless mode. Fixed at construction.
    names: HashMap<String, usize>,
    default_lane: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                deque: VecDeque::new(),
                lanes: Vec::new(),
                cursor: 0,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            names: HashMap::new(),
            default_lane: 0,
        }
    }

    /// Laned queue: one weighted sub-queue per `(name, weight)` pair, a
    /// `default` lane synthesized (weight 1) when absent so unknown lane
    /// keys always land somewhere. An empty `lanes` slice degenerates to
    /// [`BoundedQueue::new`]. The capacity bounds the *total* across all
    /// lanes — backpressure semantics are unchanged.
    pub fn with_lanes(cap: usize, lanes: &[(String, u32)]) -> Self {
        assert!(cap > 0);
        if lanes.is_empty() {
            return BoundedQueue::new(cap);
        }
        let mut names: HashMap<String, usize> = HashMap::new();
        let mut lane_vec: Vec<Lane<T>> = Vec::new();
        for (name, w) in lanes {
            if names.contains_key(name) {
                continue;
            }
            names.insert(name.clone(), lane_vec.len());
            lane_vec.push(Lane {
                items: VecDeque::new(),
                deficit: 0,
                quantum: (*w).max(1) as i64,
            });
        }
        if !names.contains_key(DEFAULT_TENANT) {
            names.insert(DEFAULT_TENANT.to_string(), lane_vec.len());
            lane_vec.push(Lane { items: VecDeque::new(), deficit: 0, quantum: 1 });
        }
        let default_lane = names[DEFAULT_TENANT];
        BoundedQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), lanes: lane_vec, cursor: 0, closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
            names,
            default_lane,
        }
    }

    /// Whether this queue schedules across tenant lanes.
    pub fn laned(&self) -> bool {
        !self.names.is_empty()
    }

    /// Point-in-time per-lane gauges `(name, depth, deficit)`, sorted by
    /// name; empty in laneless mode. The DRR deficit is scheduling state
    /// — surfacing it lets `/stats` show *why* a backlogged tenant is or
    /// is not served next (a negative deficit means the lane recently
    /// drew a wide batch and owes the rotation credit).
    pub fn lane_stats(&self) -> Vec<(String, usize, i64)> {
        if self.names.is_empty() {
            return Vec::new();
        }
        let g = self.inner.lock().unwrap();
        let mut out: Vec<(String, usize, i64)> = self
            .names
            .iter()
            .map(|(name, &i)| (name.clone(), g.lanes[i].items.len(), g.lanes[i].deficit))
            .collect();
        out.sort();
        out
    }

    fn lane_index(&self, lane: &str) -> usize {
        *self.names.get(lane).unwrap_or(&self.default_lane)
    }

    fn enqueue(g: &mut Inner<T>, idx: Option<usize>, item: T) {
        match idx {
            Some(i) => g.lanes[i].items.push_back(item),
            None => g.deque.push_back(item),
        }
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        self.push_to(DEFAULT_TENANT, item)
    }

    /// Blocking push into a named lane (unknown names → default lane;
    /// laneless queues ignore the name). Returns false when closed.
    pub fn push_to(&self, lane: &str, item: T) -> bool {
        let idx = if self.laned() { Some(self.lane_index(lane)) } else { None };
        let mut g = self.inner.lock().unwrap();
        while g.total() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        Self::enqueue(&mut g, idx, item);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push; Err(item) when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        self.try_push_to(DEFAULT_TENANT, item)
    }

    /// Non-blocking laned push; Err(item) when full or closed.
    pub fn try_push_to(&self, lane: &str, item: T) -> Result<(), T> {
        let idx = if self.laned() { Some(self.lane_index(lane)) } else { None };
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.total() >= self.cap {
            return Err(item);
        }
        Self::enqueue(&mut g, idx, item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking single pop; None when closed and drained. Laned queues
    /// elect the lane by DRR (a single pop is a width-1 batch).
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.lanes.is_empty() {
                if let Some(x) = g.deque.pop_front() {
                    self.not_full.notify_one();
                    return Some(x);
                }
            } else if g.total() > 0 {
                let (_, mut batch) = g.drr_serve(1, &|_: &T, _: &T| false);
                self.not_full.notify_one();
                return Some(batch.pop().unwrap());
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop the head plus up to `max - 1` additional jobs for which
    /// `affine(head, candidate)` holds (scanning the whole queue, preserving
    /// relative order of the rest). None when closed and drained. On laned
    /// queues the head comes from the DRR-elected lane and affine followers
    /// are collected from that lane only.
    pub fn pop_batch(&self, max: usize, affine: impl Fn(&T, &T) -> bool) -> Option<Vec<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.lanes.is_empty() {
                if !g.deque.is_empty() {
                    let head = g.deque.pop_front().unwrap();
                    let mut batch = vec![head];
                    collect_affine(&mut g.deque, &mut batch, max, &affine);
                    self.not_full.notify_all();
                    return Some(batch);
                }
            } else if g.total() > 0 {
                let (_, batch) = g.drr_serve(max, &affine);
                self.not_full.notify_all();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// `pop_batch` with a bounded admission window: when the instant
    /// grouping leaves the batch below `max`, hold it open up to
    /// `window_s` seconds (on `clock`) and fuse late-arriving affine jobs
    /// as they land. Returns the batch plus how it left the queue.
    ///
    /// Contract (locked by tests here and in `tests/wire_differential.rs`):
    /// * `window_s <= 0` delegates to [`BoundedQueue::pop_batch`] with
    ///   **zero clock reads** — bit-for-bit today's behavior, preserving
    ///   the pipeline's exactly-two-reads-per-execution `ScriptedClock`
    ///   accounting.
    /// * A batch that reaches `max` instantly also reads the clock zero
    ///   times ([`WindowOutcome::Filled`]).
    /// * Otherwise one read sets the deadline and each wake re-reads it;
    ///   the window elapsing or the queue closing releases the partial
    ///   batch ([`WindowOutcome::TimedOut`]).
    /// * Condvar waits are bounded by clock reads (each wait spans the
    ///   clock's remaining window, so waits ≤ reads − 2): holding a batch
    ///   open never busy-spins the worker on fixed real-time slices.
    /// * On laned queues the lane is elected once, when the head is
    ///   popped; late arrivals fuse only from that lane, and the window
    ///   fill is charged to the same deficit.
    pub fn pop_batch_windowed(
        &self,
        max: usize,
        affine: impl Fn(&T, &T) -> bool,
        window_s: f64,
        clock: &dyn Clock,
    ) -> Option<(Vec<T>, WindowOutcome)> {
        if window_s <= 0.0 {
            return self.pop_batch(max, affine).map(|b| (b, WindowOutcome::Disabled));
        }
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.lanes.is_empty() {
                if !g.deque.is_empty() {
                    break;
                }
            } else if g.total() > 0 {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        // Elect the lane (laned mode) and take the instant grouping.
        let lane = if g.lanes.is_empty() { None } else { Some(g.drr_pick()) };
        let head = match lane {
            Some(li) => g.lanes[li].items.pop_front().unwrap(),
            None => g.deque.pop_front().unwrap(),
        };
        let mut batch = vec![head];
        let fill = |g: &mut Inner<T>, batch: &mut Vec<T>| match lane {
            Some(li) => collect_affine(&mut g.lanes[li].items, batch, max, &affine),
            None => collect_affine(&mut g.deque, batch, max, &affine),
        };
        let charge = |g: &mut Inner<T>, n: usize| {
            if let Some(li) = lane {
                g.lanes[li].deficit -= n as i64;
            }
        };
        fill(&mut g, &mut batch);
        if batch.len() >= max {
            charge(&mut g, batch.len());
            self.not_full.notify_all();
            return Some((batch, WindowOutcome::Filled));
        }
        // Partial batch: hold it open until the window elapses, the queue
        // closes, or a late arrival fills it. The deadline lives on the
        // injected clock, and so does each condvar wait: the slice is the
        // clock's *remaining* window (floored at 1µs so a sub-µs remainder
        // still parks), so a wake is always a push/close notification or
        // the window genuinely elapsing — never a fixed real-time tick.
        // Waits are therefore bounded by clock reads, not wall time: a
        // scripted clock that sits still costs one parked wait, not a
        // busy-spin at ~1ms granularity.
        let deadline = clock.now_s() + window_s;
        loop {
            let now = clock.now_s();
            if g.closed || now >= deadline {
                charge(&mut g, batch.len());
                self.not_full.notify_all();
                return Some((batch, WindowOutcome::TimedOut));
            }
            let slice = std::time::Duration::from_secs_f64((deadline - now).max(1e-6));
            let (g2, _) = self.not_empty.wait_timeout(g, slice).unwrap();
            g = g2;
            fill(&mut g, &mut batch);
            if batch.len() >= max {
                charge(&mut g, batch.len());
                self.not_full.notify_all();
                return Some((batch, WindowOutcome::Filled));
            }
        }
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            assert!(q.push(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1);
        q.close();
        assert!(!q.push(2), "push after close fails");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0);
        let q2 = Arc::clone(&q);
        let handle = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(0));
        assert!(handle.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_batch_groups_affine_jobs() {
        let q = BoundedQueue::new(16);
        // (shape, id)
        for item in [(256, 0), (512, 1), (256, 2), (256, 3), (512, 4)] {
            q.push(item);
        }
        let batch = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(batch, vec![(256, 0), (256, 2), (256, 3)]);
        let rest = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(rest, vec![(512, 1), (512, 4)]);
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..6 {
            q.push((1, i));
        }
        let batch = q.pop_batch(4, |_, _| true).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 1000;
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(p * 1000 + i);
                }
            }));
        }
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            let c = Arc::clone(&consumed);
            consumers.push(std::thread::spawn(move || {
                while q.pop().is_some() {
                    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(std::sync::atomic::Ordering::Relaxed), total);
    }

    use crate::coordinator::tuner::ScriptedClock;

    #[test]
    fn windowed_disabled_is_pop_batch_bit_for_bit_with_zero_clock_reads() {
        let q = BoundedQueue::new(16);
        let r = BoundedQueue::new(16);
        for item in [(256, 0), (512, 1), (256, 2), (256, 3), (512, 4)] {
            q.push(item);
            r.push(item);
        }
        let clock = ScriptedClock::new(vec![]);
        let (batch, outcome) =
            q.pop_batch_windowed(8, |h, c| h.0 == c.0, 0.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Disabled);
        assert_eq!(batch, r.pop_batch(8, |h, c| h.0 == c.0).unwrap());
        assert_eq!(clock.reads(), 0, "disabled window must never read the clock");
        // Negative windows are disabled too.
        let (rest, outcome) =
            q.pop_batch_windowed(8, |h, c| h.0 == c.0, -1.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Disabled);
        assert_eq!(rest, r.pop_batch(8, |h, c| h.0 == c.0).unwrap());
        assert_eq!(clock.reads(), 0);
    }

    #[test]
    fn windowed_filled_instantly_reads_no_clock() {
        let q = BoundedQueue::new(16);
        for i in 0..4 {
            q.push((7, i));
        }
        let clock = ScriptedClock::new(vec![]);
        let (batch, outcome) =
            q.pop_batch_windowed(3, |h, c| h.0 == c.0, 1.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Filled);
        assert_eq!(batch.len(), 3);
        assert_eq!(clock.reads(), 0, "an instantly-full batch must not read the clock");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn windowed_times_out_on_scripted_deadline_with_exactly_two_reads() {
        let q = BoundedQueue::new(16);
        q.push((7, 0));
        // Read 1 sets deadline = 10.0 + 0.5; read 2 observes 11.0 > deadline,
        // so the partial batch is released without any condvar wait.
        let clock = ScriptedClock::new(vec![10.0, 11.0]);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 0.5, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        assert_eq!(clock.reads(), 2);
    }

    #[test]
    fn windowed_fuses_late_arrival_within_window() {
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            q2.push((9, 1)); // non-affine: must NOT fuse
            q2.push((7, 2)); // affine: fills the batch
        });
        // Tiny step keeps the scripted clock far below the deadline forever;
        // only the late arrival can end the wait.
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(2, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        producer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::Filled);
        assert_eq!(batch, vec![(7, 0), (7, 2)]);
        assert_eq!(q.len(), 1, "non-affine job stays queued");
        assert_eq!(q.pop(), Some((9, 1)));
    }

    #[test]
    fn windowed_close_releases_partial_batch() {
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            q2.close();
        });
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        closer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        // Closed and drained: the windowed pop reports end-of-queue.
        assert!(q.pop_batch_windowed(4, |h, c| h.0 == c.0, 1.0, &clock).is_none());
    }

    #[test]
    fn windowed_stalled_clock_parks_with_bounded_condvar_waits() {
        // The wait slice derives from the injected clock's remaining
        // window, so a scripted clock that never nears its deadline costs
        // ONE parked wait until the close notification — not a wake every
        // fixed 1ms real-time slice. Each wait is preceded by exactly one
        // clock read, so the read counter bounds the wait count: deadline
        // read + pre-wait read + post-wake read = 3 (a spurious OS wakeup
        // can add the odd extra read; anything near the old ~30 reads for
        // a 30ms stall means the fixed-slice spin is back).
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            q2.close();
        });
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        closer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        assert!(
            clock.reads() <= 6,
            "stalled-clock window must park, not spin: {} clock reads over a 30ms stall",
            clock.reads()
        );
    }

    // ---- tenant lanes / deficit round robin ------------------------------

    fn lanes(specs: &[(&str, u32)]) -> Vec<(String, u32)> {
        specs.iter().map(|(n, w)| (n.to_string(), *w)).collect()
    }

    #[test]
    fn empty_lane_spec_degenerates_to_laneless() {
        let q: BoundedQueue<u32> = BoundedQueue::with_lanes(4, &[]);
        assert!(!q.laned());
        assert!(q.push_to("anything", 1));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn unknown_lane_routes_to_default_and_default_is_synthesized() {
        let q = BoundedQueue::with_lanes(8, &lanes(&[("alpha", 1)]));
        assert!(q.laned());
        assert!(q.push_to("nobody", 1)); // → synthesized default lane
        assert!(q.push_to("alpha", 2));
        assert!(q.push(3)); // plain push → default lane
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(q.pop().unwrap());
        }
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn affine_collection_never_crosses_lanes() {
        // Same shape key in both lanes: a laneless queue would fuse all
        // four; lanes must keep tenants separate.
        let q = BoundedQueue::with_lanes(16, &lanes(&[("a", 1), ("b", 1)]));
        q.push_to("a", (7, 0));
        q.push_to("a", (7, 1));
        q.push_to("b", (7, 2));
        q.push_to("b", (7, 3));
        let first = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        let second = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(second.len(), 2);
        let ids: Vec<i32> = first.iter().chain(second.iter()).map(|x| x.1).collect();
        assert!(ids == vec![0, 1, 2, 3] || ids == vec![2, 3, 0, 1], "got {ids:?}");
    }

    #[test]
    fn drr_long_run_service_tracks_weights() {
        // Weight 3 vs 1, width-1 batches, both lanes permanently
        // backlogged: served counts must track the 3:1 quanta exactly
        // (DRR with unit cost is exact over full rotations).
        let q = BoundedQueue::with_lanes(512, &lanes(&[("big", 3), ("small", 1)]));
        for i in 0..200 {
            q.push_to("big", ("big", i));
            q.push_to("small", ("small", i));
        }
        let (mut big, mut small) = (0u32, 0u32);
        for _ in 0..160 {
            let b = q.pop_batch(1, |_, _| false).unwrap();
            match b[0].0 {
                "big" => big += 1,
                _ => small += 1,
            }
        }
        assert_eq!(big + small, 160);
        assert_eq!(big, 120, "weight-3 lane serves 3/4 of unit-cost pops (got {big})");
        assert_eq!(small, 40);
    }

    #[test]
    fn drr_batches_charge_deficit_and_lane_recovers() {
        // A full-width batch drives the lane's deficit negative; the
        // other lane is then served while the first recovers, but the
        // first is never starved out entirely.
        let q = BoundedQueue::with_lanes(512, &lanes(&[("a", 1), ("b", 1)]));
        for i in 0..40 {
            q.push_to("a", ("a", i));
            q.push_to("b", ("b", i));
        }
        let mut order = Vec::new();
        while let Some(batch) = {
            if q.is_empty() {
                None
            } else {
                q.pop_batch(4, |h, c| h.0 == c.0)
            }
        } {
            order.push((batch[0].0, batch.len()));
        }
        let a_total: usize = order.iter().filter(|x| x.0 == "a").map(|x| x.1).sum();
        let b_total: usize = order.iter().filter(|x| x.0 == "b").map(|x| x.1).sum();
        assert_eq!(a_total, 40);
        assert_eq!(b_total, 40);
        // No run of same-lane batches longer than the recovery bound:
        // after a width-4 batch (deficit −3) the other backlogged lane
        // must win the next 3+ elections.
        let mut max_run = 0;
        let mut run = 0;
        let mut prev = "";
        for (lane, _) in &order {
            if *lane == prev {
                run += 1;
            } else {
                run = 1;
                prev = lane;
            }
            max_run = max_run.max(run);
        }
        assert!(max_run <= 2, "same-lane batch runs must stay bounded, got {max_run}");
    }

    #[test]
    fn windowed_pop_on_lanes_fills_from_elected_lane_only() {
        let q = Arc::new(BoundedQueue::with_lanes(16, &lanes(&[("a", 1), ("b", 1)])));
        q.push_to("a", (7, 0));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            q2.push_to("b", (7, 1)); // affine shape but wrong lane: must NOT fuse
            q2.push_to("a", (7, 2)); // same lane: fills the batch
        });
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(2, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        producer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::Filled);
        assert_eq!(batch, vec![(7, 0), (7, 2)]);
        assert_eq!(q.len(), 1, "other tenant's job stays queued");
        assert_eq!(q.pop(), Some((7, 1)));
    }

    /// Reference model: the same surplus-DRR accounting as `Inner`,
    /// re-implemented independently so the property test pins *exact*
    /// deficit arithmetic, not just aggregate fairness.
    struct ModelLane {
        items: VecDeque<(usize, u32)>, // (shape, seq)
        deficit: i64,
        quantum: i64,
    }

    fn model_pick(lanes: &mut [ModelLane], cursor: &mut usize) -> usize {
        let n = lanes.len();
        loop {
            for step in 0..n {
                let i = (*cursor + step) % n;
                if lanes[i].items.is_empty() {
                    lanes[i].deficit = 0;
                    continue;
                }
                lanes[i].deficit += lanes[i].quantum;
                if lanes[i].deficit > 0 {
                    *cursor = (i + 1) % n;
                    return i;
                }
            }
        }
    }

    fn model_serve(lanes: &mut [ModelLane], cursor: &mut usize, max: usize) -> Vec<(usize, u32)> {
        let li = model_pick(lanes, cursor);
        let head = lanes[li].items.pop_front().unwrap();
        let mut batch = vec![head];
        let mut i = 0;
        while i < lanes[li].items.len() && batch.len() < max {
            if lanes[li].items[i].0 == batch[0].0 {
                let item = lanes[li].items.remove(i).unwrap();
                batch.push(item);
            } else {
                i += 1;
            }
        }
        lanes[li].deficit -= batch.len() as i64;
        batch
    }

    #[test]
    fn prop_weighted_fair_dequeue_matches_model_and_never_starves() {
        // Randomized adversarial interleavings: a hot lane floods, batch
        // width varies, shapes collide across lanes. The queue's dequeue
        // sequence must match the independent DRR model *exactly* (same
        // deficits, same elections), and no backlogged lane may wait
        // longer than the analytic starvation bound:
        //   rotations ≤ ceil((max_batch + quantum_i)/quantum_i) before
        //   lane i's deficit turns positive, and each rotation serves at
        //   most (lanes − 1) other batches ⇒ gap ≤ lanes · (max + Qmax).
        let cfg = crate::prop::Config { cases: 40, base_seed: 0x9D44, ..Default::default() };
        crate::prop::check(
            cfg,
            |g| {
                let nlanes = g.usize_in(2, 4);
                let names: Vec<String> = (0..nlanes).map(|i| format!("t{i}")).collect();
                let weights: Vec<u32> = (0..nlanes).map(|_| g.usize_in(1, 4) as u32).collect();
                let max = g.usize_in(1, 4);
                let total = g.usize_in(30, 120);
                // Adversarial arrivals: one lane is hot (picked ~half the
                // time), shapes drawn from a tiny pool so fusion happens.
                let hot = g.usize_in(0, nlanes - 1);
                let mut arrivals: Vec<(usize, usize)> = Vec::new(); // (lane, shape)
                for _ in 0..total {
                    let lane =
                        if g.bool() { hot } else { g.usize_in(0, nlanes - 1) };
                    arrivals.push((lane, g.usize_in(0, 2)));
                }
                (names, weights, max, arrivals)
            },
            |(names, weights, max, arrivals)| {
                let spec: Vec<(String, u32)> =
                    names.iter().cloned().zip(weights.iter().copied()).collect();
                let q: BoundedQueue<(usize, u32)> = BoundedQueue::with_lanes(4096, &spec);
                let mut model: Vec<ModelLane> = weights
                    .iter()
                    .map(|w| ModelLane {
                        items: VecDeque::new(),
                        deficit: 0,
                        quantum: (*w).max(1) as i64,
                    })
                    .collect();
                // The queue synthesizes a default lane after the configured
                // ones; it stays empty, so mirror it in the model.
                model.push(ModelLane { items: VecDeque::new(), deficit: 0, quantum: 1 });
                let mut cursor = 0usize;
                for (seq, (lane, shape)) in arrivals.iter().enumerate() {
                    let item = (*shape, seq as u32);
                    if q.try_push_to(&names[*lane], item).is_err() {
                        return Err("push failed".to_string());
                    }
                    model[*lane].items.push_back(item);
                }
                // Drain; compare every batch against the model and track
                // the starvation gap per lane.
                let qmax = *weights.iter().max().unwrap() as usize;
                let bound = (names.len() + 1) * (*max + qmax) + names.len() + 1;
                let mut waiting: Vec<usize> = vec![0; names.len()];
                let mut pops = 0usize;
                while !q.is_empty() {
                    let got =
                        q.pop_batch(*max, |h, c| h.0 == c.0).ok_or("queue closed early")?;
                    let want = model_serve(&mut model, &mut cursor, *max);
                    if got != want {
                        return Err(format!(
                            "pop {pops}: queue served {got:?}, model says {want:?}"
                        ));
                    }
                    pops += 1;
                    // The batch head's seq recovers which lane was served.
                    let served_lane = arrivals[got[0].1 as usize].0;
                    for (li, w) in waiting.iter_mut().enumerate() {
                        if !model[li].items.is_empty() {
                            *w += 1;
                            if *w > bound {
                                return Err(format!(
                                    "lane {li} backlogged for {w} pops (bound {bound})"
                                ));
                            }
                        } else {
                            *w = 0;
                        }
                    }
                    waiting[served_lane] = 0;
                }
                for (li, lane) in model.iter().enumerate() {
                    if !lane.items.is_empty() {
                        return Err(format!("model lane {li} still holds items after drain"));
                    }
                }
                Ok(())
            },
        );
    }
}
