//! Bounded MPMC job queue with blocking backpressure and affinity-keyed
//! batch dequeue.
//!
//! `push` blocks when the queue is full (producers feel backpressure instead
//! of OOMing the coordinator); `pop_batch` removes up to `max` jobs that the
//! caller's affinity predicate groups with the head job. The coordinator
//! keys the predicate on the A operand (`pool::batch_affine`: handle
//! equality for registered operands, the content signature otherwise), so
//! a dequeued batch provably shares one A operand and the worker executes
//! it **fused**: at most one A conversion (none when the operand is
//! registered — the store's cached slabs serve the whole batch), one wide
//! kernel over the stacked Bs, one warm compiled executable (see
//! `pool.rs` and DESIGN.md §Batching).
//!
//! `pop_batch_windowed` extends the instant grouping with a **time-window
//! admission policy**: a partial batch is held open for a bounded window
//! (measured on an injected [`Clock`], so tests script the exact
//! fuse-vs-timeout decision) and late-arriving affine singles fuse into it.
//! Window ≤ 0 delegates to `pop_batch` with **zero clock reads** — today's
//! behavior bit-for-bit. Admission timing changes batching choices, never
//! results (DESIGN.md §Wire).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::tuner::Clock;

/// How a windowed batch left the queue (surfaced in `Metrics`/`/stats`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowOutcome {
    /// Window disabled (≤ 0): instant `pop_batch` semantics.
    Disabled,
    /// The batch reached `max` width — inside the window or instantly.
    Filled,
    /// The window elapsed (or the queue closed) with a partial batch.
    TimedOut,
}

/// Move every job affine to `batch[0]` from the deque into `batch` (up to
/// `max` total), scanning the whole deque and preserving the relative
/// order of the rest. Shared by `pop_batch` and `pop_batch_windowed` so
/// the two admission policies provably group by the same predicate.
fn collect_affine<T>(
    deque: &mut VecDeque<T>,
    batch: &mut Vec<T>,
    max: usize,
    affine: &impl Fn(&T, &T) -> bool,
) {
    let mut i = 0;
    while i < deque.len() && batch.len() < max {
        if affine(&batch[0], &deque[i]) {
            let item = deque.remove(i).unwrap();
            batch.push(item);
        } else {
            i += 1;
        }
    }
}

struct Inner<T> {
    deque: VecDeque<T>,
    closed: bool,
}

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { deque: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.deque.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.deque.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push; Err(item) when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.deque.len() >= self.cap {
            return Err(item);
        }
        g.deque.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking single pop; None when closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.deque.pop_front() {
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop the head plus up to `max - 1` additional jobs for which
    /// `affine(head, candidate)` holds (scanning the whole queue, preserving
    /// relative order of the rest). None when closed and drained.
    pub fn pop_batch(&self, max: usize, affine: impl Fn(&T, &T) -> bool) -> Option<Vec<T>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.deque.is_empty() {
                let head = g.deque.pop_front().unwrap();
                let mut batch = vec![head];
                collect_affine(&mut g.deque, &mut batch, max, &affine);
                self.not_full.notify_all();
                return Some(batch);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// `pop_batch` with a bounded admission window: when the instant
    /// grouping leaves the batch below `max`, hold it open up to
    /// `window_s` seconds (on `clock`) and fuse late-arriving affine jobs
    /// as they land. Returns the batch plus how it left the queue.
    ///
    /// Contract (locked by tests here and in `tests/wire_differential.rs`):
    /// * `window_s <= 0` delegates to [`BoundedQueue::pop_batch`] with
    ///   **zero clock reads** — bit-for-bit today's behavior, preserving
    ///   the pipeline's exactly-two-reads-per-execution `ScriptedClock`
    ///   accounting.
    /// * A batch that reaches `max` instantly also reads the clock zero
    ///   times ([`WindowOutcome::Filled`]).
    /// * Otherwise one read sets the deadline and each wake re-reads it;
    ///   the window elapsing or the queue closing releases the partial
    ///   batch ([`WindowOutcome::TimedOut`]).
    /// * Condvar waits are bounded by clock reads (each wait spans the
    ///   clock's remaining window, so waits ≤ reads − 2): holding a batch
    ///   open never busy-spins the worker on fixed real-time slices.
    pub fn pop_batch_windowed(
        &self,
        max: usize,
        affine: impl Fn(&T, &T) -> bool,
        window_s: f64,
        clock: &dyn Clock,
    ) -> Option<(Vec<T>, WindowOutcome)> {
        if window_s <= 0.0 {
            return self.pop_batch(max, affine).map(|b| (b, WindowOutcome::Disabled));
        }
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.deque.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
        let head = g.deque.pop_front().unwrap();
        let mut batch = vec![head];
        collect_affine(&mut g.deque, &mut batch, max, &affine);
        if batch.len() >= max {
            self.not_full.notify_all();
            return Some((batch, WindowOutcome::Filled));
        }
        // Partial batch: hold it open until the window elapses, the queue
        // closes, or a late arrival fills it. The deadline lives on the
        // injected clock, and so does each condvar wait: the slice is the
        // clock's *remaining* window (floored at 1µs so a sub-µs remainder
        // still parks), so a wake is always a push/close notification or
        // the window genuinely elapsing — never a fixed real-time tick.
        // Waits are therefore bounded by clock reads, not wall time: a
        // scripted clock that sits still costs one parked wait, not a
        // busy-spin at ~1ms granularity.
        let deadline = clock.now_s() + window_s;
        loop {
            let now = clock.now_s();
            if g.closed || now >= deadline {
                self.not_full.notify_all();
                return Some((batch, WindowOutcome::TimedOut));
            }
            let slice = std::time::Duration::from_secs_f64((deadline - now).max(1e-6));
            let (g2, _) = self.not_empty.wait_timeout(g, slice).unwrap();
            g = g2;
            collect_affine(&mut g.deque, &mut batch, max, &affine);
            if batch.len() >= max {
                self.not_full.notify_all();
                return Some((batch, WindowOutcome::Filled));
            }
        }
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().deque.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            assert!(q.push(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1);
        q.close();
        assert!(!q.push(2), "push after close fails");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0);
        let q2 = Arc::clone(&q);
        let handle = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(0));
        assert!(handle.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_batch_groups_affine_jobs() {
        let q = BoundedQueue::new(16);
        // (shape, id)
        for item in [(256, 0), (512, 1), (256, 2), (256, 3), (512, 4)] {
            q.push(item);
        }
        let batch = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(batch, vec![(256, 0), (256, 2), (256, 3)]);
        let rest = q.pop_batch(8, |h, c| h.0 == c.0).unwrap();
        assert_eq!(rest, vec![(512, 1), (512, 4)]);
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = BoundedQueue::new(16);
        for i in 0..6 {
            q.push((1, i));
        }
        let batch = q.pop_batch(4, |_, _| true).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 1000;
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(p * 1000 + i);
                }
            }));
        }
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            let c = Arc::clone(&consumed);
            consumers.push(std::thread::spawn(move || {
                while q.pop().is_some() {
                    c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        for h in consumers {
            h.join().unwrap();
        }
        assert_eq!(consumed.load(std::sync::atomic::Ordering::Relaxed), total);
    }

    use crate::coordinator::tuner::ScriptedClock;

    #[test]
    fn windowed_disabled_is_pop_batch_bit_for_bit_with_zero_clock_reads() {
        let q = BoundedQueue::new(16);
        let r = BoundedQueue::new(16);
        for item in [(256, 0), (512, 1), (256, 2), (256, 3), (512, 4)] {
            q.push(item);
            r.push(item);
        }
        let clock = ScriptedClock::new(vec![]);
        let (batch, outcome) =
            q.pop_batch_windowed(8, |h, c| h.0 == c.0, 0.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Disabled);
        assert_eq!(batch, r.pop_batch(8, |h, c| h.0 == c.0).unwrap());
        assert_eq!(clock.reads(), 0, "disabled window must never read the clock");
        // Negative windows are disabled too.
        let (rest, outcome) =
            q.pop_batch_windowed(8, |h, c| h.0 == c.0, -1.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Disabled);
        assert_eq!(rest, r.pop_batch(8, |h, c| h.0 == c.0).unwrap());
        assert_eq!(clock.reads(), 0);
    }

    #[test]
    fn windowed_filled_instantly_reads_no_clock() {
        let q = BoundedQueue::new(16);
        for i in 0..4 {
            q.push((7, i));
        }
        let clock = ScriptedClock::new(vec![]);
        let (batch, outcome) =
            q.pop_batch_windowed(3, |h, c| h.0 == c.0, 1.0, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::Filled);
        assert_eq!(batch.len(), 3);
        assert_eq!(clock.reads(), 0, "an instantly-full batch must not read the clock");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn windowed_times_out_on_scripted_deadline_with_exactly_two_reads() {
        let q = BoundedQueue::new(16);
        q.push((7, 0));
        // Read 1 sets deadline = 10.0 + 0.5; read 2 observes 11.0 > deadline,
        // so the partial batch is released without any condvar wait.
        let clock = ScriptedClock::new(vec![10.0, 11.0]);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 0.5, &clock).unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        assert_eq!(clock.reads(), 2);
    }

    #[test]
    fn windowed_fuses_late_arrival_within_window() {
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            q2.push((9, 1)); // non-affine: must NOT fuse
            q2.push((7, 2)); // affine: fills the batch
        });
        // Tiny step keeps the scripted clock far below the deadline forever;
        // only the late arrival can end the wait.
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(2, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        producer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::Filled);
        assert_eq!(batch, vec![(7, 0), (7, 2)]);
        assert_eq!(q.len(), 1, "non-affine job stays queued");
        assert_eq!(q.pop(), Some((9, 1)));
    }

    #[test]
    fn windowed_close_releases_partial_batch() {
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            q2.close();
        });
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        closer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        // Closed and drained: the windowed pop reports end-of-queue.
        assert!(q.pop_batch_windowed(4, |h, c| h.0 == c.0, 1.0, &clock).is_none());
    }

    #[test]
    fn windowed_stalled_clock_parks_with_bounded_condvar_waits() {
        // The wait slice derives from the injected clock's remaining
        // window, so a scripted clock that never nears its deadline costs
        // ONE parked wait until the close notification — not a wake every
        // fixed 1ms real-time slice. Each wait is preceded by exactly one
        // clock read, so the read counter bounds the wait count: deadline
        // read + pre-wait read + post-wake read = 3 (a spurious OS wakeup
        // can add the odd extra read; anything near the old ~30 reads for
        // a 30ms stall means the fixed-slice spin is back).
        let q = Arc::new(BoundedQueue::new(16));
        q.push((7, 0));
        let q2 = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            q2.close();
        });
        let clock = ScriptedClock::with_step(vec![0.0], 1e-9);
        let (batch, outcome) =
            q.pop_batch_windowed(4, |h, c| h.0 == c.0, 3600.0, &clock).unwrap();
        closer.join().unwrap();
        assert_eq!(outcome, WindowOutcome::TimedOut);
        assert_eq!(batch, vec![(7, 0)]);
        assert!(
            clock.reads() <= 6,
            "stalled-clock window must park, not spin: {} clock reads over a 30ms stall",
            clock.reads()
        );
    }
}
