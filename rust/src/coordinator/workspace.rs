//! Per-worker workspace arena: the reusable pad/convert/repad scratch
//! buffers behind the zero-copy request pipeline, plus the stacked-B /
//! stacked-C wide buffers behind fused multi-B batch execution
//! (DESIGN.md §Batching).
//!
//! **Ownership rule: mutable scratch per worker; immutable converted
//! operands shared.** One `Workspace` per coordinator worker, owned next
//! to that worker's engine, never shared: every buffer here is borrowed by
//! in-flight slab views during a request (`GcooSlabs`/`EllSlabs` point
//! straight into `gcoo_*`/`ell_*`), so sharing a workspace across threads —
//! or across two concurrently processed requests — would corrupt the slabs
//! an engine kernel is reading. The worker loop processes requests
//! sequentially, so reuse is safe and steady-state serving does **zero
//! per-request allocation on the A-side path**: every buffer is resized in
//! place (`Vec::resize` / [`crate::ndarray::Mat::zero_into`]) and reaches a
//! stable capacity after the first request of each shape.
//!
//! The *shared* half of the rule is the operand store
//! (`coordinator/store.rs`): registered As and their converted device
//! slabs are frozen at registration and shared into workers via `Arc`, so
//! engines borrow cached slabs directly instead of scattering into this
//! arena — handle traffic touches the workspace only for B padding and
//! the stacked wide buffers.

use crate::ndarray::Mat;

/// Reusable per-worker scratch buffers (see module docs for the ownership
/// rule). Fields are public so the pipeline can take disjoint borrows —
/// e.g. GCOO slab views from `gcoo_*` while B is borrowed from `b_pad`.
pub struct Workspace {
    /// Padded-A scratch (dense path only; sparse paths never pad A).
    pub a_pad: Mat,
    /// Padded-B scratch (any path, when the request is below n_exec).
    pub b_pad: Mat,
    /// Device GCOO slab buffers, `g·cap` each (vals/rows/cols).
    pub gcoo_vals: Vec<f32>,
    pub gcoo_rows: Vec<i32>,
    pub gcoo_cols: Vec<i32>,
    /// Device ELL slab buffers, `n·rowcap` each (vals/cols).
    pub ell_vals: Vec<f32>,
    pub ell_cols: Vec<i32>,
    /// Device CMRS slab buffers, `g·cap` each (vals/rows/cols), strip
    /// entries round-robin interleaved.
    pub cmrs_vals: Vec<f32>,
    pub cmrs_rows: Vec<i32>,
    pub cmrs_cols: Vec<i32>,
    /// Device row-split slab buffers: `segs·cap` entry arrays (vals/cols)
    /// plus the per-segment row ids (`rowsplit_rows`, length `segs`).
    pub rowsplit_vals: Vec<f32>,
    pub rowsplit_rows: Vec<i32>,
    pub rowsplit_cols: Vec<i32>,
    /// Fused-batch wide-B operand: the batch's B matrices stacked
    /// column-wise into one `n_exec × width·n_exec` matrix (each block
    /// zero-padded from its request's n). Reused across batches.
    pub b_stack: Mat,
    /// Fused-batch wide-C staging buffer the engine `_into` kernels write
    /// to; per-request C blocks are scattered out of it. Reused across
    /// batches (the dense batch path replaces it with the engine's owned
    /// result instead — see `process_batch_ws`).
    pub c_stack: Mat,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace {
            a_pad: Mat::zeros(0, 0),
            b_pad: Mat::zeros(0, 0),
            gcoo_vals: Vec::new(),
            gcoo_rows: Vec::new(),
            gcoo_cols: Vec::new(),
            ell_vals: Vec::new(),
            ell_cols: Vec::new(),
            cmrs_vals: Vec::new(),
            cmrs_rows: Vec::new(),
            cmrs_cols: Vec::new(),
            rowsplit_vals: Vec::new(),
            rowsplit_rows: Vec::new(),
            rowsplit_cols: Vec::new(),
            b_stack: Mat::zeros(0, 0),
            c_stack: Mat::zeros(0, 0),
        }
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::convert;
    use crate::gen;
    use crate::ndarray::Mat;
    use crate::prop::{check, Config};
    use crate::sparse::Gcoo;

    /// Workspace invariant: pad→trim through the arena buffers is the
    /// identity for any (n, n_exec ≥ n), and the pad border is zero.
    #[test]
    fn prop_pad_trim_round_trip() {
        check(
            Config { cases: 48, base_seed: 0xA11, ..Default::default() },
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let n_exec = n + g.usize_in(0, 24);
                let a = Mat::randn(n, n, &mut g.rng);
                (a, n_exec)
            },
            |(a, n_exec)| {
                let n = a.rows;
                let mut ws = Workspace::new();
                ws.a_pad.pad_from(a, *n_exec);
                if ws.a_pad.rows != *n_exec {
                    return Err("pad size wrong".into());
                }
                for i in 0..*n_exec {
                    for j in 0..*n_exec {
                        let want = if i < n && j < n { a[(i, j)] } else { 0.0 };
                        if ws.a_pad[(i, j)] != want {
                            return Err(format!("pad[{i},{j}] = {}", ws.a_pad[(i, j)]));
                        }
                    }
                }
                ws.b_pad.trim_from(&ws.a_pad, n);
                if &ws.b_pad != a {
                    return Err("trim(pad(a)) != a".into());
                }
                Ok(())
            },
        );
    }

    /// Workspace invariant: converting into the arena slabs equals the
    /// allocate-per-request reference pipeline (convert, then pad), and a
    /// second conversion at the same geometry reuses the buffers.
    #[test]
    fn prop_slab_conversion_matches_reference() {
        check(
            Config { cases: 32, base_seed: 0xA12, max_size: 48, ..Default::default() },
            |g| {
                let n = g.usize_in(2, g.size.max(2));
                let p = *g.pick(&[2usize, 4, 8]);
                let sparsity = g.f64_in(0.5, 0.98);
                let a = gen::uniform(n, sparsity, &mut g.rng);
                let extra = g.usize_in(0, 8);
                (a, p, extra)
            },
            |(a, p, extra)| {
                let stats = convert::scan_stats(a, *p, 2);
                let cap = stats.max_band_nnz().max(1) + extra;
                let mut ws = Workspace::new();
                convert::dense_to_slabs_into(
                    a, &stats, a.rows, cap, 2,
                    &mut ws.gcoo_vals, &mut ws.gcoo_rows, &mut ws.gcoo_cols,
                )
                .map_err(|e| e.to_string())?;
                let reference = Gcoo::from_dense(a, *p).pad(cap).map_err(|e| e.to_string())?;
                if ws.gcoo_vals != reference.vals
                    || ws.gcoo_rows != reference.rows
                    || ws.gcoo_cols != reference.cols
                {
                    return Err("arena slabs != reference convert-then-pad".into());
                }
                let ptr = ws.gcoo_vals.as_ptr();
                convert::dense_to_slabs_into(
                    a, &stats, a.rows, cap, 2,
                    &mut ws.gcoo_vals, &mut ws.gcoo_rows, &mut ws.gcoo_cols,
                )
                .map_err(|e| e.to_string())?;
                if ws.gcoo_vals.as_ptr() != ptr {
                    return Err("steady-state conversion reallocated".into());
                }
                Ok(())
            },
        );
    }

    /// Workspace invariant: slab repad grow→shrink is the identity for any
    /// grow capacity (grow then shrink back never loses entries).
    #[test]
    fn prop_repad_grow_shrink_idempotent() {
        check(
            Config { cases: 32, base_seed: 0xA13, max_size: 40, ..Default::default() },
            |g| {
                let n = g.usize_in(2, g.size.max(2));
                let sparsity = g.f64_in(0.6, 0.95);
                let a = gen::uniform(n, sparsity, &mut g.rng);
                let grow = g.usize_in(1, 16);
                (a, grow)
            },
            |(a, grow)| {
                let gcoo = Gcoo::from_dense(a, 4);
                let cap = gcoo.max_group_nnz().max(1);
                let base = gcoo.pad(cap).map_err(|e| e.to_string())?;
                let grown = base.as_slabs().repad(cap + grow);
                if grown.as_slabs().repad(cap) != base {
                    return Err("repad grow→shrink not identity".into());
                }
                // Growing must agree with padding directly at the capacity.
                let direct = gcoo.pad(cap + grow).map_err(|e| e.to_string())?;
                if grown != direct {
                    return Err("repad(grow) != pad(grow)".into());
                }
                Ok(())
            },
        );
    }
}
