//! Consistent-hash shard ring for the multi-coordinator cluster.
//!
//! The cluster (DESIGN.md §Cluster) partitions operand ownership across N
//! coordinator nodes with a fixed-seed hash ring: `vnodes` points per node,
//! each at `mix64(seed, node, vnode)`, sorted; a key's **owner** is the node
//! of the first point clockwise of `mix64(seed, key)`, and its **replica
//! set** is the owner plus the next `r − 1` *distinct* nodes walking the
//! ring. Everything is a pure function of `(nodes, vnodes, seed)`, so the
//! router needs no routing table: any party that knows the membership doc
//! computes identical placement, which is what lets the router stay
//! stateless and lets a restarted router resume mid-traffic.
//!
//! Handles route by their integer id. That works because each node's store
//! only ever *assigns* ids its own ring position owns ([`ShardSpec::owns`]
//! filters the store's id sequence — see `OperandStore::register`):
//! `ring.owner(handle)` always resolves to the node that registered it,
//! with no translation map anywhere. A 1-node ring owns every id, so the
//! degenerate cluster assigns the same dense 1, 2, 3… sequence as a bare
//! coordinator — single-node behavior is bitwise unchanged.

/// SplitMix64 finalizer: the ring's only hash primitive. Deterministic,
/// seed-mixed, and avalanching — consecutive handle ids land on unrelated
/// ring positions, which is what spreads a hot id range across nodes.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// The cluster-wide default ring seed. Part of the membership contract:
/// every node and every router must agree on it (the membership codec
/// carries it explicitly so a mismatch is a load-time error, not silent
/// misrouting).
pub const DEFAULT_RING_SEED: u64 = 0x5EED_C0DE_0B57_AC1E;

/// Default virtual nodes per physical node. Enough to keep the 3-node
/// spread within a reasonable factor without making ring construction (a
/// sort of `nodes · vnodes` points) noticeable at registration time.
pub const DEFAULT_VNODES: u32 = 16;

/// A node's view of the shard layout — `Copy`, so it embeds directly in
/// `CoordinatorConfig` (which is `Copy` by contract). `None` shard spec in
/// the config means "not clustered": the store's id sequence runs dense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Cluster size N (≥ 1).
    pub nodes: u32,
    /// This node's index in `0..nodes`.
    pub node: u32,
    /// Virtual nodes per physical node (≥ 1).
    pub vnodes: u32,
    /// Ring seed — must match the membership doc.
    pub seed: u64,
}

impl ShardSpec {
    /// The spec for node `node` of an N-node cluster with default ring
    /// parameters.
    pub fn node_of(node: u32, nodes: u32) -> ShardSpec {
        ShardSpec { nodes, node, vnodes: DEFAULT_VNODES, seed: DEFAULT_RING_SEED }
    }

    /// Materialize the ring this spec describes.
    pub fn ring(&self) -> Ring {
        Ring::new(self.nodes, self.vnodes, self.seed)
    }

    /// Does this node own id `key`? (Store id admission builds the ring
    /// once per registration and filters its sequence with this.)
    pub fn owns(&self, ring: &Ring, key: u64) -> bool {
        ring.owner(key) == self.node
    }
}

/// The fixed-seed consistent-hash ring. Construction sorts
/// `nodes · vnodes` `(position, node)` points; lookups binary-search them.
#[derive(Clone, Debug)]
pub struct Ring {
    seed: u64,
    nodes: u32,
    /// Sorted ring points: (position hash, owning node).
    points: Vec<(u64, u32)>,
}

impl Ring {
    pub fn new(nodes: u32, vnodes: u32, seed: u64) -> Ring {
        assert!(nodes >= 1, "a ring needs at least one node");
        assert!(vnodes >= 1, "a node needs at least one ring point");
        let mut points = Vec::with_capacity((nodes * vnodes) as usize);
        for node in 0..nodes {
            for v in 0..vnodes {
                let point = mix64(seed ^ mix64(((node as u64) << 32) | v as u64));
                points.push((point, node));
            }
        }
        // Ties (astronomically unlikely, but the contract must be total)
        // break toward the lower node index via the tuple order.
        points.sort_unstable();
        Ring { seed, nodes, points }
    }

    pub fn nodes(&self) -> u32 {
        self.nodes
    }

    /// The node owning `key`: the first ring point clockwise of the key's
    /// seed-mixed position (wrapping past the top back to the first point).
    pub fn owner(&self, key: u64) -> u32 {
        self.points[self.slot(key)].1
    }

    /// The replica set for `key`: the owner plus the next `r − 1`
    /// *distinct* nodes walking the ring clockwise, capped at the cluster
    /// size. Order matters — failover tries the set left to right.
    pub fn replicas(&self, key: u64, r: u32) -> Vec<u32> {
        let want = r.min(self.nodes).max(1) as usize;
        let mut out = Vec::with_capacity(want);
        let start = self.slot(key);
        for i in 0..self.points.len() {
            let node = self.points[(start + i) % self.points.len()].1;
            if !out.contains(&node) {
                out.push(node);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    fn slot(&self, key: u64) -> usize {
        let h = mix64(self.seed ^ mix64(key));
        let idx = self.points.partition_point(|&(p, _)| p < h);
        if idx == self.points.len() {
            0
        } else {
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_placement() {
        let a = Ring::new(3, DEFAULT_VNODES, DEFAULT_RING_SEED);
        let b = Ring::new(3, DEFAULT_VNODES, DEFAULT_RING_SEED);
        for key in 0..10_000u64 {
            assert_eq!(a.owner(key), b.owner(key));
            assert_eq!(a.replicas(key, 2), b.replicas(key, 2));
        }
    }

    #[test]
    fn owner_heads_replica_set_and_nodes_are_distinct() {
        let ring = Ring::new(5, DEFAULT_VNODES, DEFAULT_RING_SEED);
        for key in 0..2_000u64 {
            let owner = ring.owner(key);
            assert!(owner < 5);
            for r in 1..=7u32 {
                let reps = ring.replicas(key, r);
                assert_eq!(reps[0], owner, "owner heads the replica set");
                assert_eq!(reps.len(), r.min(5) as usize, "capped at cluster size");
                let mut sorted = reps.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), reps.len(), "replicas are distinct nodes");
            }
        }
    }

    #[test]
    fn single_node_ring_owns_everything() {
        let ring = Ring::new(1, DEFAULT_VNODES, DEFAULT_RING_SEED);
        let spec = ShardSpec::node_of(0, 1);
        for key in 0..1_000u64 {
            assert_eq!(ring.owner(key), 0);
            assert!(spec.owns(&ring, key), "K=1 degenerates to the dense id sequence");
        }
    }

    #[test]
    fn three_node_spread_is_workable() {
        // Not a statistical claim — a pinned property of the default seed
        // the cluster actually ships: over the first 3000 handle ids every
        // node owns a healthy share, so the store's owned-id filter always
        // finds its next id within a short scan.
        let ring = Ring::new(3, DEFAULT_VNODES, DEFAULT_RING_SEED);
        let mut counts = [0usize; 3];
        let mut longest_gap = [0usize; 3];
        let mut since = [0usize; 3];
        for key in 1..=3_000u64 {
            let owner = ring.owner(key) as usize;
            counts[owner] += 1;
            for node in 0..3 {
                if node == owner {
                    since[node] = 0;
                } else {
                    since[node] += 1;
                    longest_gap[node] = longest_gap[node].max(since[node]);
                }
            }
        }
        for node in 0..3 {
            assert!(counts[node] >= 300, "node {node} owns {} of 3000 ids", counts[node]);
            assert!(
                longest_gap[node] < 64,
                "node {node} must find an owned id within a short scan (gap {})",
                longest_gap[node]
            );
        }
    }

    #[test]
    fn different_seeds_shuffle_placement() {
        let a = Ring::new(4, DEFAULT_VNODES, DEFAULT_RING_SEED);
        let b = Ring::new(4, DEFAULT_VNODES, DEFAULT_RING_SEED ^ 1);
        let moved = (0..4_000u64).filter(|&k| a.owner(k) != b.owner(k)).count();
        assert!(moved > 1_000, "seed participates in placement ({moved} moved)");
    }
}
