//! Job types crossing the coordinator boundary.

use crate::ndarray::Mat;

/// Algorithm families the coordinator can route to (== artifact `algo`s).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Gcoo,
    GcooNoreuse,
    Csr,
    DenseXla,
    DensePallas,
}

impl Algo {
    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::Gcoo => "gcoo",
            Algo::GcooNoreuse => "gcoo_noreuse",
            Algo::Csr => "csr",
            Algo::DenseXla => "dense_xla",
            Algo::DensePallas => "dense_pallas",
        }
    }

    pub fn from_str(s: &str) -> Option<Algo> {
        match s {
            "gcoo" => Some(Algo::Gcoo),
            "gcoo_noreuse" => Some(Algo::GcooNoreuse),
            "csr" => Some(Algo::Csr),
            "dense_xla" | "dense" => Some(Algo::DenseXla),
            "dense_pallas" => Some(Algo::DensePallas),
            _ => None,
        }
    }
}

/// One SpDM request: C = A·B with A treated as sparse.
#[derive(Clone, Debug)]
pub struct SpdmRequest {
    pub id: u64,
    pub a: Mat,
    pub b: Mat,
    /// Force a specific algorithm (None = selector decides).
    pub algo_hint: Option<Algo>,
    /// Verify the result against the CPU oracle (costs O(nnz·n)).
    pub verify: bool,
}

impl SpdmRequest {
    pub fn new(id: u64, a: Mat, b: Mat) -> Self {
        SpdmRequest { id, a, b, algo_hint: None, verify: false }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct SpdmResponse {
    pub id: u64,
    pub algo: Algo,
    pub artifact: String,
    /// Dimension the request was padded to.
    pub n_exec: usize,
    /// Extra overhead: dense→sparse conversion + padding (the paper's EO).
    pub convert_s: f64,
    /// Kernel execution (the paper's KC).
    pub kernel_s: f64,
    /// End-to-end including queueing.
    pub total_s: f64,
    pub verified: Option<bool>,
    pub error: Option<String>,
    /// The result matrix (trimmed back to the request's n).
    pub c: Option<Mat>,
}

impl SpdmResponse {
    pub fn failed(id: u64, algo: Algo, msg: String) -> Self {
        SpdmResponse {
            id,
            algo,
            artifact: String::new(),
            n_exec: 0,
            convert_s: 0.0,
            kernel_s: 0.0,
            total_s: 0.0,
            verified: None,
            error: Some(msg),
            c: None,
        }
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_round_trip() {
        for a in [Algo::Gcoo, Algo::GcooNoreuse, Algo::Csr, Algo::DenseXla, Algo::DensePallas] {
            assert_eq!(Algo::from_str(a.as_str()), Some(a));
        }
        assert_eq!(Algo::from_str("dense"), Some(Algo::DenseXla));
        assert_eq!(Algo::from_str("bogus"), None);
    }

    #[test]
    fn failed_response_reports_error() {
        let r = SpdmResponse::failed(7, Algo::Gcoo, "boom".into());
        assert!(!r.ok());
        assert_eq!(r.id, 7);
    }
}
