//! Job types crossing the coordinator boundary.

use crate::ndarray::Mat;

/// Algorithm families (defined next to the planner in `runtime::plan`,
/// re-exported here so coordinator users keep their import path).
pub use crate::runtime::Algo;

/// One SpDM request: C = A·B with A treated as sparse.
#[derive(Clone, Debug)]
pub struct SpdmRequest {
    pub id: u64,
    pub a: Mat,
    pub b: Mat,
    /// Force a specific algorithm (None = selector decides).
    pub algo_hint: Option<Algo>,
    /// Verify the result against the CPU oracle (costs O(nnz·n)).
    pub verify: bool,
}

impl SpdmRequest {
    pub fn new(id: u64, a: Mat, b: Mat) -> Self {
        SpdmRequest { id, a, b, algo_hint: None, verify: false }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct SpdmResponse {
    pub id: u64,
    pub algo: Algo,
    pub artifact: String,
    /// Dimension the request was padded to.
    pub n_exec: usize,
    /// Extra overhead: dense→sparse conversion + padding (the paper's EO).
    pub convert_s: f64,
    /// Kernel execution (the paper's KC).
    pub kernel_s: f64,
    /// End-to-end including queueing.
    pub total_s: f64,
    pub verified: Option<bool>,
    pub error: Option<String>,
    /// The result matrix (trimmed back to the request's n).
    pub c: Option<Mat>,
    /// Host bytes copied moving A/B/C through the pipeline (pads, trims,
    /// capacity re-pads). Zero on the steady-state matching-cap path.
    pub bytes_copied: u64,
    /// Materializations skipped by borrowing (matching-size B, matching-cap
    /// slabs, matching-size C moved out instead of trimmed).
    pub copies_avoided: u64,
}

impl SpdmResponse {
    pub fn failed(id: u64, algo: Algo, msg: String) -> Self {
        SpdmResponse {
            id,
            algo,
            artifact: String::new(),
            n_exec: 0,
            convert_s: 0.0,
            kernel_s: 0.0,
            total_s: 0.0,
            verified: None,
            error: Some(msg),
            c: None,
            bytes_copied: 0,
            copies_avoided: 0,
        }
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_round_trip() {
        for a in [Algo::Gcoo, Algo::GcooNoreuse, Algo::Csr, Algo::DenseXla, Algo::DensePallas] {
            assert_eq!(Algo::from_str(a.as_str()), Some(a));
        }
        assert_eq!(Algo::from_str("dense"), Some(Algo::DenseXla));
        assert_eq!(Algo::from_str("bogus"), None);
    }

    #[test]
    fn failed_response_reports_error() {
        let r = SpdmResponse::failed(7, Algo::Gcoo, "boom".into());
        assert!(!r.ok());
        assert_eq!(r.id, 7);
    }
}
