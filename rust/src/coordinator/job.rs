//! Job types crossing the coordinator boundary.

use super::store::{OperandEntry, OperandId};
use super::tenant::DEFAULT_TENANT;
use crate::ndarray::Mat;

/// Algorithm families (defined next to the planner in `runtime::plan`,
/// re-exported here so coordinator users keep their import path).
pub use crate::runtime::Algo;

/// Content signature of the A operand, computed **once at submit time** and
/// used as the batch-affinity key: two requests may share a fused batch
/// (one A conversion, one wide kernel) only when their signatures are
/// equal. Dimensions and nnz are stored outright so equality is trivially
/// sound on them; the value hash (FNV-1a over the f32 bit patterns, in
/// storage order) distinguishes same-shape/same-nnz matrices with
/// different content — the near-collision case the property tests pin.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ASig {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// FNV-1a64 over `(rows, cols, every element's to_bits())`.
    pub hash: u64,
}

impl ASig {
    pub fn of(a: &Mat) -> ASig {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(PRIME);
        };
        mix(a.rows as u64);
        mix(a.cols as u64);
        let mut nnz = 0usize;
        for &v in &a.data {
            if v != 0.0 {
                nnz += 1;
            }
            mix(v.to_bits() as u64);
        }
        ASig { rows: a.rows, cols: a.cols, nnz, hash: h }
    }
}

/// How a request supplies its A operand: shipped inline with the request,
/// or by reference to an A previously registered in the coordinator's
/// [`super::OperandStore`] (`put_a` on the wire). Handle requests pay no
/// A transfer, no signature hash, and no conversion — the store entry
/// already holds the converted device slabs at the planned capacity.
#[derive(Clone, Debug)]
pub enum AOperand {
    /// The dense A travels with the request (the v1 contract).
    Inline(Mat),
    /// Reference to a registered operand; resolved (and pinned) by
    /// [`super::Coordinator::submit`].
    Handle(OperandId),
}

impl AOperand {
    /// The inline matrix, when this operand carries one.
    pub fn as_inline(&self) -> Option<&Mat> {
        match self {
            AOperand::Inline(m) => Some(m),
            AOperand::Handle(_) => None,
        }
    }

    /// The operand handle, when this is a by-reference operand.
    pub fn handle(&self) -> Option<OperandId> {
        match self {
            AOperand::Inline(_) => None,
            AOperand::Handle(h) => Some(*h),
        }
    }
}

/// One SpDM request: C = A·B with A treated as sparse.
///
/// An inline `a` is treated as immutable after construction: the
/// batch-affinity signature is computed in [`SpdmRequest::new`], so
/// mutating it in place afterwards would let the batcher fuse requests
/// whose As differ. Build a fresh request instead.
#[derive(Clone, Debug)]
pub struct SpdmRequest {
    pub id: u64,
    pub a: AOperand,
    pub b: Mat,
    /// Force a specific algorithm (None = selector decides).
    pub algo_hint: Option<Algo>,
    /// Verify the result against the CPU oracle (costs O(nnz·n)).
    pub verify: bool,
    /// Batch-affinity key over A (see [`ASig`]): computed at construction
    /// for inline operands; for handle operands a placeholder until
    /// [`super::Coordinator::submit`] copies the store entry's signature in.
    pub a_sig: ASig,
    /// Owning tenant (ISSUE 9): the scheduling lane, token bucket, and
    /// store slice this request charges. [`DEFAULT_TENANT`] when absent
    /// on the wire — and batch affinity additionally requires equal
    /// tenants, so fusion never crosses a tenant boundary.
    pub tenant: String,
}

impl SpdmRequest {
    /// Inline-A request (the v1 constructor — unchanged call shape).
    pub fn new(id: u64, a: Mat, b: Mat) -> Self {
        let a_sig = ASig::of(&a);
        SpdmRequest {
            id,
            a: AOperand::Inline(a),
            b,
            algo_hint: None,
            verify: false,
            a_sig,
            tenant: DEFAULT_TENANT.to_string(),
        }
    }

    /// Handle-A request. The signature is a placeholder derived from the
    /// handle (never equal across distinct handles); `Coordinator::submit`
    /// replaces it with the registered entry's true content signature so
    /// mixed handle/inline traffic batches on equal content.
    pub fn for_handle(id: u64, handle: OperandId, b: Mat) -> Self {
        let a_sig = ASig { rows: 0, cols: 0, nnz: 0, hash: handle.0 };
        SpdmRequest {
            id,
            a: AOperand::Handle(handle),
            b,
            algo_hint: None,
            verify: false,
            a_sig,
            tenant: DEFAULT_TENANT.to_string(),
        }
    }

    /// Builder: tag the request with its owning tenant.
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    /// The dense A this request multiplies by: the inline payload, or the
    /// resolved store entry for handle requests. `None` when a handle has
    /// not been resolved (or `entry` belongs to a different handle).
    pub fn a_mat<'a>(&'a self, entry: Option<&'a OperandEntry>) -> Option<&'a Mat> {
        match &self.a {
            AOperand::Inline(m) => Some(m),
            AOperand::Handle(h) => entry.filter(|e| e.handle == *h).map(|e| &e.a),
        }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct SpdmResponse {
    pub id: u64,
    pub algo: Algo,
    pub artifact: String,
    /// Dimension the request was padded to.
    pub n_exec: usize,
    /// Extra overhead: dense→sparse conversion + padding (the paper's EO).
    pub convert_s: f64,
    /// Kernel execution (the paper's KC).
    pub kernel_s: f64,
    /// End-to-end including queueing.
    pub total_s: f64,
    pub verified: Option<bool>,
    pub error: Option<String>,
    /// The result matrix (trimmed back to the request's n).
    pub c: Option<Mat>,
    /// Host bytes copied moving A/B/C through the pipeline (pads, trims,
    /// capacity re-pads). Zero on the steady-state matching-cap path.
    pub bytes_copied: u64,
    /// Materializations skipped by borrowing (matching-size B, matching-cap
    /// slabs, matching-size C moved out instead of trimmed).
    pub copies_avoided: u64,
    /// Dense→sparse conversions this request actually performed: 1 on the
    /// inline sparse paths (the batch head for fused execution), 0 for
    /// handle requests served from cached slabs and for dense routing.
    pub conversions: u64,
}

impl SpdmResponse {
    pub fn failed(id: u64, algo: Algo, msg: String) -> Self {
        SpdmResponse {
            id,
            algo,
            artifact: String::new(),
            n_exec: 0,
            convert_s: 0.0,
            kernel_s: 0.0,
            total_s: 0.0,
            verified: None,
            error: Some(msg),
            c: None,
            bytes_copied: 0,
            copies_avoided: 0,
            conversions: 0,
        }
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_round_trip() {
        for a in [
            Algo::Gcoo,
            Algo::GcooNoreuse,
            Algo::Csr,
            Algo::DenseXla,
            Algo::DensePallas,
            Algo::Cmrs,
            Algo::RowSplit,
        ] {
            assert_eq!(Algo::from_str(a.as_str()), Some(a));
        }
        assert_eq!(Algo::from_str("dense"), Some(Algo::DenseXla));
        assert_eq!(Algo::from_str("bogus"), None);
    }

    #[test]
    fn failed_response_reports_error() {
        let r = SpdmResponse::failed(7, Algo::Gcoo, "boom".into());
        assert!(!r.ok());
        assert_eq!(r.id, 7);
    }

    #[test]
    fn a_sig_is_content_sensitive() {
        let mut rng = crate::rng::Rng::new(11);
        let a = Mat::randn(6, 6, &mut rng);
        assert_eq!(ASig::of(&a), ASig::of(&a.clone()), "equal matrices, equal signature");
        // Same dims, same nnz, one value changed: hash must differ.
        let mut a2 = a.clone();
        a2[(2, 3)] += 1.0;
        let (s1, s2) = (ASig::of(&a), ASig::of(&a2));
        assert_eq!((s1.rows, s1.cols, s1.nnz), (s2.rows, s2.cols, s2.nnz));
        assert_ne!(s1, s2, "value change must break the signature");
        // Different placement of the same values: storage-order hash differs.
        let b1 = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 2.0]);
        let b2 = Mat::from_vec(2, 2, vec![0.0, 1.0, 2.0, 0.0]);
        assert_ne!(ASig::of(&b1), ASig::of(&b2));
    }
}
