//! Serving metrics: throughput counters, latency distributions, the
//! fused-batch accounting (batch-width histogram + conversions amortized
//! by executing a shape-affine batch with one A conversion), and the
//! admission-window outcome counters (batches released full vs released
//! by the window timer — see `queue.rs::pop_batch_windowed`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::queue::WindowOutcome;
use crate::json::{self, Value};
use crate::ndarray::percentile;

/// Shared metrics sink (one per coordinator).
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub verify_failures: AtomicU64,
    /// Host bytes copied moving operands through the pipeline (pads,
    /// trims, capacity re-pads) — the traffic the workspace arenas exist
    /// to eliminate.
    pub bytes_copied: AtomicU64,
    /// Materializations skipped by borrowing (matching-size/matching-cap
    /// zero-copy paths).
    pub copies_avoided: AtomicU64,
    /// Amortization credit of the fused batch path: A conversions skipped
    /// relative to one-at-a-time execution, credited per dequeued batch
    /// from actual per-response accounting (jobs that would have converted
    /// solo minus conversions the batch really performed). Exact on every
    /// traffic mix: a width-w inline sparse batch credits w−1, dense
    /// batches credit 0, and handle traffic credits 0 (it converts zero
    /// whether fused or not — EO was paid at `put_a`).
    pub conversions_amortized: AtomicU64,
    /// Dense→sparse conversions actually performed (the paper's EO
    /// events): one per inline sparse request (one per *batch* under
    /// fusion), one per registered operand — and **zero** per
    /// multiply-by-handle, which is the whole point of the operand store.
    pub conversions_total: AtomicU64,
    /// Batch-width histogram: `batch_widths[w]` counts dequeued batches of
    /// width w (index 0 unused), so Σ w·batch_widths[w] = jobs processed.
    batch_widths: Mutex<Vec<u64>>,
    /// Admission-window batches released at full width (`Filled`).
    pub window_hits: AtomicU64,
    /// Admission-window batches released partial by the window elapsing
    /// (`TimedOut`). `Disabled` outcomes count in neither.
    pub window_timeouts: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    kernel_s: Mutex<Vec<f64>>,
    convert_s: Mutex<Vec<f64>>,
    started: Instant,
    per_algo: Mutex<std::collections::HashMap<&'static str, u64>>,
    /// Per-tenant admission rejections: tenant → (rate_limited,
    /// quota_exceeded). The aggregate error counter never distinguished
    /// which tenant was being throttled — the tenant-blind `/stats` bug
    /// this splits open.
    tenant_rejections: Mutex<std::collections::HashMap<String, (u64, u64)>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            verify_failures: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            copies_avoided: AtomicU64::new(0),
            conversions_amortized: AtomicU64::new(0),
            conversions_total: AtomicU64::new(0),
            batch_widths: Mutex::new(Vec::new()),
            window_hits: AtomicU64::new(0),
            window_timeouts: AtomicU64::new(0),
            latencies_s: Mutex::new(Vec::new()),
            kernel_s: Mutex::new(Vec::new()),
            convert_s: Mutex::new(Vec::new()),
            started: Instant::now(),
            per_algo: Mutex::new(std::collections::HashMap::new()),
            tenant_rejections: Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Count one token-bucket rejection against `tenant`.
    pub fn record_rate_limited(&self, tenant: &str) {
        self.tenant_rejections
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_insert((0, 0))
            .0 += 1;
    }

    /// Count one store-slice rejection against `tenant`.
    pub fn record_quota_exceeded(&self, tenant: &str) {
        self.tenant_rejections
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_insert((0, 0))
            .1 += 1;
    }

    /// Per-tenant rejection counters (tenant → (rate_limited,
    /// quota_exceeded)); `Coordinator::snapshot` merges these with the
    /// store/queue gauges into full [`TenantStat`] rows.
    pub fn tenant_rejections(&self) -> std::collections::HashMap<String, (u64, u64)> {
        self.tenant_rejections.lock().unwrap().clone()
    }

    pub fn record_completion(&self, algo: &'static str, total_s: f64, kernel_s: f64, convert_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_s.lock().unwrap().push(total_s);
        self.kernel_s.lock().unwrap().push(kernel_s);
        self.convert_s.lock().unwrap().push(convert_s);
        *self.per_algo.lock().unwrap().entry(algo).or_insert(0) += 1;
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A verified request disagreed with the CPU oracle.
    pub fn record_verify_failure(&self) {
        self.verify_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Accumulate one request's copy accounting.
    pub fn record_copy_traffic(&self, bytes_copied: u64, copies_avoided: u64) {
        self.bytes_copied.fetch_add(bytes_copied, Ordering::Relaxed);
        self.copies_avoided.fetch_add(copies_avoided, Ordering::Relaxed);
    }

    /// Record dense→sparse conversions actually performed (request paths
    /// report theirs per response; `put_a` registration reports its one).
    pub fn record_conversions(&self, count: u64) {
        if count > 0 {
            self.conversions_total.fetch_add(count, Ordering::Relaxed);
        }
    }

    /// Record one dequeued batch of `width` jobs in the width histogram.
    /// The amortization credit is reported separately via
    /// [`Metrics::record_amortized`] once the batch's responses reveal how
    /// many conversions it actually skipped.
    pub fn record_batch(&self, width: usize) {
        if width == 0 {
            return;
        }
        let mut hist = self.batch_widths.lock().unwrap();
        if hist.len() <= width {
            hist.resize(width + 1, 0);
        }
        hist[width] += 1;
    }

    /// Record how a windowed batch left the queue. `Disabled` (window off)
    /// is deliberately not counted: the counters then read all-zero and
    /// `/stats` shows the admission window is inert.
    pub fn record_window(&self, outcome: WindowOutcome) {
        match outcome {
            WindowOutcome::Disabled => {}
            WindowOutcome::Filled => {
                self.window_hits.fetch_add(1, Ordering::Relaxed);
            }
            WindowOutcome::TimedOut => {
                self.window_timeouts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Credit A conversions a batch skipped relative to one-at-a-time
    /// execution (computed by the worker from the batch's responses).
    pub fn record_amortized(&self, skipped: u64) {
        if skipped > 0 {
            self.conversions_amortized.fetch_add(skipped, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies_s.lock().unwrap().clone();
        let ker = self.kernel_s.lock().unwrap().clone();
        let conv = self.convert_s.lock().unwrap().clone();
        let elapsed = self.started.elapsed().as_secs_f64();
        let completed = self.completed.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            verify_failures: self.verify_failures.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            copies_avoided: self.copies_avoided.load(Ordering::Relaxed),
            conversions_amortized: self.conversions_amortized.load(Ordering::Relaxed),
            conversions_total: self.conversions_total.load(Ordering::Relaxed),
            store_entries: 0,
            store_bytes: 0,
            store_budget_bytes: 0,
            store_hits: 0,
            store_misses: 0,
            store_evictions: 0,
            spill_writes: 0,
            spill_promotes: 0,
            spill_bytes: 0,
            route_flips: 0,
            explorations: 0,
            window_hits: self.window_hits.load(Ordering::Relaxed),
            window_timeouts: self.window_timeouts.load(Ordering::Relaxed),
            batch_hist: self.batch_widths.lock().unwrap().clone(),
            throughput_rps: completed as f64 / elapsed.max(1e-9),
            p50_s: pct(&lat, 50.0),
            p95_s: pct(&lat, 95.0),
            p99_s: pct(&lat, 99.0),
            mean_kernel_s: mean(&ker),
            mean_convert_s: mean(&conv),
            per_algo: self.per_algo.lock().unwrap().clone(),
            tenants: {
                // Counter-only rows (bytes/lane gauges need the store and
                // queue, which a bare Metrics cannot see) — the
                // coordinator snapshot replaces these with full rows.
                let mut rows: Vec<TenantStat> = self
                    .tenant_rejections
                    .lock()
                    .unwrap()
                    .iter()
                    .map(|(name, &(rl, qe))| TenantStat {
                        name: name.clone(),
                        rate_limited: rl,
                        quota_exceeded: qe,
                        ..TenantStat::default()
                    })
                    .collect();
                rows.sort_by(|a, b| a.name.cmp(&b.name));
                rows
            },
        }
    }
}

fn pct(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, p)
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// One tenant's split of the serving gauges (ISSUE 10): resident store
/// bytes against the configured slice, admission rejections by kind, and
/// the DRR lane's live depth/deficit. Built by `Coordinator::snapshot`;
/// a bare `Metrics::snapshot` carries rejection counters only.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantStat {
    pub name: String,
    /// Store bytes currently charged to this tenant.
    pub bytes: u64,
    /// Configured store slice (0 = whole budget).
    pub slice_budget_bytes: u64,
    /// Requests/registrations refused by the token bucket.
    pub rate_limited: u64,
    /// Registrations refused by the store slice.
    pub quota_exceeded: u64,
    /// Jobs queued in this tenant's DRR lane right now.
    pub lane_depth: u64,
    /// The lane's signed DRR deficit (negative: owes rotation credit
    /// after a wide batch).
    pub lane_deficit: i64,
}

/// Point-in-time view for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub verify_failures: u64,
    pub bytes_copied: u64,
    pub copies_avoided: u64,
    pub conversions_amortized: u64,
    /// Dense→sparse conversions actually performed (EO events). Constant
    /// per handle under multiply-by-reference traffic: one at `put_a`,
    /// zero per subsequent handle request.
    pub conversions_total: u64,
    /// Operand-store gauges, filled by `Coordinator::snapshot` (zero from
    /// a bare `Metrics::snapshot`, which has no store in scope).
    pub store_entries: u64,
    pub store_bytes: u64,
    pub store_budget_bytes: u64,
    pub store_hits: u64,
    pub store_misses: u64,
    pub store_evictions: u64,
    /// Spill-tier gauges (ISSUE 9), filled by `Coordinator::snapshot` from
    /// the store's spill tier (zero from a bare `Metrics::snapshot`, and
    /// zero with no `spill_dir` configured): entries demoted to disk,
    /// entries promoted back by a handle miss, and file bytes resident in
    /// the tier right now.
    pub spill_writes: u64,
    pub spill_promotes: u64,
    pub spill_bytes: u64,
    /// Adaptive-routing counters, filled by `Coordinator::snapshot` from
    /// the tuner (zero from a bare `Metrics::snapshot`): model-driven
    /// route flips (entry republishes) and seeded exploration executions.
    pub route_flips: u64,
    pub explorations: u64,
    /// Admission-window outcome counters (zero when the window is off):
    /// batches released full vs released partial by the window timer.
    pub window_hits: u64,
    pub window_timeouts: u64,
    /// `batch_hist[w]` = dequeued batches of width w (index 0 unused).
    pub batch_hist: Vec<u64>,
    pub throughput_rps: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_kernel_s: f64,
    pub mean_convert_s: f64,
    pub per_algo: std::collections::HashMap<&'static str, u64>,
    /// Per-tenant splits, sorted by tenant name (empty on untenanted
    /// coordinators with no recorded rejections).
    pub tenants: Vec<TenantStat>,
}

impl MetricsSnapshot {
    /// Jobs accounted by the batch-width histogram (Σ w·batch_hist[w]) —
    /// equals completed + errors once every dequeued batch is recorded.
    pub fn batched_jobs(&self) -> u64 {
        self.batch_hist
            .iter()
            .enumerate()
            .map(|(w, &count)| w as u64 * count)
            .sum()
    }

    /// Mean width of dequeued batches (Σ w·hist[w] / Σ hist[w]); 0.0 before
    /// any batch. The number the admission window exists to raise.
    pub fn mean_batch_width(&self) -> f64 {
        let batches: u64 = self.batch_hist.iter().sum();
        if batches == 0 {
            0.0
        } else {
            self.batched_jobs() as f64 / batches as f64
        }
    }

    pub fn render(&self) -> String {
        let mut tenants = String::new();
        for t in &self.tenants {
            tenants.push_str(&format!(
                "\ntenant:   {}: {} B of {} B slice / {} rate-limited / {} quota-exceeded / lane {} deep (deficit {})",
                t.name,
                t.bytes,
                t.slice_budget_bytes,
                t.rate_limited,
                t.quota_exceeded,
                t.lane_depth,
                t.lane_deficit,
            ));
        }
        format!(
            "requests: {} submitted / {} completed / {} errors / {} verify failures\n\
             latency:  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms\n\
             phases:   kernel {:.3} ms  convert {:.3} ms (means)\n\
             copies:   {} B copied / {} avoided (zero-copy borrows)\n\
             batches:  width hist {:?} (mean width {:.2}) / {} conversions amortized\n\
             window:   {} filled / {} timed out\n\
             store:    {} operands / {} B of {} B budget / {} hits / {} misses / {} evictions / {} conversions total\n\
             spill:    {} writes / {} promotes / {} B on disk\n\
             routing:  {} route flips / {} explorations\n\
             rate:     {:.1} req/s   per-algo: {:?}{tenants}",
            self.submitted,
            self.completed,
            self.errors,
            self.verify_failures,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.p99_s * 1e3,
            self.mean_kernel_s * 1e3,
            self.mean_convert_s * 1e3,
            self.bytes_copied,
            self.copies_avoided,
            self.batch_hist,
            self.mean_batch_width(),
            self.conversions_amortized,
            self.window_hits,
            self.window_timeouts,
            self.store_entries,
            self.store_bytes,
            self.store_budget_bytes,
            self.store_hits,
            self.store_misses,
            self.store_evictions,
            self.conversions_total,
            self.spill_writes,
            self.spill_promotes,
            self.spill_bytes,
            self.route_flips,
            self.explorations,
            self.throughput_rps,
            self.per_algo,
        )
    }

    /// Structured JSON form (the serve `stats` reply). Every counter the
    /// text `render` shows, machine-readable; `batch_hist` is the width
    /// histogram array (index = batch width, index 0 unused).
    pub fn to_json(&self) -> String {
        let hist = Value::Arr(self.batch_hist.iter().map(|&c| Value::from(c)).collect());
        let per_algo = Value::Obj(
            self.per_algo
                .iter()
                .map(|(k, v)| (k.to_string(), Value::from(*v)))
                .collect(),
        );
        let tenants = Value::Arr(
            self.tenants
                .iter()
                .map(|t| {
                    Value::obj()
                        .field("name", t.name.as_str())
                        .field("bytes", t.bytes)
                        .field("slice_budget_bytes", t.slice_budget_bytes)
                        .field("rate_limited", t.rate_limited)
                        .field("quota_exceeded", t.quota_exceeded)
                        .field("lane_depth", t.lane_depth)
                        .field("lane_deficit", t.lane_deficit)
                        .build()
                })
                .collect(),
        );
        json::write(
            &Value::obj()
                .field("submitted", self.submitted)
                .field("completed", self.completed)
                .field("errors", self.errors)
                .field("verify_failures", self.verify_failures)
                .field("bytes_copied", self.bytes_copied)
                .field("copies_avoided", self.copies_avoided)
                .field("conversions_amortized", self.conversions_amortized)
                .field("conversions_total", self.conversions_total)
                .field("store_entries", self.store_entries)
                .field("store_bytes", self.store_bytes)
                .field("store_budget_bytes", self.store_budget_bytes)
                .field("store_hits", self.store_hits)
                .field("store_misses", self.store_misses)
                .field("store_evictions", self.store_evictions)
                .field("spill_writes", self.spill_writes)
                .field("spill_promotes", self.spill_promotes)
                .field("spill_bytes", self.spill_bytes)
                .field("route_flips", self.route_flips)
                .field("explorations", self.explorations)
                .field("window_hits", self.window_hits)
                .field("window_timeouts", self.window_timeouts)
                .field("batch_hist", hist)
                .field("mean_batch_width", self.mean_batch_width())
                .field("throughput_rps", self.throughput_rps)
                .field("p50_ms", self.p50_s * 1e3)
                .field("p95_ms", self.p95_s * 1e3)
                .field("p99_ms", self.p99_s * 1e3)
                .field("mean_kernel_ms", self.mean_kernel_s * 1e3)
                .field("mean_convert_ms", self.mean_convert_s * 1e3)
                .field("per_algo", per_algo)
                .field("tenants", tenants)
                .build(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion("gcoo", 0.010, 0.004, 0.002);
        m.record_completion("gcoo", 0.020, 0.008, 0.004);
        m.record_completion("dense_xla", 0.030, 0.030, 0.0);
        m.record_error();
        m.record_verify_failure();
        m.record_copy_traffic(4096, 3);
        m.record_copy_traffic(0, 2);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.verify_failures, 1);
        assert_eq!(s.bytes_copied, 4096);
        assert_eq!(s.copies_avoided, 5);
        assert_eq!(s.per_algo["gcoo"], 2);
        assert!((s.p50_s - 0.020).abs() < 1e-12);
        assert!(s.throughput_rps > 0.0);
        assert!(s.render().contains("4096 B copied / 5 avoided"));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_s, 0.0);
        assert_eq!(s.conversions_amortized, 0);
        assert_eq!(s.batched_jobs(), 0);
        assert!(s.render().contains("0 completed"));
    }

    #[test]
    fn batch_histogram_and_amortized_conversions() {
        let m = Metrics::new();
        // Batches of widths 3, 1, 3, 5 → 12 jobs; the all-inline-sparse
        // worker credit for those widths is (2+0+2+4)=8 amortized.
        for w in [3usize, 1, 3, 5] {
            m.record_batch(w);
            m.record_amortized((w - 1) as u64);
        }
        m.record_batch(0); // ignored
        m.record_amortized(0); // no-op
        let s = m.snapshot();
        assert_eq!(s.batch_hist[1], 1);
        assert_eq!(s.batch_hist[3], 2);
        assert_eq!(s.batch_hist[5], 1);
        assert_eq!(s.batched_jobs(), 12);
        assert_eq!(s.conversions_amortized, 8);
        assert!(s.render().contains("8 conversions amortized"));
    }

    #[test]
    fn snapshot_json_carries_batch_counters() {
        let m = Metrics::new();
        m.record_completion("gcoo", 0.010, 0.004, 0.002);
        m.record_batch(4);
        m.record_amortized(3);
        let text = m.snapshot().to_json();
        let v = crate::json::parse(&text).expect("stats snapshot is valid JSON");
        assert_eq!(v.get("completed").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("conversions_amortized").unwrap().as_u64(), Some(3));
        let hist = v.get("batch_hist").unwrap().as_arr().unwrap();
        assert_eq!(hist[4].as_u64(), Some(1));
        assert_eq!(v.get("per_algo").unwrap().get("gcoo").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn window_outcomes_count_and_surface() {
        let m = Metrics::new();
        m.record_window(WindowOutcome::Filled);
        m.record_window(WindowOutcome::Filled);
        m.record_window(WindowOutcome::TimedOut);
        m.record_window(WindowOutcome::Disabled); // counted nowhere
        m.record_batch(4);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.window_hits, 2);
        assert_eq!(s.window_timeouts, 1);
        assert!((s.mean_batch_width() - 3.0).abs() < 1e-12);
        assert!(s.render().contains("2 filled / 1 timed out"));
        assert!(s.render().contains("(mean width 3.00)"));
        let v = crate::json::parse(&s.to_json()).unwrap();
        assert_eq!(v.get("window_hits").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("window_timeouts").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("mean_batch_width").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn tenant_rejections_split_and_surface() {
        let m = Metrics::new();
        m.record_rate_limited("alpha");
        m.record_rate_limited("alpha");
        m.record_quota_exceeded("beta");
        let mut s = m.snapshot();
        assert_eq!(s.tenants.len(), 2, "one row per tenant, sorted");
        assert_eq!(s.tenants[0].name, "alpha");
        assert_eq!((s.tenants[0].rate_limited, s.tenants[0].quota_exceeded), (2, 0));
        assert_eq!(s.tenants[1].name, "beta");
        assert_eq!((s.tenants[1].rate_limited, s.tenants[1].quota_exceeded), (0, 1));
        // Fill the gauges Coordinator::snapshot merges in; render and JSON
        // must carry every field.
        s.tenants[0].bytes = 2048;
        s.tenants[0].slice_budget_bytes = 4096;
        s.tenants[0].lane_depth = 3;
        s.tenants[0].lane_deficit = -2;
        assert!(s.render().contains(
            "alpha: 2048 B of 4096 B slice / 2 rate-limited / 0 quota-exceeded / lane 3 deep (deficit -2)"
        ));
        let v = crate::json::parse(&s.to_json()).unwrap();
        let ts = v.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].get("name").unwrap().as_str(), Some("alpha"));
        assert_eq!(ts[0].get("bytes").unwrap().as_u64(), Some(2048));
        assert_eq!(ts[0].get("slice_budget_bytes").unwrap().as_u64(), Some(4096));
        assert_eq!(ts[0].get("rate_limited").unwrap().as_u64(), Some(2));
        assert_eq!(ts[0].get("lane_depth").unwrap().as_u64(), Some(3));
        assert_eq!(ts[0].get("lane_deficit").unwrap().as_f64(), Some(-2.0));
        assert_eq!(ts[1].get("quota_exceeded").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn conversion_and_store_counters_surface() {
        let m = Metrics::new();
        m.record_conversions(1);
        m.record_conversions(0); // no-op
        m.record_conversions(2);
        let mut s = m.snapshot();
        assert_eq!(s.conversions_total, 3);
        // Store gauges are merged in by Coordinator::snapshot; simulate.
        s.store_entries = 2;
        s.store_bytes = 4096;
        s.store_budget_bytes = 8192;
        s.store_hits = 7;
        s.store_misses = 1;
        s.store_evictions = 1;
        s.spill_writes = 4;
        s.spill_promotes = 2;
        s.spill_bytes = 1024;
        s.route_flips = 2;
        s.explorations = 5;
        assert!(s.render().contains("2 operands / 4096 B of 8192 B budget"));
        assert!(s.render().contains("3 conversions total"));
        assert!(s.render().contains("4 writes / 2 promotes / 1024 B on disk"));
        assert!(s.render().contains("2 route flips / 5 explorations"));
        let v = crate::json::parse(&s.to_json()).unwrap();
        assert_eq!(v.get("conversions_total").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("spill_writes").unwrap().as_u64(), Some(4));
        assert_eq!(v.get("spill_promotes").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("spill_bytes").unwrap().as_u64(), Some(1024));
        assert_eq!(v.get("route_flips").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("explorations").unwrap().as_u64(), Some(5));
        assert_eq!(v.get("store_hits").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("store_bytes").unwrap().as_u64(), Some(4096));
        assert_eq!(v.get("store_evictions").unwrap().as_u64(), Some(1));
    }
}
