//! Serving metrics: throughput counters and latency distributions.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::ndarray::percentile;

/// Shared metrics sink (one per coordinator).
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    pub verify_failures: AtomicU64,
    /// Host bytes copied moving operands through the pipeline (pads,
    /// trims, capacity re-pads) — the traffic the workspace arenas exist
    /// to eliminate.
    pub bytes_copied: AtomicU64,
    /// Materializations skipped by borrowing (matching-size/matching-cap
    /// zero-copy paths).
    pub copies_avoided: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    kernel_s: Mutex<Vec<f64>>,
    convert_s: Mutex<Vec<f64>>,
    started: Instant,
    per_algo: Mutex<std::collections::HashMap<&'static str, u64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            verify_failures: AtomicU64::new(0),
            bytes_copied: AtomicU64::new(0),
            copies_avoided: AtomicU64::new(0),
            latencies_s: Mutex::new(Vec::new()),
            kernel_s: Mutex::new(Vec::new()),
            convert_s: Mutex::new(Vec::new()),
            started: Instant::now(),
            per_algo: Mutex::new(std::collections::HashMap::new()),
        }
    }

    pub fn record_completion(&self, algo: &'static str, total_s: f64, kernel_s: f64, convert_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_s.lock().unwrap().push(total_s);
        self.kernel_s.lock().unwrap().push(kernel_s);
        self.convert_s.lock().unwrap().push(convert_s);
        *self.per_algo.lock().unwrap().entry(algo).or_insert(0) += 1;
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// A verified request disagreed with the CPU oracle.
    pub fn record_verify_failure(&self) {
        self.verify_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Accumulate one request's copy accounting.
    pub fn record_copy_traffic(&self, bytes_copied: u64, copies_avoided: u64) {
        self.bytes_copied.fetch_add(bytes_copied, Ordering::Relaxed);
        self.copies_avoided.fetch_add(copies_avoided, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies_s.lock().unwrap().clone();
        let ker = self.kernel_s.lock().unwrap().clone();
        let conv = self.convert_s.lock().unwrap().clone();
        let elapsed = self.started.elapsed().as_secs_f64();
        let completed = self.completed.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            verify_failures: self.verify_failures.load(Ordering::Relaxed),
            bytes_copied: self.bytes_copied.load(Ordering::Relaxed),
            copies_avoided: self.copies_avoided.load(Ordering::Relaxed),
            throughput_rps: completed as f64 / elapsed.max(1e-9),
            p50_s: pct(&lat, 50.0),
            p95_s: pct(&lat, 95.0),
            p99_s: pct(&lat, 99.0),
            mean_kernel_s: mean(&ker),
            mean_convert_s: mean(&conv),
            per_algo: self.per_algo.lock().unwrap().clone(),
        }
    }
}

fn pct(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, p)
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Point-in-time view for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub verify_failures: u64,
    pub bytes_copied: u64,
    pub copies_avoided: u64,
    pub throughput_rps: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_kernel_s: f64,
    pub mean_convert_s: f64,
    pub per_algo: std::collections::HashMap<&'static str, u64>,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests: {} submitted / {} completed / {} errors / {} verify failures\n\
             latency:  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms\n\
             phases:   kernel {:.3} ms  convert {:.3} ms (means)\n\
             copies:   {} B copied / {} avoided (zero-copy borrows)\n\
             rate:     {:.1} req/s   per-algo: {:?}",
            self.submitted,
            self.completed,
            self.errors,
            self.verify_failures,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.p99_s * 1e3,
            self.mean_kernel_s * 1e3,
            self.mean_convert_s * 1e3,
            self.bytes_copied,
            self.copies_avoided,
            self.throughput_rps,
            self.per_algo,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.record_completion("gcoo", 0.010, 0.004, 0.002);
        m.record_completion("gcoo", 0.020, 0.008, 0.004);
        m.record_completion("dense_xla", 0.030, 0.030, 0.0);
        m.record_error();
        m.record_verify_failure();
        m.record_copy_traffic(4096, 3);
        m.record_copy_traffic(0, 2);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.verify_failures, 1);
        assert_eq!(s.bytes_copied, 4096);
        assert_eq!(s.copies_avoided, 5);
        assert_eq!(s.per_algo["gcoo"], 2);
        assert!((s.p50_s - 0.020).abs() < 1e-12);
        assert!(s.throughput_rps > 0.0);
        assert!(s.render().contains("4096 B copied / 5 avoided"));
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_s, 0.0);
        assert!(s.render().contains("0 completed"));
    }
}
