//! Multi-tenant admission control: tenant specs, token-bucket rate
//! limiting, and the quota/backpressure error grammar.
//!
//! A tenant id rides on both wire planes (an optional `tenant` field in
//! JSON v2, a length-prefixed slot in binary v3 frames) and defaults to
//! [`DEFAULT_TENANT`] when absent, so every pre-tenancy client stays
//! byte-compatible. The registry owns three per-tenant knobs:
//!
//! * **weight** — the deficit-round-robin quantum used by the laned
//!   [`super::BoundedQueue`] (fusion stays within a tenant's lane);
//! * **rate / burst** — a token bucket consulted by `submit`/`put_a`;
//!   an empty bucket yields a typed [`RATE_LIMITED`] error that never
//!   closes the connection;
//! * **store slice** — the byte budget `OperandStore` lets this tenant
//!   occupy; registrations beyond it can evict only the tenant's own
//!   entries and otherwise fail with a typed [`QUOTA_EXCEEDED`] error.
//!
//! Admission may change *scheduling order and residency*, never result
//! bits: a request that is admitted computes exactly what it would have
//! computed untenanted.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::tuner::Clock;

/// The tenant every request without an explicit id belongs to.
pub const DEFAULT_TENANT: &str = "default";

/// Error-grammar prefix for token-bucket rejections.
pub const RATE_LIMITED: &str = "RATE_LIMITED";

/// Error-grammar prefix for store-slice rejections.
pub const QUOTA_EXCEEDED: &str = "QUOTA_EXCEEDED";

/// Wire-level ceiling on tenant-id length: the binary plane carries the
/// id behind a u8 length prefix, and the JSON plane enforces the same
/// bound for parity.
pub const MAX_TENANT_LEN: usize = 255;

/// Per-tenant admission knobs. A zero `rate_per_s` means unlimited (no
/// bucket, no clock reads); a zero `store_slice_bytes` means the tenant
/// may use the whole store budget (the pre-tenancy behavior).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// DRR quantum (items per round-robin visit). Clamped to >= 1.
    pub weight: u32,
    /// Token refill rate in requests per second; 0 = unlimited.
    pub rate_per_s: f64,
    /// Bucket capacity (maximum burst); 0 falls back to `rate_per_s`
    /// rounded up, so a configured rate always admits at least one.
    pub burst: f64,
    /// Store-budget slice in bytes; 0 = the whole store budget.
    pub store_slice_bytes: u64,
}

impl TenantSpec {
    /// An unlimited spec: weight 1, no rate limit, whole-budget slice.
    pub fn unlimited(name: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight: 1,
            rate_per_s: 0.0,
            burst: 0.0,
            store_slice_bytes: 0,
        }
    }

    fn burst_cap(&self) -> f64 {
        if self.burst > 0.0 {
            self.burst
        } else {
            self.rate_per_s.ceil().max(1.0)
        }
    }
}

struct Bucket {
    tokens: f64,
    last_s: f64,
    primed: bool,
}

/// Registry of tenant specs plus the live token buckets. Unknown tenant
/// names share the `default` tenant's spec (and its bucket), so a typo'd
/// id degrades to default-tenant treatment instead of a hole in the
/// admission wall.
pub struct TenantRegistry {
    specs: HashMap<String, TenantSpec>,
    default_spec: TenantSpec,
    buckets: Mutex<HashMap<String, Bucket>>,
    clock: Arc<dyn Clock>,
}

impl TenantRegistry {
    /// Build from configured specs. A `default` spec is synthesized
    /// (unlimited) when none is supplied, so the registry always has a
    /// fallback identity.
    pub fn new(tenants: &[TenantSpec], clock: Arc<dyn Clock>) -> TenantRegistry {
        let mut specs: HashMap<String, TenantSpec> = HashMap::new();
        for t in tenants {
            let mut spec = t.clone();
            spec.weight = spec.weight.max(1);
            specs.insert(spec.name.clone(), spec);
        }
        let default_spec = specs
            .get(DEFAULT_TENANT)
            .cloned()
            .unwrap_or_else(|| TenantSpec::unlimited(DEFAULT_TENANT));
        specs.entry(DEFAULT_TENANT.to_string()).or_insert_with(|| default_spec.clone());
        TenantRegistry { specs, default_spec, buckets: Mutex::new(HashMap::new()), clock }
    }

    /// Registry with only the unlimited default tenant (pre-tenancy
    /// behavior; never reads the clock).
    pub fn default_only(clock: Arc<dyn Clock>) -> TenantRegistry {
        TenantRegistry::new(&[], clock)
    }

    /// The spec governing `tenant` (the default spec for unknown names).
    pub fn spec_of(&self, tenant: &str) -> &TenantSpec {
        self.specs.get(tenant).unwrap_or(&self.default_spec)
    }

    /// The accounting identity `tenant` resolves to: its own name when
    /// configured, otherwise [`DEFAULT_TENANT`] (unknown tenants share
    /// the default bucket and slice rather than minting fresh ones).
    pub fn resolve_owned(&self, tenant: &str) -> String {
        if self.specs.contains_key(tenant) {
            tenant.to_string()
        } else {
            DEFAULT_TENANT.to_string()
        }
    }

    /// DRR weight for `tenant`.
    pub fn weight_of(&self, tenant: &str) -> u32 {
        self.spec_of(tenant).weight.max(1)
    }

    /// Store-budget slice for `tenant` (0 = whole budget).
    pub fn slice_of(&self, tenant: &str) -> u64 {
        self.spec_of(tenant).store_slice_bytes
    }

    /// `(name, weight)` pairs for configuring queue lanes, default lane
    /// included. Empty when no tenants are configured: the queue then
    /// stays in its single-deque (pre-tenancy) mode.
    pub fn lanes(&self) -> Vec<(String, u32)> {
        if self.specs.len() == 1 && self.default_spec == TenantSpec::unlimited(DEFAULT_TENANT) {
            return Vec::new();
        }
        let mut lanes: Vec<(String, u32)> =
            self.specs.iter().map(|(n, s)| (n.clone(), s.weight.max(1))).collect();
        lanes.sort();
        lanes
    }

    /// Whether any tenant is configured beyond the unlimited default.
    pub fn is_multi(&self) -> bool {
        !self.lanes().is_empty()
    }

    /// Token-bucket admission for one request from `tenant`. Unlimited
    /// tenants (rate 0) are admitted without reading the clock, so
    /// scripted-clock tests of untenanted coordinators observe zero
    /// extra reads. Returns the typed `RATE_LIMITED: ...` message on
    /// rejection; the caller surfaces it as an error frame / JSON error
    /// and keeps the connection open.
    pub fn admit(&self, tenant: &str) -> Result<(), String> {
        let spec = self.spec_of(tenant);
        if spec.rate_per_s <= 0.0 {
            return Ok(());
        }
        let now = self.clock.now_s();
        let cap = spec.burst_cap();
        let mut g = self.buckets.lock().unwrap();
        let b = g.entry(spec.name.clone()).or_insert(Bucket {
            tokens: cap,
            last_s: now,
            primed: false,
        });
        if b.primed {
            let dt = (now - b.last_s).max(0.0);
            b.tokens = (b.tokens + dt * spec.rate_per_s).min(cap);
        } else {
            b.primed = true;
        }
        b.last_s = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err(format!(
                "{}: tenant `{}` over {} req/s (burst {})",
                RATE_LIMITED, spec.name, spec.rate_per_s, cap
            ))
        }
    }
}

impl std::fmt::Debug for TenantRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut names: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        names.sort();
        f.debug_struct("TenantRegistry").field("tenants", &names).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tuner::ScriptedClock;

    fn spec(name: &str, weight: u32, rate: f64, burst: f64, slice: u64) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight,
            rate_per_s: rate,
            burst,
            store_slice_bytes: slice,
        }
    }

    #[test]
    fn default_only_registry_is_unlimited_and_laneless() {
        let clock = Arc::new(ScriptedClock::new(vec![]));
        let reg = TenantRegistry::default_only(clock.clone());
        assert!(!reg.is_multi());
        assert!(reg.lanes().is_empty());
        for _ in 0..100 {
            assert!(reg.admit(DEFAULT_TENANT).is_ok());
            assert!(reg.admit("anyone").is_ok());
        }
        assert_eq!(clock.reads(), 0, "unlimited tenants must not read the clock");
        assert_eq!(reg.slice_of("anyone"), 0);
        assert_eq!(reg.weight_of("anyone"), 1);
    }

    #[test]
    fn token_bucket_rejects_with_typed_error_and_refills() {
        // Scripted clock: bucket primed at t=0, flood, then advance 1s.
        let clock = Arc::new(ScriptedClock::with_step(vec![0.0, 0.0, 0.0, 0.0, 1.0], 0.0));
        let reg = TenantRegistry::new(&[spec("hot", 1, 2.0, 2.0, 0)], clock);
        assert!(reg.admit("hot").is_ok());
        assert!(reg.admit("hot").is_ok());
        let err = reg.admit("hot").unwrap_err();
        assert!(err.starts_with(RATE_LIMITED), "typed prefix, got: {err}");
        assert!(err.contains("`hot`"), "names the tenant: {err}");
        let err2 = reg.admit("hot").unwrap_err();
        assert!(err2.starts_with(RATE_LIMITED));
        // t=1.0: 2 req/s refill -> two more tokens.
        assert!(reg.admit("hot").is_ok());
    }

    #[test]
    fn burst_defaults_to_rate_and_unknown_names_share_default() {
        let clock = Arc::new(ScriptedClock::with_step(vec![0.0], 0.0));
        let reg = TenantRegistry::new(
            &[spec("default", 2, 1.0, 0.0, 4096), spec("alpha", 3, 0.0, 0.0, 1 << 20)],
            clock,
        );
        assert!(reg.is_multi());
        assert_eq!(reg.lanes(), vec![("alpha".to_string(), 3), ("default".to_string(), 2)]);
        // Unknown name resolves to default's spec: slice, weight, bucket.
        assert_eq!(reg.slice_of("mystery"), 4096);
        assert_eq!(reg.weight_of("mystery"), 2);
        assert_eq!(reg.resolve_owned("mystery"), "default");
        assert_eq!(reg.resolve_owned("alpha"), "alpha");
        assert!(reg.admit("mystery").is_ok(), "burst defaults to ceil(rate) = 1");
        let err = reg.admit("default").unwrap_err();
        assert!(err.starts_with(RATE_LIMITED));
        // Unknown names drained the shared default bucket.
        assert!(reg.admit("mystery").is_err());
        // alpha is unlimited.
        for _ in 0..10 {
            assert!(reg.admit("alpha").is_ok());
        }
    }

    #[test]
    fn weight_clamped_to_one() {
        let clock = Arc::new(ScriptedClock::new(vec![]));
        let reg = TenantRegistry::new(&[spec("z", 0, 0.0, 0.0, 0)], clock);
        assert_eq!(reg.weight_of("z"), 1);
    }
}
