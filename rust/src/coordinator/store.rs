//! Converted-operand store — the register-once / multiply-by-reference
//! half of the operand-handle API (ISSUE 4).
//!
//! The paper's whole argument is operations per byte of slow-memory
//! traffic: GCOOSpDM pays the conversion overhead (EO) once and then
//! maximizes reuse of the sparse operand. [`OperandStore`] makes that
//! reuse a first-class, cross-request contract: `put_a` registers A once —
//! one signature hash, one fused stats scan, one resolved [`ExecPlan`],
//! one conversion into device slabs at the planned capacity — and every
//! subsequent multiply-by-handle executes straight from the cached
//! [`DeviceOperand`], shipping only B.
//!
//! **Ownership rule (amends the workspace rule, DESIGN.md §1):** mutable
//! scratch stays strictly per worker (`Workspace`), but *immutable
//! converted operands are shared*: entries are `Arc`ed into workers, whose
//! engines borrow the cached slabs directly. Entries are frozen at
//! registration — nothing ever writes through the `Arc` — so concurrent
//! borrows from many workers are safe by construction (std-only, no
//! interior mutability on the data path).
//!
//! The store is byte-budgeted: registration evicts least-recently-used
//! entries until the new entry fits, never evicting an entry pinned by an
//! in-flight job (the pin is taken at submit and dropped after the reply),
//! and fails rather than exceed the budget when everything resident is
//! pinned. `drop_a` removes an entry immediately; jobs already holding the
//! `Arc` finish against their snapshot.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::job::{ASig, Algo};
use super::pool::CoordinatorConfig;
use super::selector::Selector;
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{DeviceOperand, ExecPlan, Registry};
use crate::sparse::{Ell, GcooPadded};

/// Opaque handle naming a registered A operand (the wire `a_handle`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OperandId(pub u64);

impl std::fmt::Display for OperandId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a#{}", self.0)
    }
}

/// One registered operand: the dense A (kept for the verification oracle
/// and the defensive batch re-screen), its content signature, the plan the
/// selector resolved at registration, and the already-converted device
/// form at the plan's capacity. Immutable after construction; shared into
/// workers via `Arc`.
#[derive(Debug)]
pub struct OperandEntry {
    pub handle: OperandId,
    pub a: Mat,
    pub sig: ASig,
    /// The algorithm hint registration was performed under (None = selector
    /// policy). Cached-slab execution requires a compatible hint — see
    /// [`OperandEntry::serves_hint`].
    pub hint: Option<Algo>,
    /// Resolved at registration, width 1 (the batch path widens a clone).
    pub plan: ExecPlan,
    /// The converted device form at `plan`'s capacity.
    pub operand: DeviceOperand,
    /// Registration-time conversion cost (the paper's EO, paid once here).
    pub convert_s: f64,
    /// Budget charge: dense A bytes + device-form bytes.
    pub bytes: u64,
    /// In-flight jobs currently holding this entry (eviction barrier).
    pins: AtomicUsize,
}

impl OperandEntry {
    pub fn pinned(&self) -> bool {
        self.pins.load(Ordering::SeqCst) > 0
    }

    /// Whether a request carrying `hint` can execute from the cached plan
    /// and slabs. An unhinted request always can — **the registered
    /// routing is the contract**: `put_a` resolved (and replied with) the
    /// plan, so multiply-by-handle runs it. An explicit hint must match
    /// the hint registration planned under (the selector is deterministic,
    /// so the cached plan is exactly what it would resolve — keeping the
    /// handle path bitwise identical to the same-hinted inline path) or
    /// name the planned algorithm outright. Any other hint falls back to
    /// the convert-per-request path using the entry's dense A.
    pub fn serves_hint(&self, hint: Option<Algo>) -> bool {
        hint.is_none() || hint == self.hint || hint == Some(self.plan.algo)
    }
}

/// Pin guard: holds the entry alive *and* marked in-flight so the LRU
/// evictor skips it. Taken by `Coordinator::submit`, dropped after the
/// worker replies.
#[derive(Debug)]
pub struct OperandPin {
    entry: Arc<OperandEntry>,
}

impl OperandPin {
    pub fn entry(&self) -> &OperandEntry {
        &self.entry
    }
}

impl std::ops::Deref for OperandPin {
    type Target = OperandEntry;
    fn deref(&self) -> &OperandEntry {
        &self.entry
    }
}

impl Drop for OperandPin {
    fn drop(&mut self) {
        self.entry.pins.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One row of `list_a`: enough for clients to introspect routing and cost.
#[derive(Clone, Debug, PartialEq)]
pub struct OperandSummary {
    pub handle: OperandId,
    pub n: usize,
    pub nnz: usize,
    pub algo: Algo,
    pub artifact: String,
    pub bytes: u64,
}

/// Point-in-time store counters (merged into `MetricsSnapshot`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub entries: u64,
    pub bytes: u64,
    pub budget_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct Slot {
    entry: Arc<OperandEntry>,
    last_used: u64,
}

struct Inner {
    entries: HashMap<u64, Slot>,
    next_id: u64,
    tick: u64,
    bytes: u64,
}

impl Inner {
    /// Locked dedup lookup: the resident entry with identical content
    /// (full element compare on signature match — a hash collision must
    /// not alias two operands) and hint, LRU-refreshed. Deliberately does
    /// NOT count a store hit: `hits`/`misses` measure served handle
    /// traffic (`checkout`/`peek_dims`), not `put_a` dedups.
    fn resident(&mut self, a: &Mat, sig: ASig, hint: Option<Algo>) -> Option<Arc<OperandEntry>> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self
            .entries
            .values_mut()
            .find(|s| s.entry.sig == sig && s.entry.hint == hint && s.entry.a.data == a.data)?;
        slot.last_used = tick;
        Some(Arc::clone(&slot.entry))
    }
}

/// The byte-budgeted, LRU-evicting converted-operand store. One per
/// coordinator, shared (`Arc`) with the serving front end.
pub struct OperandStore {
    budget: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inner: Mutex<Inner>,
}

impl OperandStore {
    pub fn new(budget_bytes: u64) -> Self {
        OperandStore {
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                next_id: 0,
                tick: 0,
                bytes: 0,
            }),
        }
    }

    /// Register an A operand: hash, scan, plan, convert (all outside the
    /// store lock), then insert under the byte budget, evicting LRU
    /// unpinned entries as needed. Registering content+hint already
    /// resident dedups to the existing handle (no second conversion).
    /// Returns the shared entry and whether a dense→sparse conversion was
    /// actually performed (`false` on dedup hits and dense routing; a
    /// race-losing duplicate that already converted before the in-lock
    /// dedup recheck reports `true` — the EO event happened).
    pub fn register(
        &self,
        a: Mat,
        hint: Option<Algo>,
        reg: &Registry,
        cfg: &CoordinatorConfig,
    ) -> Result<(Arc<OperandEntry>, bool), String> {
        let n = a.rows;
        if n == 0 || a.cols != n {
            return Err(format!("registered A must be square and non-empty, got {}x{}", a.rows, a.cols));
        }
        // Cheap lower bound before any work: the dense A alone already
        // charges a.data.len()*4 bytes, so an operand that cannot fit the
        // budget is rejected without paying the scan/conversion (a
        // server-exposed path should not burn work on doomed requests).
        if (a.data.len() * 4) as u64 > self.budget {
            return Err(format!(
                "operand (≥{} B dense) exceeds the store budget ({} B)",
                a.data.len() * 4,
                self.budget
            ));
        }
        let sig = ASig::of(&a);
        // Dedup: same content (full element compare on signature match —
        // a hash collision must not alias two operands) under the same
        // hint → the existing handle, refreshed in the LRU order.
        if let Some(entry) = self.find_resident(&a, sig, hint) {
            return Ok((entry, false));
        }

        // Plan first, then convert straight to the planned capacity — the
        // same plan-then-convert pipeline the per-request path uses.
        let t0 = Instant::now();
        let stats = convert::scan_stats(&a, cfg.gcoo_p, cfg.convert_threads);
        let selector = Selector::new(cfg.policy);
        let plan = selector.plan(
            reg,
            n,
            stats.sparsity(),
            stats.max_band_nnz(),
            stats.max_row_nnz,
            hint,
        )?;
        let operand = match plan.algo {
            Algo::Gcoo | Algo::GcooNoreuse => {
                let (mut vals, mut rows, mut cols) = (Vec::new(), Vec::new(), Vec::new());
                convert::dense_to_slabs_into(
                    &a,
                    &stats,
                    plan.n_exec,
                    plan.cap,
                    cfg.convert_threads,
                    &mut vals,
                    &mut rows,
                    &mut cols,
                )
                .map_err(|e| e.to_string())?;
                DeviceOperand::Gcoo(GcooPadded {
                    g: plan.n_exec.div_ceil(cfg.gcoo_p),
                    cap: plan.cap,
                    p: cfg.gcoo_p,
                    n: plan.n_exec,
                    vals,
                    rows,
                    cols,
                })
            }
            Algo::Csr => {
                let (mut vals, mut cols) = (Vec::new(), Vec::new());
                convert::dense_to_ell_into(&a, plan.n_exec, plan.cap, &mut vals, &mut cols)
                    .map_err(|e| e.to_string())?;
                DeviceOperand::Ell(Ell { n: plan.n_exec, rowcap: plan.cap, vals, cols })
            }
            Algo::DenseXla | Algo::DensePallas => {
                // "Conversion" here is the pad to execution size, done once
                // at registration like the sparse forms. A dense-routed
                // entry knowingly stores two copies of A (the original for
                // dedup/oracle/re-screen, the exec-sized pad for the
                // engine) and charges the budget for both — dense routing
                // has no EO to amortize, so registering it is a transfer
                // optimization only, and sharing one allocation would need
                // self-referential storage the std-only rule makes ugly.
                let mut a_exec = Mat::zeros(0, 0);
                a_exec.pad_from(&a, plan.n_exec);
                DeviceOperand::Dense(a_exec)
            }
        };
        let converted = plan.algo.is_sparse();
        let convert_s = t0.elapsed().as_secs_f64();
        let bytes = (a.data.len() * 4 + operand.bytes()) as u64;
        if bytes > self.budget {
            return Err(format!(
                "operand ({bytes} B) exceeds the store budget ({} B)",
                self.budget
            ));
        }

        let mut g = self.inner.lock().unwrap();
        // Re-check dedup under the insert lock: a concurrent registration
        // of the same content may have landed while this thread was
        // converting (the scan/convert runs unlocked). The duplicate
        // conversion is wasted work; a duplicate *entry* — double byte
        // charge, split batching — must not be. Unlike the early dedup
        // hit, this thread really did pay the scan/conversion, so the
        // `converted` flag reports it (conversions_total counts EO events
        // performed, not entries created).
        if let Some(existing) = g.resident(&a, sig, hint) {
            return Ok((existing, converted));
        }
        // Two-phase eviction: pick least-recently-used unpinned victims
        // until the new entry fits, and commit the removals only once it
        // provably does — a registration that cannot fit must not evict
        // anything (pins are an eviction barrier, not victims; observed-
        // unpinned entries cannot gain a pin while we hold the lock, since
        // `checkout` also locks).
        if g.bytes + bytes > self.budget {
            let mut victims: Vec<(u64, u64, u64)> = g
                .entries
                .iter()
                .filter(|(_, s)| !s.entry.pinned())
                .map(|(&id, s)| (s.last_used, id, s.entry.bytes))
                .collect();
            victims.sort_unstable();
            let mut freed = 0u64;
            let mut take = 0usize;
            while g.bytes - freed + bytes > self.budget && take < victims.len() {
                freed += victims[take].2;
                take += 1;
            }
            if g.bytes - freed + bytes > self.budget {
                return Err(format!(
                    "operand store budget exhausted ({} B resident, {} B of it pinned; \
                     a {} B entry cannot fit the {} B budget)",
                    g.bytes,
                    g.bytes - victims.iter().map(|v| v.2).sum::<u64>(),
                    bytes,
                    self.budget
                ));
            }
            for &(_, id, _) in &victims[..take] {
                let slot = g.entries.remove(&id).expect("victim resident");
                g.bytes -= slot.entry.bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        g.next_id += 1;
        g.tick += 1;
        let handle = OperandId(g.next_id);
        let entry = Arc::new(OperandEntry {
            handle,
            a,
            sig,
            hint,
            plan,
            operand,
            convert_s,
            bytes,
            pins: AtomicUsize::new(0),
        });
        g.bytes += bytes;
        let tick = g.tick;
        g.entries.insert(handle.0, Slot { entry: Arc::clone(&entry), last_used: tick });
        Ok((entry, converted))
    }

    /// Resident entry with this exact content and hint, LRU-refreshed
    /// (see [`Inner::resident`] — registration dedups are not store hits).
    fn find_resident(&self, a: &Mat, sig: ASig, hint: Option<Algo>) -> Option<Arc<OperandEntry>> {
        self.inner.lock().unwrap().resident(a, sig, hint)
    }

    /// Look up and pin an entry for an in-flight job (bumps the LRU order
    /// and the hit counter; a missing handle counts a miss).
    pub fn checkout(&self, h: OperandId) -> Option<OperandPin> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        match g.entries.get_mut(&h.0) {
            Some(slot) => {
                slot.last_used = tick;
                slot.entry.pins.fetch_add(1, Ordering::SeqCst);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(OperandPin { entry: Arc::clone(&slot.entry) })
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Dimension of a registered A without touching LRU order or the hit
    /// counter (the serve layer uses this to size synthetic B operands).
    /// An unknown handle still counts a store **miss** — wire-path
    /// rejections resolve here, before `checkout` ever runs, and must
    /// surface in the miss gauge.
    pub fn peek_dims(&self, h: OperandId) -> Option<usize> {
        let dims = self.inner.lock().unwrap().entries.get(&h.0).map(|s| s.entry.a.rows);
        if dims.is_none() {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        dims
    }

    /// Remove an entry (wire `drop_a`). In-flight jobs holding the `Arc`
    /// finish against their snapshot; later lookups miss. Returns whether
    /// the handle was resident.
    pub fn remove(&self, h: OperandId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.entries.remove(&h.0) {
            Some(slot) => {
                g.bytes -= slot.entry.bytes;
                true
            }
            None => false,
        }
    }

    /// Summaries of every resident entry, ordered by handle (wire `list_a`).
    pub fn list(&self) -> Vec<OperandSummary> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<OperandSummary> = g
            .entries
            .values()
            .map(|s| OperandSummary {
                handle: s.entry.handle,
                n: s.entry.a.rows,
                nnz: s.entry.sig.nnz,
                algo: s.entry.plan.algo,
                artifact: s.entry.plan.artifact.clone(),
                bytes: s.entry.bytes,
            })
            .collect();
        out.sort_by_key(|s| s.handle);
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes_used(&self) -> u64 {
        self.inner.lock().unwrap().bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    pub fn stats(&self) -> StoreStats {
        let g = self.inner.lock().unwrap();
        StoreStats {
            entries: g.entries.len() as u64,
            bytes: g.bytes,
            budget_bytes: self.budget,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::prop::{check, Config};
    use crate::rng::Rng;
    use std::path::PathBuf;

    /// Stub registry at n=64 (gcoo caps {64, 512}, csr, dense) backed by a
    /// real file so the engine could load it — matches the integration
    /// stubs.
    fn reg() -> Registry {
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
             "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
             "params": {}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig::default()
    }

    fn sparse_a(seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        gen::uniform(64, 0.99, &mut rng)
    }

    #[test]
    fn register_converts_once_and_dedups_same_content() {
        let store = OperandStore::new(64 << 20);
        let (e1, converted) = store.register(sparse_a(1), None, &reg(), &cfg()).unwrap();
        assert!(converted, "sparse registration performs the one conversion");
        assert_eq!(e1.plan.algo, Algo::Gcoo);
        assert!(matches!(e1.operand, DeviceOperand::Gcoo(_)));
        assert!(e1.convert_s > 0.0);
        assert_eq!(store.len(), 1);
        // Same content + hint → same handle, no second conversion.
        let (e2, converted) = store.register(sparse_a(1), None, &reg(), &cfg()).unwrap();
        assert!(!converted);
        assert_eq!(e2.handle, e1.handle);
        assert_eq!(store.len(), 1);
        // Different content → a fresh handle.
        let (e3, _) = store.register(sparse_a(2), None, &reg(), &cfg()).unwrap();
        assert_ne!(e3.handle, e1.handle);
        assert_eq!(store.len(), 2);
        // Same content, different hint → its own entry (different slabs).
        let (e4, _) = store.register(sparse_a(1), Some(Algo::Csr), &reg(), &cfg()).unwrap();
        assert_ne!(e4.handle, e1.handle);
        assert!(matches!(e4.operand, DeviceOperand::Ell(_)));
    }

    /// The hint contract: unhinted requests always run the registered
    /// plan; explicit hints are served from cache only when they match the
    /// registration hint or the planned algorithm.
    #[test]
    fn serves_hint_contract() {
        let store = OperandStore::new(64 << 20);
        let (hinted, _) = store.register(sparse_a(5), Some(Algo::Gcoo), &reg(), &cfg()).unwrap();
        assert!(hinted.serves_hint(None), "no hint → the registered routing applies");
        assert!(hinted.serves_hint(Some(Algo::Gcoo)));
        assert!(!hinted.serves_hint(Some(Algo::Csr)), "conflicting hint falls back");
        let (unhinted, _) = store.register(sparse_a(6), None, &reg(), &cfg()).unwrap();
        assert_eq!(unhinted.plan.algo, Algo::Gcoo, "0.99-sparse routes gcoo");
        assert!(unhinted.serves_hint(None));
        assert!(unhinted.serves_hint(Some(Algo::Gcoo)), "naming the planned algo is served");
        assert!(!unhinted.serves_hint(Some(Algo::DenseXla)));
    }

    #[test]
    fn checkout_pins_and_remove_hides() {
        let store = OperandStore::new(64 << 20);
        let (e, _) = store.register(sparse_a(3), None, &reg(), &cfg()).unwrap();
        assert!(!e.pinned());
        let pin = store.checkout(e.handle).expect("resident");
        assert!(e.pinned());
        assert_eq!(pin.entry().handle, e.handle);
        assert!(store.checkout(OperandId(9999)).is_none(), "unknown handle misses");
        let st = store.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        // peek_dims: no hit/LRU side effects on success, but an unknown
        // handle still counts a miss (the serve layer rejects there).
        assert_eq!(store.peek_dims(e.handle), Some(64));
        assert_eq!(store.peek_dims(OperandId(9999)), None);
        let st = store.stats();
        assert_eq!((st.hits, st.misses), (1, 2), "peek miss counts; peek hit does not");
        // Remove while pinned: later lookups miss, the pin's snapshot lives.
        assert!(store.remove(e.handle));
        assert!(!store.remove(e.handle), "double drop reports not-resident");
        assert!(store.checkout(e.handle).is_none());
        assert_eq!(pin.a.rows, 64, "in-flight snapshot survives the drop");
        drop(pin);
        assert!(!e.pinned());
        assert_eq!(store.bytes_used(), 0);
    }

    #[test]
    fn lru_eviction_order_and_budget() {
        // Budget sized for ~2 of these entries: the third registration must
        // evict the least recently *used* one (entry 1 was refreshed by a
        // checkout, so entry 2 is the victim).
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(10), None, &reg(), &cfg())
            .unwrap();
        let budget = e_probe.bytes * 5 / 2;
        let store = OperandStore::new(budget);
        let (e1, _) = store.register(sparse_a(10), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(11), None, &reg(), &cfg()).unwrap();
        drop(store.checkout(e1.handle)); // refresh e1 in the LRU order
        let (e3, _) = store.register(sparse_a(12), None, &reg(), &cfg()).unwrap();
        assert!(store.bytes_used() <= budget, "budget never exceeded");
        assert!(store.checkout(e2.handle).is_none(), "LRU victim evicted");
        assert!(store.checkout(e1.handle).is_some(), "recently-used entry survives");
        assert!(store.checkout(e3.handle).is_some());
        assert_eq!(store.stats().evictions, 1);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(20), None, &reg(), &cfg())
            .unwrap();
        // Room for one entry only.
        let store = OperandStore::new(e_probe.bytes * 3 / 2);
        let (e1, _) = store.register(sparse_a(20), None, &reg(), &cfg()).unwrap();
        let _pin = store.checkout(e1.handle).expect("resident");
        // The only resident entry is pinned: registration must refuse
        // rather than evict it or blow the budget.
        let err = store.register(sparse_a(21), None, &reg(), &cfg()).unwrap_err();
        assert!(err.contains("pinned"), "{err}");
        assert!(store.checkout(e1.handle).is_some(), "pinned entry survived");
        assert!(store.bytes_used() <= store.budget_bytes());
        // Unpinned, the same registration succeeds by evicting it.
        drop(_pin);
        drop(store.checkout(e1.handle));
        let (e2, _) = store.register(sparse_a(21), None, &reg(), &cfg()).unwrap();
        assert!(store.checkout(e1.handle).is_none());
        assert!(store.checkout(e2.handle).is_some());
    }

    #[test]
    fn oversized_operand_rejected_outright() {
        let store = OperandStore::new(1024); // smaller than any 64×64 entry
        let err = store.register(sparse_a(30), None, &reg(), &cfg()).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
        assert_eq!(store.len(), 0);
    }

    /// A registration that cannot fit even after evicting every unpinned
    /// entry must fail without evicting anything — a failed `put_a` must
    /// not shoot down operands that later handle traffic would re-resolve.
    #[test]
    fn failed_registration_evicts_nothing() {
        let probe = OperandStore::new(u64::MAX);
        let (small, _) = probe.register(sparse_a(60), None, &reg(), &cfg()).unwrap();
        let mut rng = Rng::new(61);
        let dense_a = gen::uniform(64, 0.5, &mut rng);
        let (big, _) = probe.register(dense_a.clone(), Some(Algo::Gcoo), &reg(), &cfg()).unwrap();
        assert!(big.bytes > 2 * small.bytes, "cap-512 entry dwarfs the cap-64 entry");
        let (s_bytes, b_bytes) = (small.bytes, big.bytes);

        // Residents: one unpinned small, one pinned small. The big entry
        // fits the budget alone but not alongside the pinned entry, so
        // registration must fail — and leave BOTH residents untouched
        // (the one-at-a-time evictor this regression pins would have
        // evicted the unpinned entry before discovering the failure).
        let store = OperandStore::new(b_bytes + s_bytes / 2);
        let (e1, _) = store.register(sparse_a(62), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(63), None, &reg(), &cfg()).unwrap();
        let _pin = store.checkout(e2.handle).expect("resident");
        let err = store.register(dense_a, Some(Algo::Gcoo), &reg(), &cfg()).unwrap_err();
        assert!(err.contains("pinned"), "{err}");
        assert_eq!(store.len(), 2, "failed registration must not evict");
        assert!(store.checkout(e1.handle).is_some(), "unpinned resident survives the failure");
        assert_eq!(store.stats().evictions, 0);
    }

    #[test]
    fn non_square_rejected() {
        let store = OperandStore::new(64 << 20);
        let a = Mat::zeros(8, 16);
        assert!(store.register(a, None, &reg(), &cfg()).is_err());
    }

    #[test]
    fn list_reports_routing() {
        let store = OperandStore::new(64 << 20);
        let (e1, _) = store.register(sparse_a(40), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(41), Some(Algo::Csr), &reg(), &cfg()).unwrap();
        let listed = store.list();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].handle, e1.handle);
        assert_eq!(listed[0].algo, Algo::Gcoo);
        assert_eq!(listed[1].handle, e2.handle);
        assert_eq!(listed[1].algo, Algo::Csr);
        assert!(listed.iter().all(|s| s.n == 64 && s.bytes > 0 && !s.artifact.is_empty()));
        assert_eq!(
            store.bytes_used(),
            listed.iter().map(|s| s.bytes).sum::<u64>(),
            "byte accounting matches the resident set"
        );
    }

    /// Property: under random register / checkout / remove interleavings
    /// the byte budget is never exceeded, accounting stays exact, and a
    /// held pin is never evicted.
    #[test]
    fn prop_budget_and_pin_invariants() {
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(50), None, &reg(), &cfg())
            .unwrap();
        let entry_bytes = e_probe.bytes;
        check(
            Config { cases: 16, base_seed: 0x570E, ..Default::default() },
            |g| {
                let slots = g.usize_in(2, 4); // budget in whole entries
                let ops: Vec<u8> = (0..g.usize_in(4, 16)).map(|_| g.rng.next_u64() as u8).collect();
                (slots, ops)
            },
            |(slots, ops)| {
                let store = OperandStore::new(entry_bytes * (*slots as u64) + entry_bytes / 2);
                let mut pins = Vec::new();
                let mut handles = Vec::new();
                for (i, op) in ops.iter().enumerate() {
                    match op % 4 {
                        0 | 1 => {
                            // Register fresh content; failure is legal only
                            // when everything resident is pinned.
                            match store.register(sparse_a(1000 + i as u64), None, &reg(), &cfg()) {
                                Ok((e, _)) => handles.push(e.handle),
                                Err(msg) => {
                                    if !msg.contains("pinned") {
                                        return Err(format!("unexpected register failure: {msg}"));
                                    }
                                }
                            }
                        }
                        2 => {
                            if let Some(&h) = handles.get(i % handles.len().max(1)) {
                                if let Some(p) = store.checkout(h) {
                                    pins.push(p);
                                }
                            }
                        }
                        _ => {
                            pins.pop(); // release an arbitrary pin
                        }
                    }
                    if store.bytes_used() > store.budget_bytes() {
                        return Err("byte budget exceeded".into());
                    }
                    for p in &pins {
                        if store.checkout(p.entry().handle).is_none() {
                            return Err("pinned entry was evicted".into());
                        }
                    }
                    // checkout() above pinned again and dropped immediately;
                    // drain those transient pins via the returned guards.
                }
                let expected: u64 =
                    store.list().iter().map(|s| s.bytes).sum();
                if store.bytes_used() != expected {
                    return Err("byte accounting drifted".into());
                }
                Ok(())
            },
        );
    }
}
