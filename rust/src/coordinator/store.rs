//! Converted-operand store — the register-once / multiply-by-reference
//! half of the operand-handle API (ISSUE 4).
//!
//! The paper's whole argument is operations per byte of slow-memory
//! traffic: GCOOSpDM pays the conversion overhead (EO) once and then
//! maximizes reuse of the sparse operand. [`OperandStore`] makes that
//! reuse a first-class, cross-request contract: `put_a` registers A once —
//! one signature hash, one fused stats scan, one resolved [`ExecPlan`],
//! one conversion into device slabs at the planned capacity — and every
//! subsequent multiply-by-handle executes straight from the cached
//! [`DeviceOperand`], shipping only B.
//!
//! **Ownership rule (amends the workspace rule, DESIGN.md §1):** mutable
//! scratch stays strictly per worker (`Workspace`), but *immutable
//! converted operands are shared*: entries are `Arc`ed into workers, whose
//! engines borrow the cached slabs directly. Entries are frozen at
//! registration — nothing ever writes through the `Arc` — so concurrent
//! borrows from many workers are safe by construction (std-only, no
//! interior mutability on the data path).
//!
//! The store is byte-budgeted: registration evicts least-recently-used
//! entries until the new entry fits, never evicting an entry pinned by an
//! in-flight job (the pin is taken at submit and dropped after the reply),
//! and fails rather than exceed the budget when everything resident is
//! pinned. `drop_a` removes an entry immediately; jobs already holding the
//! `Arc` finish against their snapshot.
//!
//! **Entry versioning (adaptive routing):** entries stay immutable, but a
//! handle's *published* entry can change — a model-driven route flip
//! ([`OperandStore::reroute`]) republishes the handle under the measured
//! favorite with a freshly converted device form, bumping `version` and
//! swapping the slot's `Arc`. Pins keep old versions alive untouched, so a
//! flip can never corrupt an in-flight job; stale flips (the slot already
//! moved on) are refused. A superseded version that is still pinned stays
//! **retired in its slot**: it keeps charging the byte budget (the memory
//! is genuinely resident) and keeps blocking eviction of the handle (a
//! flip must not lift the pin barrier an in-flight job relies on) until
//! its pins drop, at which point it is purged opportunistically under the
//! lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::job::{ASig, Algo};
use super::pool::CoordinatorConfig;
use super::selector::Selector;
use super::spill::SpillStore;
use super::tenant::{TenantRegistry, DEFAULT_TENANT, QUOTA_EXCEEDED};
use crate::convert::{self, AStats};
use crate::ndarray::Mat;
use crate::runtime::{DeviceOperand, ExecPlan, Registry};
use crate::simgpu::{self, GcooStructure, WalkConfig};
use crate::sparse::{CmrsPadded, Ell, Gcoo, GcooPadded, RowSplitPadded};

/// Opaque handle naming a registered A operand (the wire `a_handle`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OperandId(pub u64);

impl std::fmt::Display for OperandId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a#{}", self.0)
    }
}

/// One registered operand: the dense A (kept for the verification oracle
/// and the defensive batch re-screen), its content signature, the plan the
/// selector resolved at registration, and the already-converted device
/// form at the plan's capacity. Immutable after construction; shared into
/// workers via `Arc`.
#[derive(Debug)]
pub struct OperandEntry {
    pub handle: OperandId,
    /// Owning tenant (accounting identity; [`DEFAULT_TENANT`] untenanted).
    /// Eviction pressure from one tenant's registrations can only claim
    /// victims with the same owner — slice isolation (ISSUE 9).
    pub tenant: String,
    pub a: Mat,
    pub sig: ASig,
    /// The algorithm hint registration was performed under (None = selector
    /// policy). Cached-slab execution requires a compatible hint — see
    /// [`OperandEntry::serves_hint`].
    pub hint: Option<Algo>,
    /// Registration-time scan stats (sparsity + band/row counts). The
    /// entry is immutable, so explorations and route flips reuse these
    /// instead of re-scanning the O(n²) dense A.
    pub stats: AStats,
    /// Resolved at registration, width 1 (the batch path widens a clone).
    pub plan: ExecPlan,
    /// Ranked plan list (the published plan first, then every other
    /// resolvable family): what the tuner explores and flips between.
    /// Hinted registrations never explore, so their list is `plan` alone.
    pub candidates: Vec<ExecPlan>,
    /// The converted device form at `plan`'s capacity.
    pub operand: DeviceOperand,
    /// Registration-time conversion cost (the paper's EO, paid once here).
    pub convert_s: f64,
    /// Budget charge: dense A bytes + device-form bytes.
    pub bytes: u64,
    /// Publication version of this handle: 1 at registration, bumped by
    /// each route-flip republish ([`OperandStore::reroute`]).
    pub version: u64,
    /// In-flight jobs currently holding this entry (eviction barrier).
    pins: AtomicUsize,
}

impl OperandEntry {
    pub fn pinned(&self) -> bool {
        self.pins.load(Ordering::SeqCst) > 0
    }

    /// Whether a request carrying `hint` can execute from the cached plan
    /// and slabs. An unhinted request always can — **the registered
    /// routing is the contract**: `put_a` resolved (and replied with) the
    /// plan, so multiply-by-handle runs it. An explicit hint must match
    /// the hint registration planned under (the selector is deterministic,
    /// so the cached plan is exactly what it would resolve — keeping the
    /// handle path bitwise identical to the same-hinted inline path) or
    /// name the planned algorithm outright. Any other hint falls back to
    /// the convert-per-request path using the entry's dense A.
    pub fn serves_hint(&self, hint: Option<Algo>) -> bool {
        hint.is_none() || hint == self.hint || hint == Some(self.plan.algo)
    }
}

/// Pin guard: holds the entry alive *and* marked in-flight so the LRU
/// evictor skips it. Taken by `Coordinator::submit`, dropped after the
/// worker replies.
#[derive(Debug)]
pub struct OperandPin {
    entry: Arc<OperandEntry>,
}

impl OperandPin {
    pub fn entry(&self) -> &OperandEntry {
        &self.entry
    }
}

impl std::ops::Deref for OperandPin {
    type Target = OperandEntry;
    fn deref(&self) -> &OperandEntry {
        &self.entry
    }
}

impl Drop for OperandPin {
    fn drop(&mut self) {
        self.entry.pins.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One row of `list_a`: enough for clients to introspect routing and cost.
#[derive(Clone, Debug, PartialEq)]
pub struct OperandSummary {
    pub handle: OperandId,
    pub n: usize,
    pub nnz: usize,
    pub algo: Algo,
    pub artifact: String,
    pub bytes: u64,
    /// Storage tier: `"ram"` (resident, servable now) or `"spilled"`
    /// (demoted to the disk tier; the next reference promotes it back).
    pub tier: &'static str,
    /// The store tick the entry was last used at — operators read this to
    /// see eviction/promotion candidates (higher = more recently used).
    pub last_used_seq: u64,
}

/// Point-in-time store counters (merged into `MetricsSnapshot`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub entries: u64,
    pub bytes: u64,
    pub budget_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries demoted to the disk spill tier (0 when no tier configured).
    pub spill_writes: u64,
    /// Entries promoted back from disk by one sequential read.
    pub spill_promotes: u64,
    /// Bytes currently resident in spill files.
    pub spill_bytes: u64,
}

struct Slot {
    entry: Arc<OperandEntry>,
    last_used: u64,
    /// Superseded versions still pinned by in-flight jobs: they keep
    /// charging the budget (their memory is resident) and keep the slot
    /// out of the evictor until the pins drop (see `Inner::purge_retired`).
    retired: Vec<Arc<OperandEntry>>,
}

struct Inner {
    entries: HashMap<u64, Slot>,
    next_id: u64,
    tick: u64,
    bytes: u64,
    /// Per-tenant resident bytes (published + retired versions). Absent
    /// key = 0. Sums to `bytes` at all times.
    tenant_bytes: HashMap<String, u64>,
}

impl Inner {
    fn charge_tenant(&mut self, tenant: &str, bytes: u64) {
        *self.tenant_bytes.entry(tenant.to_string()).or_insert(0) += bytes;
    }

    fn credit_tenant(&mut self, tenant: &str, bytes: u64) {
        if let Some(v) = self.tenant_bytes.get_mut(tenant) {
            *v = v.saturating_sub(bytes);
            if *v == 0 {
                self.tenant_bytes.remove(tenant);
            }
        }
    }

    fn tenant_resident(&self, tenant: &str) -> u64 {
        self.tenant_bytes.get(tenant).copied().unwrap_or(0)
    }

    /// Drop superseded entry versions whose pins have all been released,
    /// reclaiming their budget charge. Called under the lock by every
    /// path that reads or reshapes the byte accounting (registration,
    /// flips, gauges) — retired versions that remain afterwards are
    /// genuinely pinned.
    fn purge_retired(&mut self) {
        let mut freed: Vec<(String, u64)> = Vec::new();
        for slot in self.entries.values_mut() {
            slot.retired.retain(|e| {
                if e.pinned() {
                    true
                } else {
                    freed.push((e.tenant.clone(), e.bytes));
                    false
                }
            });
        }
        for (tenant, b) in freed {
            self.bytes -= b;
            self.credit_tenant(&tenant, b);
        }
    }

    /// Locked dedup lookup: the resident entry with identical content
    /// (full element compare on signature match — a hash collision must
    /// not alias two operands), hint, **and owning tenant** (two tenants
    /// registering the same bytes get separate entries — dedup across
    /// tenants would let one tenant's drop or eviction reach into
    /// another's slice), LRU-refreshed. Deliberately does NOT count a
    /// store hit: `hits`/`misses` measure served handle traffic
    /// (`checkout`/`peek_dims`), not `put_a` dedups.
    fn resident(
        &mut self,
        a: &Mat,
        sig: ASig,
        hint: Option<Algo>,
        tenant: &str,
    ) -> Option<Arc<OperandEntry>> {
        self.tick += 1;
        let tick = self.tick;
        let slot = self.entries.values_mut().find(|s| {
            s.entry.sig == sig
                && s.entry.hint == hint
                && s.entry.tenant == tenant
                && s.entry.a.data == a.data
        })?;
        slot.last_used = tick;
        Some(Arc::clone(&slot.entry))
    }
}

/// The byte-budgeted, LRU-evicting converted-operand store. One per
/// coordinator, shared (`Arc`) with the serving front end.
pub struct OperandStore {
    budget: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Tenant specs for slice lookups (None = untenanted: one `default`
    /// accounting bucket with the whole budget, bit-for-bit pre-tenancy).
    tenants: Option<Arc<TenantRegistry>>,
    /// Disk spill tier (None = evictions destroy the conversion, the
    /// pre-spill behavior).
    spill: Option<SpillStore>,
    inner: Mutex<Inner>,
}

impl OperandStore {
    pub fn new(budget_bytes: u64) -> Self {
        OperandStore::with_tiers(budget_bytes, None, None)
    }

    /// Store with tenancy slices and/or a disk spill tier behind it.
    pub fn with_tiers(
        budget_bytes: u64,
        tenants: Option<Arc<TenantRegistry>>,
        spill: Option<SpillStore>,
    ) -> Self {
        OperandStore {
            budget: budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            tenants,
            spill,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                next_id: 0,
                tick: 0,
                bytes: 0,
                tenant_bytes: HashMap::new(),
            }),
        }
    }

    /// The disk spill tier, when configured.
    pub fn spill(&self) -> Option<&SpillStore> {
        self.spill.as_ref()
    }

    /// Resident bytes currently charged to `tenant` (published + retired
    /// versions) — the slice-isolation gauge the acceptance tests assert.
    pub fn tenant_bytes_of(&self, tenant: &str) -> u64 {
        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        g.tenant_resident(tenant)
    }

    /// The byte slice `tenant` may occupy (0 = whole budget).
    fn slice_of(&self, tenant: &str) -> u64 {
        self.tenants.as_ref().map_or(0, |t| t.slice_of(tenant))
    }

    /// The accounting identity a wire-level tenant id resolves to.
    fn resolve_tenant(&self, tenant: &str) -> String {
        match &self.tenants {
            Some(t) => t.resolve_owned(tenant),
            None => tenant.to_string(),
        }
    }

    /// Register an A operand: hash, scan, plan, convert (all outside the
    /// store lock), then insert under the byte budget, evicting LRU
    /// unpinned entries as needed. Registering content+hint already
    /// resident dedups to the existing handle (no second conversion).
    /// Returns the shared entry and whether a dense→sparse conversion was
    /// actually performed (`false` on dedup hits and dense routing; a
    /// race-losing duplicate that already converted before the in-lock
    /// dedup recheck reports `true` — the EO event happened).
    pub fn register(
        &self,
        a: Mat,
        hint: Option<Algo>,
        reg: &Registry,
        cfg: &CoordinatorConfig,
    ) -> Result<(Arc<OperandEntry>, bool), String> {
        self.register_for(DEFAULT_TENANT, a, hint, reg, cfg)
    }

    /// [`OperandStore::register`] on behalf of a tenant: the entry charges
    /// the tenant's byte slice, evicts only the tenant's own entries under
    /// pressure, and fails with a typed `QUOTA_EXCEEDED` error when the
    /// slice cannot fit it. The `default` tenant with no configured slice
    /// is bit-for-bit the untenanted path.
    pub fn register_for(
        &self,
        tenant: &str,
        a: Mat,
        hint: Option<Algo>,
        reg: &Registry,
        cfg: &CoordinatorConfig,
    ) -> Result<(Arc<OperandEntry>, bool), String> {
        let tenant = self.resolve_tenant(tenant);
        let slice = self.slice_of(&tenant);
        let n = a.rows;
        if n == 0 || a.cols != n {
            return Err(format!("registered A must be square and non-empty, got {}x{}", a.rows, a.cols));
        }
        // Cheap lower bound before any work: the dense A alone already
        // charges a.data.len()*4 bytes, so an operand that cannot fit the
        // budget is rejected without paying the scan/conversion (a
        // server-exposed path should not burn work on doomed requests).
        if (a.data.len() * 4) as u64 > self.budget {
            return Err(format!(
                "operand (≥{} B dense) exceeds the store budget ({} B)",
                a.data.len() * 4,
                self.budget
            ));
        }
        if slice > 0 && (a.data.len() * 4) as u64 > slice {
            return Err(format!(
                "{QUOTA_EXCEEDED}: tenant `{tenant}` operand (≥{} B dense) exceeds its {slice} B store slice",
                a.data.len() * 4
            ));
        }
        let sig = ASig::of(&a);
        // Dedup: same content (full element compare on signature match —
        // a hash collision must not alias two operands) under the same
        // hint and tenant → the existing handle, refreshed in the LRU
        // order.
        if let Some(entry) = self.find_resident(&a, sig, hint, &tenant) {
            return Ok((entry, false));
        }

        // Plan first, then convert straight to the planned capacity — the
        // same plan-then-convert pipeline the per-request path uses.
        let t0 = Instant::now();
        let stats = convert::scan_stats(&a, cfg.gcoo_p, cfg.convert_threads);
        let selector = Selector::new(cfg.policy);
        let plan = selector.plan(
            reg,
            n,
            stats.sparsity(),
            stats.max_band_nnz(),
            stats.max_row_nnz,
            hint,
        )?;
        let operand = device_operand_for(&a, &stats, &plan, cfg)?;
        let converted = plan.algo.is_sparse();
        let convert_s = t0.elapsed().as_secs_f64();
        // Ranked plan list for the tuner. Hinted registrations never
        // explore (the hint is the contract), so their list is the plan
        // alone; unhinted entries publish every resolvable family, prior
        // order, optionally re-ranked by the autotune measured-refinement
        // stage (bounded budget, deterministic simulation).
        let candidates = match hint {
            Some(_) => vec![plan.clone()],
            None => {
                let mut c = selector.plan_candidates(
                    reg,
                    n,
                    stats.sparsity(),
                    stats.max_band_nnz(),
                    stats.max_row_nnz,
                );
                c.retain(|p| p.algo != plan.algo);
                c.insert(0, plan.clone());
                refine_candidates(&a, cfg.gcoo_p, &mut c, cfg.tuning.register_refine_budget);
                c
            }
        };
        let bytes = (a.data.len() * 4 + operand.bytes()) as u64;
        if bytes > self.budget {
            return Err(format!(
                "operand ({bytes} B) exceeds the store budget ({} B)",
                self.budget
            ));
        }
        if slice > 0 && bytes > slice {
            return Err(format!(
                "{QUOTA_EXCEEDED}: tenant `{tenant}` operand ({bytes} B) exceeds its {slice} B store slice"
            ));
        }

        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        // Re-check dedup under the insert lock: a concurrent registration
        // of the same content may have landed while this thread was
        // converting (the scan/convert runs unlocked). The duplicate
        // conversion is wasted work; a duplicate *entry* — double byte
        // charge, split batching — must not be. Unlike the early dedup
        // hit, this thread really did pay the scan/conversion, so the
        // `converted` flag reports it (conversions_total counts EO events
        // performed, not entries created).
        if let Some(existing) = g.resident(&a, sig, hint, &tenant) {
            return Ok((existing, converted));
        }
        self.evict_for(&mut g, &tenant, slice, bytes)?;
        // Owned-id sequence (DESIGN.md §Cluster): a clustered store only
        // assigns handle ids its own shard owns on the consistent-hash
        // ring, so `ring.owner(handle)` always resolves to the node that
        // registered it and a stateless router can route any handle with
        // no translation map. Unclustered (`shard: None`) the sequence is
        // the dense 1, 2, 3… it has always been, bit-for-bit.
        g.next_id += 1;
        if let Some(spec) = cfg.shard {
            let ring = spec.ring();
            while !spec.owns(&ring, g.next_id) {
                g.next_id += 1;
            }
        }
        g.tick += 1;
        let handle = OperandId(g.next_id);
        let entry = Arc::new(OperandEntry {
            handle,
            tenant: tenant.clone(),
            a,
            sig,
            hint,
            stats,
            plan,
            candidates,
            operand,
            convert_s,
            bytes,
            version: 1,
            pins: AtomicUsize::new(0),
        });
        g.bytes += bytes;
        g.charge_tenant(&tenant, bytes);
        let tick = g.tick;
        g.entries.insert(
            handle.0,
            Slot { entry: Arc::clone(&entry), last_used: tick, retired: Vec::new() },
        );
        Ok((entry, converted))
    }

    /// Two-phase eviction under the insert lock: pick least-recently-used
    /// unpinned victims until `bytes` more would fit, and commit the
    /// removals only once they provably suffice — an insert that cannot
    /// fit must not evict anything (pins are an eviction barrier, not
    /// victims; observed-unpinned entries cannot gain a pin while we hold
    /// the lock, since `checkout` also locks).
    ///
    /// **Tenancy:** victims are always the inserting tenant's own entries
    /// — one tenant's registration pressure can never evict another
    /// tenant's residents (slice isolation). The fit test covers both the
    /// global budget and the tenant's slice (`slice` 0 = whole budget);
    /// an unsatisfiable slice yields a typed `QUOTA_EXCEEDED` error, an
    /// unsatisfiable budget keeps the pre-tenancy message. Untenanted,
    /// every entry belongs to `default` and this is bit-for-bit the old
    /// evictor.
    ///
    /// **Spill:** committed victims demote to the disk tier (file write
    /// under the store lock — eviction is already a slow path, and the
    /// lock guarantees a victim cannot be re-registered mid-demotion).
    /// Demote failures are swallowed: the tier is a cache under the
    /// store, never a correctness dependency.
    fn evict_for(&self, g: &mut Inner, tenant: &str, slice: u64, bytes: u64) -> Result<(), String> {
        let tb = g.tenant_resident(tenant);
        let fits = |freed: u64| {
            g.bytes - freed + bytes <= self.budget
                && (slice == 0 || tb.saturating_sub(freed) + bytes <= slice)
        };
        if fits(0) {
            return Ok(());
        }
        let mut victims: Vec<(u64, u64, u64)> = g
            .entries
            .iter()
            // A slot is evictable only when it belongs to the inserting
            // tenant and neither its published entry nor any retired
            // (superseded, still-pinned) version is held by an in-flight
            // job.
            .filter(|(_, s)| {
                s.entry.tenant == tenant && !s.entry.pinned() && s.retired.is_empty()
            })
            .map(|(&id, s)| (s.last_used, id, s.entry.bytes))
            .collect();
        victims.sort_unstable();
        let mut freed = 0u64;
        let mut take = 0usize;
        while !fits(freed) && take < victims.len() {
            freed += victims[take].2;
            take += 1;
        }
        if !fits(freed) {
            if slice > 0 && tb.saturating_sub(freed) + bytes > slice {
                return Err(format!(
                    "{QUOTA_EXCEEDED}: tenant `{tenant}` store slice exhausted \
                     ({tb} B resident of a {slice} B slice, {} B of it pinned; \
                     a {bytes} B entry cannot fit)",
                    tb - victims.iter().map(|v| v.2).sum::<u64>(),
                ));
            }
            return Err(format!(
                "operand store budget exhausted ({} B resident, {} B of it pinned; \
                 a {} B entry cannot fit the {} B budget)",
                g.bytes,
                g.bytes - victims.iter().map(|v| v.2).sum::<u64>(),
                bytes,
                self.budget
            ));
        }
        for &(last_used, id, _) in &victims[..take] {
            let slot = g.entries.remove(&id).expect("victim resident");
            g.bytes -= slot.entry.bytes;
            g.credit_tenant(&slot.entry.tenant, slot.entry.bytes);
            if let Some(spill) = &self.spill {
                let _ = spill.demote(&slot.entry, &slot.entry.tenant, last_used);
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Resident entry with this exact content, hint, and tenant,
    /// LRU-refreshed (see [`Inner::resident`] — registration dedups are
    /// not store hits).
    fn find_resident(
        &self,
        a: &Mat,
        sig: ASig,
        hint: Option<Algo>,
        tenant: &str,
    ) -> Option<Arc<OperandEntry>> {
        self.inner.lock().unwrap().resident(a, sig, hint, tenant)
    }

    /// Look up and pin an entry for an in-flight job (bumps the LRU order
    /// and the hit counter; a missing handle counts a miss). A handle
    /// absent from RAM but present in the spill index is **promoted**
    /// first — one sequential read, signature verified, re-inserted under
    /// the owner's slice — and then served exactly like a resident hit.
    /// Promotion never re-converts: the spilled device form is the one
    /// registration built, so `conversions_total` is constant across a
    /// demote/promote cycle.
    pub fn checkout(&self, h: OperandId) -> Option<OperandPin> {
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(slot) = g.entries.get_mut(&h.0) {
                slot.last_used = tick;
                slot.entry.pins.fetch_add(1, Ordering::SeqCst);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(OperandPin { entry: Arc::clone(&slot.entry) });
            }
        }
        if let Some(pin) = self.promote_spilled(h) {
            return Some(pin);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Promote a spilled handle back into RAM and pin it. The file read
    /// and decode happen outside the store lock; insertion re-checks
    /// residency (a concurrent checkout may have won the promotion race)
    /// and evicts within the owner's slice to make room. Failure modes
    /// all degrade to a miss: a corrupt or raced-away file, or a slice
    /// that cannot fit the entry even after eviction (the conversion is
    /// then genuinely lost — the promote consumed the file).
    fn promote_spilled(&self, h: OperandId) -> Option<OperandPin> {
        let spill = self.spill.as_ref()?;
        if !spill.contains(h) {
            return None;
        }
        let restored = spill.promote(h).ok()?;
        let tenant = restored.tenant.clone();
        let slice = self.slice_of(&tenant);
        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        g.tick += 1;
        let tick = g.tick;
        if let Some(slot) = g.entries.get_mut(&h.0) {
            // Lost the promotion race: another thread already re-inserted
            // the handle. Serve the resident winner.
            slot.last_used = tick;
            slot.entry.pins.fetch_add(1, Ordering::SeqCst);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(OperandPin { entry: Arc::clone(&slot.entry) });
        }
        self.evict_for(&mut g, &tenant, slice, restored.bytes).ok()?;
        let entry = Arc::new(OperandEntry {
            handle: restored.handle,
            tenant: tenant.clone(),
            a: restored.a,
            sig: restored.sig,
            hint: restored.hint,
            stats: restored.stats,
            plan: restored.plan,
            candidates: restored.candidates,
            operand: restored.operand,
            convert_s: restored.convert_s,
            bytes: restored.bytes,
            version: restored.version,
            // Born pinned: the promoting job holds it.
            pins: AtomicUsize::new(1),
        });
        g.bytes += restored.bytes;
        g.charge_tenant(&tenant, restored.bytes);
        g.entries.insert(
            h.0,
            Slot { entry: Arc::clone(&entry), last_used: tick, retired: Vec::new() },
        );
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(OperandPin { entry })
    }

    /// Dimension of a registered A without touching LRU order (the serve
    /// layer uses this to size synthetic B operands). Gauge accounting is
    /// **symmetric**: a resolved probe counts a hit exactly as an unknown
    /// handle counts a miss. Counting only the misses would deflate the
    /// served hit rate one probe per wire request — and the cluster's
    /// replication heuristic consumes that rate to decide which operands
    /// are hot (DESIGN.md §Cluster).
    pub fn peek_dims(&self, h: OperandId) -> Option<usize> {
        let dims = self.inner.lock().unwrap().entries.get(&h.0).map(|s| s.entry.a.rows);
        // A spilled handle is still a *known* handle: answer its dims from
        // the spill index (no file I/O, no promotion — the serve layer
        // only needs the size; the submit-time checkout promotes).
        let dims = dims.or_else(|| self.spill.as_ref()?.meta(h).map(|r| r.n));
        match dims {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        dims
    }

    /// Control-plane lookup of a resident entry: no pin, no LRU bump, no
    /// gauge traffic. The cluster replicator reads the owner's entry here
    /// before copying it to replica nodes — replication must observe the
    /// store, never perturb the hit rate it is driven by.
    pub fn peek_entry(&self, h: OperandId) -> Option<Arc<OperandEntry>> {
        self.inner.lock().unwrap().entries.get(&h.0).map(|s| Arc::clone(&s.entry))
    }

    /// Cluster replication hook (DESIGN.md §Cluster): install a copy of
    /// another node's entry under its **original handle**. The replica
    /// re-converts the shipped dense A from the owner's registration-time
    /// stats and plan — conversion is deterministic, so the replica's
    /// device slabs, and every result later computed from them, are
    /// bitwise identical to the owner's. Id-sequence safety: the owner
    /// assigned this handle from its owned-id ring partition, which this
    /// node's own sequence never enters, so the forced insert cannot
    /// collide with a locally assigned id. Idempotent: a handle already
    /// resident (re-replication, or a dedup alias) returns the resident
    /// entry untouched. Budget rules match `register`, including the
    /// two-phase LRU eviction.
    pub fn register_replica(
        &self,
        src: &OperandEntry,
        cfg: &CoordinatorConfig,
    ) -> Result<Arc<OperandEntry>, String> {
        // Convert outside the lock, exactly like registration.
        let operand = device_operand_for(&src.a, &src.stats, &src.plan, cfg)?;
        let bytes = (src.a.data.len() * 4 + operand.bytes()) as u64;
        if bytes > self.budget {
            return Err(format!(
                "replica ({bytes} B) exceeds the store budget ({} B)",
                self.budget
            ));
        }
        // The replica keeps the owner's tenant: slice isolation follows
        // the operand across nodes.
        let tenant = self.resolve_tenant(&src.tenant);
        let slice = self.slice_of(&tenant);
        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        if let Some(slot) = g.entries.get(&src.handle.0) {
            return Ok(Arc::clone(&slot.entry));
        }
        self.evict_for(&mut g, &tenant, slice, bytes)?;
        g.tick += 1;
        let tick = g.tick;
        let entry = Arc::new(OperandEntry {
            handle: src.handle,
            tenant: tenant.clone(),
            a: src.a.clone(),
            sig: src.sig,
            hint: src.hint,
            stats: src.stats.clone(),
            plan: src.plan.clone(),
            candidates: src.candidates.clone(),
            operand,
            convert_s: src.convert_s,
            bytes,
            version: src.version,
            pins: AtomicUsize::new(0),
        });
        g.bytes += bytes;
        g.charge_tenant(&tenant, bytes);
        g.entries.insert(
            src.handle.0,
            Slot { entry: Arc::clone(&entry), last_used: tick, retired: Vec::new() },
        );
        Ok(entry)
    }

    /// Model-driven route flip: republish `old`'s handle under the
    /// measured-favorite plan `alt`, with a freshly converted device form.
    /// Entries stay immutable — the flip creates a **new version** (same
    /// handle, `version + 1`, candidates reordered alt-first) and swaps
    /// the slot's `Arc`; pins keep old versions alive untouched, so an
    /// in-flight job can never observe a half-flipped operand. Refused
    /// when: the flip targets the incumbent algorithm, the handle was
    /// dropped, the slot already moved past `old.version` (a stale flip
    /// from a job still holding an older pin), or the swap would exceed
    /// the byte budget.
    pub fn reroute(
        &self,
        old: &OperandEntry,
        alt: &ExecPlan,
        cfg: &CoordinatorConfig,
    ) -> Result<Arc<OperandEntry>, String> {
        if alt.algo == old.plan.algo {
            return Err("flip to the incumbent algorithm is a no-op".into());
        }
        if !old.candidates.iter().any(|c| c.algo == alt.algo) {
            return Err(format!("{} is not a published candidate", alt.algo.as_str()));
        }
        // Convert outside the lock, exactly like registration — from the
        // registration-time stats (the entry is immutable; no re-scan).
        let t0 = Instant::now();
        let operand = device_operand_for(&old.a, &old.stats, alt, cfg)?;
        let convert_s = t0.elapsed().as_secs_f64();
        let bytes = (old.a.data.len() * 4 + operand.bytes()) as u64;
        let mut plan = alt.clone();
        plan.width = 1;
        let mut candidates = old.candidates.clone();
        let pos = candidates
            .iter()
            .position(|c| c.algo == alt.algo)
            .expect("membership checked above");
        let mut head = candidates.remove(pos);
        head.reason = plan.reason;
        candidates.insert(0, head);

        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        let (cur_version, cur_bytes, cur_pinned) = match g.entries.get(&old.handle.0) {
            Some(s) => (s.entry.version, s.entry.bytes, s.entry.pinned()),
            None => return Err(format!("operand {} dropped during flip", old.handle)),
        };
        if cur_version != old.version {
            return Err("stale flip: the entry was already republished".into());
        }
        // A pinned superseded version stays resident (retired) until its
        // in-flight jobs finish, so the flip transiently charges BOTH
        // versions — the budget check must cover that, not just the swap.
        let after = if cur_pinned { g.bytes + bytes } else { g.bytes - cur_bytes + bytes };
        if after > self.budget {
            return Err(format!(
                "flip would exceed the store budget ({} B)",
                self.budget
            ));
        }
        g.tick += 1;
        let tick = g.tick;
        let entry = Arc::new(OperandEntry {
            handle: old.handle,
            tenant: old.tenant.clone(),
            a: old.a.clone(),
            sig: old.sig,
            hint: old.hint,
            stats: old.stats.clone(),
            plan,
            candidates,
            operand,
            convert_s,
            bytes,
            version: old.version + 1,
            pins: AtomicUsize::new(0),
        });
        let slot = g.entries.get_mut(&old.handle.0).expect("checked resident");
        let prev = std::mem::replace(&mut slot.entry, Arc::clone(&entry));
        slot.last_used = tick;
        if prev.pinned() {
            // The superseded version is held by in-flight jobs: it stays
            // charged and keeps blocking eviction of this handle until
            // the pins drop (the flip must not lift the pin barrier).
            slot.retired.push(prev);
            g.bytes += bytes;
            g.charge_tenant(&old.tenant, bytes);
        } else {
            g.bytes = g.bytes - prev.bytes + bytes;
            g.credit_tenant(&old.tenant, prev.bytes);
            g.charge_tenant(&old.tenant, bytes);
        }
        Ok(entry)
    }

    /// Every resident entry, ordered by handle (the `explain` routing
    /// table reads candidates/versions straight off these).
    pub fn entries_snapshot(&self) -> Vec<Arc<OperandEntry>> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<Arc<OperandEntry>> =
            g.entries.values().map(|s| Arc::clone(&s.entry)).collect();
        out.sort_by_key(|e| e.handle);
        out
    }

    /// Remove an entry (wire `drop_a`). In-flight jobs holding the `Arc`
    /// finish against their snapshot; later lookups miss. Returns whether
    /// the handle was resident.
    pub fn remove(&self, h: OperandId) -> bool {
        let ram = {
            let mut g = self.inner.lock().unwrap();
            match g.entries.remove(&h.0) {
                Some(slot) => {
                    let freed =
                        slot.entry.bytes + slot.retired.iter().map(|e| e.bytes).sum::<u64>();
                    g.bytes -= freed;
                    g.credit_tenant(&slot.entry.tenant.clone(), freed);
                    true
                }
                None => false,
            }
        };
        // An explicit drop reaches the spill tier too: `drop_a` means
        // gone, not demoted.
        let spilled = self.spill.as_ref().is_some_and(|s| s.discard(h));
        ram || spilled
    }

    /// Summaries of every known entry — RAM residents (`tier: "ram"`)
    /// followed by spilled entries (`tier: "spilled"`) — ordered by
    /// handle (wire `list_a`). A handle caught mid-promotion appears
    /// once, preferring its RAM row.
    pub fn list(&self) -> Vec<OperandSummary> {
        let mut out: Vec<OperandSummary> = {
            let g = self.inner.lock().unwrap();
            g.entries
                .values()
                .map(|s| OperandSummary {
                    handle: s.entry.handle,
                    n: s.entry.a.rows,
                    nnz: s.entry.sig.nnz,
                    algo: s.entry.plan.algo,
                    artifact: s.entry.plan.artifact.clone(),
                    bytes: s.entry.bytes,
                    tier: "ram",
                    last_used_seq: s.last_used,
                })
                .collect()
        };
        if let Some(spill) = &self.spill {
            for r in spill.list() {
                if out.iter().any(|s| s.handle == r.handle) {
                    continue;
                }
                out.push(OperandSummary {
                    handle: r.handle,
                    n: r.n,
                    nnz: r.nnz,
                    algo: r.algo,
                    artifact: r.artifact,
                    bytes: r.entry_bytes,
                    tier: "spilled",
                    last_used_seq: r.last_used_seq,
                });
            }
        }
        out.sort_by_key(|s| s.handle);
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes_used(&self) -> u64 {
        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        g.bytes
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    pub fn stats(&self) -> StoreStats {
        let sp = self.spill.as_ref().map(|s| s.stats()).unwrap_or_default();
        let mut g = self.inner.lock().unwrap();
        g.purge_retired();
        StoreStats {
            entries: g.entries.len() as u64,
            bytes: g.bytes,
            budget_bytes: self.budget,
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            spill_writes: sp.writes,
            spill_promotes: sp.promotes,
            spill_bytes: sp.bytes,
        }
    }
}

/// Build the converted device form for `plan` — shared by registration and
/// the route-flip republish, so the two conversion paths can never drift.
fn device_operand_for(
    a: &Mat,
    stats: &AStats,
    plan: &ExecPlan,
    cfg: &CoordinatorConfig,
) -> Result<DeviceOperand, String> {
    match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            let (mut vals, mut rows, mut cols) = (Vec::new(), Vec::new(), Vec::new());
            convert::dense_to_slabs_into(
                a,
                stats,
                plan.n_exec,
                plan.cap,
                cfg.convert_threads,
                &mut vals,
                &mut rows,
                &mut cols,
            )
            .map_err(|e| e.to_string())?;
            Ok(DeviceOperand::Gcoo(GcooPadded {
                g: plan.n_exec.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: plan.n_exec,
                vals,
                rows,
                cols,
            }))
        }
        Algo::Csr => {
            let (mut vals, mut cols) = (Vec::new(), Vec::new());
            convert::dense_to_ell_into(a, plan.n_exec, plan.cap, &mut vals, &mut cols)
                .map_err(|e| e.to_string())?;
            Ok(DeviceOperand::Ell(Ell { n: plan.n_exec, rowcap: plan.cap, vals, cols }))
        }
        Algo::Cmrs => {
            let (mut vals, mut rows, mut cols) = (Vec::new(), Vec::new(), Vec::new());
            convert::dense_to_cmrs_into(a, stats, plan.n_exec, plan.cap, &mut vals, &mut rows, &mut cols)
                .map_err(|e| e.to_string())?;
            Ok(DeviceOperand::Cmrs(CmrsPadded {
                g: plan.n_exec.div_ceil(stats.p),
                cap: plan.cap,
                p: stats.p,
                n: plan.n_exec,
                vals,
                rows,
                cols,
            }))
        }
        Algo::RowSplit => {
            let (mut vals, mut seg_rows, mut cols) = (Vec::new(), Vec::new(), Vec::new());
            let segs = convert::dense_to_rowsplit_into(
                a,
                plan.n_exec,
                plan.cap,
                &mut vals,
                &mut seg_rows,
                &mut cols,
            )
            .map_err(|e| e.to_string())?;
            Ok(DeviceOperand::RowSplit(RowSplitPadded {
                segs,
                cap: plan.cap,
                n: plan.n_exec,
                vals,
                seg_rows,
                cols,
            }))
        }
        Algo::DenseXla | Algo::DensePallas => {
            // "Conversion" here is the pad to execution size, done once at
            // registration like the sparse forms. A dense-routed entry
            // knowingly stores two copies of A (the original for
            // dedup/oracle/re-screen, the exec-sized pad for the engine)
            // and charges the budget for both — dense routing has no EO to
            // amortize, so registering it is a transfer optimization only,
            // and sharing one allocation would need self-referential
            // storage the std-only rule makes ugly.
            let mut a_exec = Mat::zeros(0, 0);
            a_exec.pad_from(a, plan.n_exec);
            Ok(DeviceOperand::Dense(a_exec))
        }
    }
}

/// `autotune`'s measured-refinement stage at registration, bounded: rank
/// the exploration tail (`candidates[1..]`) by the trace-derived cost
/// oracle ([`simgpu::TraceOracle`] — traced kernel execution through the
/// memory model, deterministic at a fixed seed) for up to `budget` tail
/// candidates. The incumbent head — the routing `put_a` replied with — is
/// never reordered; refinement only decides which alternative the tuner
/// explores first.
fn refine_candidates(a: &Mat, p: usize, candidates: &mut [ExecPlan], budget: usize) {
    if budget == 0 || candidates.len() <= 2 {
        return; // nothing to rank: at most one alternative
    }
    let gcoo = Gcoo::from_dense(a, p);
    let structure = GcooStructure::new(&gcoo);
    let wcfg = WalkConfig { b: 128, sample_blocks: 16, seed: 7 };
    let oracle = simgpu::TraceOracle::new(&simgpu::TITANX, wcfg);
    let tail = &mut candidates[1..];
    let measured = tail.len().min(budget);
    let mut scored: Vec<(f64, ExecPlan)> = tail[..measured]
        .iter()
        .map(|c| {
            let t = match c.algo {
                Algo::Gcoo => oracle.gcoo_time(&structure, true),
                Algo::GcooNoreuse => oracle.gcoo_time(&structure, false),
                Algo::Csr => oracle.csr_time(&structure),
                Algo::Cmrs => oracle.cmrs_time(&structure),
                Algo::RowSplit => oracle.rowsplit_time(&structure, c.cap.max(1)),
                Algo::DenseXla | Algo::DensePallas => oracle.dense_time(c.n_exec),
            };
            (t, c.clone())
        })
        .collect();
    scored.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap_or(std::cmp::Ordering::Equal));
    for (slot, (_, plan)) in tail[..measured].iter_mut().zip(scored) {
        *slot = plan;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::prop::{check, Config};
    use crate::rng::Rng;
    use std::path::PathBuf;

    /// Stub registry at n=64 (gcoo caps {64, 512}, csr, dense) backed by a
    /// real file so the engine could load it — matches the integration
    /// stubs.
    fn reg() -> Registry {
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
             "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
             "params": {}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig::default()
    }

    fn sparse_a(seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        gen::uniform(64, 0.99, &mut rng)
    }

    #[test]
    fn register_converts_once_and_dedups_same_content() {
        let store = OperandStore::new(64 << 20);
        let (e1, converted) = store.register(sparse_a(1), None, &reg(), &cfg()).unwrap();
        assert!(converted, "sparse registration performs the one conversion");
        assert_eq!(e1.plan.algo, Algo::Gcoo);
        assert!(matches!(e1.operand, DeviceOperand::Gcoo(_)));
        assert!(e1.convert_s > 0.0);
        assert_eq!(store.len(), 1);
        // Same content + hint → same handle, no second conversion.
        let (e2, converted) = store.register(sparse_a(1), None, &reg(), &cfg()).unwrap();
        assert!(!converted);
        assert_eq!(e2.handle, e1.handle);
        assert_eq!(store.len(), 1);
        // Different content → a fresh handle.
        let (e3, _) = store.register(sparse_a(2), None, &reg(), &cfg()).unwrap();
        assert_ne!(e3.handle, e1.handle);
        assert_eq!(store.len(), 2);
        // Same content, different hint → its own entry (different slabs).
        let (e4, _) = store.register(sparse_a(1), Some(Algo::Csr), &reg(), &cfg()).unwrap();
        assert_ne!(e4.handle, e1.handle);
        assert!(matches!(e4.operand, DeviceOperand::Ell(_)));
    }

    /// The hint contract: unhinted requests always run the registered
    /// plan; explicit hints are served from cache only when they match the
    /// registration hint or the planned algorithm.
    #[test]
    fn serves_hint_contract() {
        let store = OperandStore::new(64 << 20);
        let (hinted, _) = store.register(sparse_a(5), Some(Algo::Gcoo), &reg(), &cfg()).unwrap();
        assert!(hinted.serves_hint(None), "no hint → the registered routing applies");
        assert!(hinted.serves_hint(Some(Algo::Gcoo)));
        assert!(!hinted.serves_hint(Some(Algo::Csr)), "conflicting hint falls back");
        let (unhinted, _) = store.register(sparse_a(6), None, &reg(), &cfg()).unwrap();
        assert_eq!(unhinted.plan.algo, Algo::Gcoo, "0.99-sparse routes gcoo");
        assert!(unhinted.serves_hint(None));
        assert!(unhinted.serves_hint(Some(Algo::Gcoo)), "naming the planned algo is served");
        assert!(!unhinted.serves_hint(Some(Algo::DenseXla)));
    }

    #[test]
    fn checkout_pins_and_remove_hides() {
        let store = OperandStore::new(64 << 20);
        let (e, _) = store.register(sparse_a(3), None, &reg(), &cfg()).unwrap();
        assert!(!e.pinned());
        let pin = store.checkout(e.handle).expect("resident");
        assert!(e.pinned());
        assert_eq!(pin.entry().handle, e.handle);
        assert!(store.checkout(OperandId(9999)).is_none(), "unknown handle misses");
        let st = store.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        // peek_dims: no LRU side effects, but gauge accounting is
        // symmetric — a resolved probe counts a hit exactly as an unknown
        // handle counts a miss, so wire-path dimension probes can never
        // deflate the hit rate the replication heuristic consumes.
        assert_eq!(store.peek_dims(e.handle), Some(64));
        assert_eq!(store.peek_dims(OperandId(9999)), None);
        let st = store.stats();
        assert_eq!((st.hits, st.misses), (2, 2), "peek accounting is symmetric");
        // Remove while pinned: later lookups miss, the pin's snapshot lives.
        assert!(store.remove(e.handle));
        assert!(!store.remove(e.handle), "double drop reports not-resident");
        assert!(store.checkout(e.handle).is_none());
        assert_eq!(pin.a.rows, 64, "in-flight snapshot survives the drop");
        drop(pin);
        assert!(!e.pinned());
        assert_eq!(store.bytes_used(), 0);
    }

    #[test]
    fn lru_eviction_order_and_budget() {
        // Budget sized for ~2 of these entries: the third registration must
        // evict the least recently *used* one (entry 1 was refreshed by a
        // checkout, so entry 2 is the victim).
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(10), None, &reg(), &cfg())
            .unwrap();
        let budget = e_probe.bytes * 5 / 2;
        let store = OperandStore::new(budget);
        let (e1, _) = store.register(sparse_a(10), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(11), None, &reg(), &cfg()).unwrap();
        drop(store.checkout(e1.handle)); // refresh e1 in the LRU order
        let (e3, _) = store.register(sparse_a(12), None, &reg(), &cfg()).unwrap();
        assert!(store.bytes_used() <= budget, "budget never exceeded");
        assert!(store.checkout(e2.handle).is_none(), "LRU victim evicted");
        assert!(store.checkout(e1.handle).is_some(), "recently-used entry survives");
        assert!(store.checkout(e3.handle).is_some());
        assert_eq!(store.stats().evictions, 1);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(20), None, &reg(), &cfg())
            .unwrap();
        // Room for one entry only.
        let store = OperandStore::new(e_probe.bytes * 3 / 2);
        let (e1, _) = store.register(sparse_a(20), None, &reg(), &cfg()).unwrap();
        let _pin = store.checkout(e1.handle).expect("resident");
        // The only resident entry is pinned: registration must refuse
        // rather than evict it or blow the budget.
        let err = store.register(sparse_a(21), None, &reg(), &cfg()).unwrap_err();
        assert!(err.contains("pinned"), "{err}");
        assert!(store.checkout(e1.handle).is_some(), "pinned entry survived");
        assert!(store.bytes_used() <= store.budget_bytes());
        // Unpinned, the same registration succeeds by evicting it.
        drop(_pin);
        drop(store.checkout(e1.handle));
        let (e2, _) = store.register(sparse_a(21), None, &reg(), &cfg()).unwrap();
        assert!(store.checkout(e1.handle).is_none());
        assert!(store.checkout(e2.handle).is_some());
    }

    #[test]
    fn oversized_operand_rejected_outright() {
        let store = OperandStore::new(1024); // smaller than any 64×64 entry
        let err = store.register(sparse_a(30), None, &reg(), &cfg()).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
        assert_eq!(store.len(), 0);
    }

    /// A registration that cannot fit even after evicting every unpinned
    /// entry must fail without evicting anything — a failed `put_a` must
    /// not shoot down operands that later handle traffic would re-resolve.
    #[test]
    fn failed_registration_evicts_nothing() {
        let probe = OperandStore::new(u64::MAX);
        let (small, _) = probe.register(sparse_a(60), None, &reg(), &cfg()).unwrap();
        let mut rng = Rng::new(61);
        let dense_a = gen::uniform(64, 0.5, &mut rng);
        let (big, _) = probe.register(dense_a.clone(), Some(Algo::Gcoo), &reg(), &cfg()).unwrap();
        assert!(big.bytes > 2 * small.bytes, "cap-512 entry dwarfs the cap-64 entry");
        let (s_bytes, b_bytes) = (small.bytes, big.bytes);

        // Residents: one unpinned small, one pinned small. The big entry
        // fits the budget alone but not alongside the pinned entry, so
        // registration must fail — and leave BOTH residents untouched
        // (the one-at-a-time evictor this regression pins would have
        // evicted the unpinned entry before discovering the failure).
        let store = OperandStore::new(b_bytes + s_bytes / 2);
        let (e1, _) = store.register(sparse_a(62), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(63), None, &reg(), &cfg()).unwrap();
        let _pin = store.checkout(e2.handle).expect("resident");
        let err = store.register(dense_a, Some(Algo::Gcoo), &reg(), &cfg()).unwrap_err();
        assert!(err.contains("pinned"), "{err}");
        assert_eq!(store.len(), 2, "failed registration must not evict");
        assert!(store.checkout(e1.handle).is_some(), "unpinned resident survives the failure");
        assert_eq!(store.stats().evictions, 0);
    }

    #[test]
    fn non_square_rejected() {
        let store = OperandStore::new(64 << 20);
        let a = Mat::zeros(8, 16);
        assert!(store.register(a, None, &reg(), &cfg()).is_err());
    }

    #[test]
    fn list_reports_routing() {
        let store = OperandStore::new(64 << 20);
        let (e1, _) = store.register(sparse_a(40), None, &reg(), &cfg()).unwrap();
        let (e2, _) = store.register(sparse_a(41), Some(Algo::Csr), &reg(), &cfg()).unwrap();
        let listed = store.list();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].handle, e1.handle);
        assert_eq!(listed[0].algo, Algo::Gcoo);
        assert_eq!(listed[1].handle, e2.handle);
        assert_eq!(listed[1].algo, Algo::Csr);
        assert!(listed.iter().all(|s| s.n == 64 && s.bytes > 0 && !s.artifact.is_empty()));
        assert_eq!(
            store.bytes_used(),
            listed.iter().map(|s| s.bytes).sum::<u64>(),
            "byte accounting matches the resident set"
        );
    }

    /// Registration publishes the ranked candidate list: the published
    /// plan first, every other resolvable family behind it — and a hinted
    /// registration publishes no alternatives at all (the hint is the
    /// contract; the tuner must have nothing to explore).
    #[test]
    fn register_publishes_ranked_candidates() {
        let store = OperandStore::new(64 << 20);
        let (e, _) = store.register(sparse_a(70), None, &reg(), &cfg()).unwrap();
        assert_eq!(e.version, 1);
        let algos: Vec<Algo> = e.candidates.iter().map(|c| c.algo).collect();
        assert_eq!(algos, vec![Algo::Gcoo, Algo::Csr, Algo::DenseXla]);
        assert_eq!(e.candidates[0].artifact, e.plan.artifact, "head is the published plan");
        let (hinted, _) = store.register(sparse_a(71), Some(Algo::Csr), &reg(), &cfg()).unwrap();
        assert_eq!(hinted.candidates.len(), 1, "hinted entries never explore");
        assert_eq!(hinted.candidates[0].algo, Algo::Csr);
    }

    /// The bounded measured-refinement stage at `put_a`: deterministic
    /// (same matrix, same order), head never reordered, and the tail
    /// ranked by the same trace-derived oracle verdicts the test
    /// recomputes.
    #[test]
    fn register_refinement_ranks_tail_deterministically() {
        let mut tcfg = cfg();
        tcfg.tuning.register_refine_budget = 2;
        let s1 = OperandStore::new(64 << 20);
        let s2 = OperandStore::new(64 << 20);
        let (e1, _) = s1.register(sparse_a(72), None, &reg(), &tcfg).unwrap();
        let (e2, _) = s2.register(sparse_a(72), None, &reg(), &tcfg).unwrap();
        assert_eq!(e1.candidates, e2.candidates, "refinement is deterministic");
        assert_eq!(e1.candidates[0].algo, e1.plan.algo, "head survives refinement");
        assert_eq!(e1.candidates.len(), 3);
        // The tail order matches the trace oracle's verdict at the same seed.
        let gcoo = Gcoo::from_dense(&e1.a, tcfg.gcoo_p);
        let structure = GcooStructure::new(&gcoo);
        let wcfg = WalkConfig { b: 128, sample_blocks: 16, seed: 7 };
        let oracle = simgpu::TraceOracle::new(&simgpu::TITANX, wcfg);
        let time_for = |algo: Algo, n_exec: usize| match algo {
            Algo::Csr => oracle.csr_time(&structure),
            Algo::DenseXla => oracle.dense_time(n_exec),
            other => panic!("unexpected tail algo {other:?}"),
        };
        let t1 = time_for(e1.candidates[1].algo, e1.candidates[1].n_exec);
        let t2 = time_for(e1.candidates[2].algo, e1.candidates[2].n_exec);
        assert!(t1 <= t2, "tail must be ranked by oracle time: {t1} vs {t2}");
    }

    /// A route flip republishes the handle as a new immutable version: the
    /// plan and device form change, the version bumps, candidates reorder
    /// — and a pin taken before the flip keeps the **old** version intact.
    #[test]
    fn reroute_republishes_and_pins_keep_old_version() {
        let store = OperandStore::new(64 << 20);
        let (e1, _) = store.register(sparse_a(80), None, &reg(), &cfg()).unwrap();
        assert_eq!((e1.plan.algo, e1.version), (Algo::Gcoo, 1));
        let pin = store.checkout(e1.handle).expect("resident");
        let alt = e1
            .candidates
            .iter()
            .find(|c| c.algo == Algo::DenseXla)
            .expect("dense candidate")
            .clone();
        let e2 = store.reroute(&e1, &alt, &cfg()).expect("flip succeeds");
        assert_eq!(e2.handle, e1.handle, "same handle, new version");
        assert_eq!(e2.version, 2);
        assert_eq!(e2.plan.algo, Algo::DenseXla);
        assert!(matches!(e2.operand, DeviceOperand::Dense(_)), "freshly converted form");
        assert_eq!(e2.candidates[0].algo, Algo::DenseXla, "candidates reorder alt-first");
        // The pre-flip pin still reads the old version, bit for bit.
        assert_eq!(pin.entry().version, 1);
        assert_eq!(pin.entry().plan.algo, Algo::Gcoo);
        assert!(matches!(pin.entry().operand, DeviceOperand::Gcoo(_)));
        // New checkouts see the new version. The superseded version is
        // still pinned, so it stays charged (its memory is resident);
        // releasing the pin reclaims it.
        let p2 = store.checkout(e1.handle).expect("resident");
        assert_eq!(p2.entry().version, 2);
        assert_eq!(
            store.bytes_used(),
            e1.bytes + e2.bytes,
            "pinned superseded version stays charged"
        );
        assert!(store.bytes_used() <= store.budget_bytes());
        // Refusals: same-algo, stale version, dropped handle.
        assert!(store.reroute(&e2, &alt, &cfg()).is_err(), "flip to incumbent refused");
        let back = e2.candidates.iter().find(|c| c.algo == Algo::Gcoo).unwrap().clone();
        let err = store.reroute(&e1, &back, &cfg()).unwrap_err();
        assert!(err.contains("stale"), "{err}");
        drop(pin);
        assert_eq!(store.bytes_used(), e2.bytes, "released pin purges the retired charge");
        drop(p2);
        assert!(store.remove(e1.handle));
        assert_eq!(store.bytes_used(), 0);
        assert!(store.reroute(&e2, &back, &cfg()).is_err(), "dropped handle refused");
    }

    /// Regression (review): a route flip must not lift the pin eviction
    /// barrier. The flipping job still pins the superseded version, so
    /// budget-pressured registration must refuse to evict the handle
    /// (and must not blow the budget by ignoring the retired charge);
    /// once the pin drops, normal eviction resumes.
    #[test]
    fn flip_keeps_pinned_version_charged_and_eviction_blocked() {
        // Probe sizes with an unbounded store: v1 (gcoo) and v2 (dense).
        let probe = OperandStore::new(u64::MAX);
        let (v1, _) = probe.register(sparse_a(95), None, &reg(), &cfg()).unwrap();
        let alt = v1.candidates.iter().find(|c| c.algo == Algo::DenseXla).unwrap().clone();
        let v2 = probe.reroute(&v1, &alt, &cfg()).unwrap();

        // Budget fits both versions of H transiently, nothing more.
        let store = OperandStore::new(v1.bytes + v2.bytes);
        let (e1, _) = store.register(sparse_a(95), None, &reg(), &cfg()).unwrap();
        let pin = store.checkout(e1.handle).expect("resident"); // in-flight job
        let alt = e1.candidates.iter().find(|c| c.algo == Algo::DenseXla).unwrap().clone();
        let e2 = store.reroute(&e1, &alt, &cfg()).expect("flip fits the budget");
        assert_eq!(store.bytes_used(), e1.bytes + e2.bytes);

        // Fresh content now needs room that only evicting H would free —
        // but H's slot holds a pinned retired version: refuse, evict
        // nothing, and keep serving the handle.
        let err = store.register(sparse_a(96), None, &reg(), &cfg()).unwrap_err();
        assert!(err.contains("pinned"), "{err}");
        assert!(store.checkout(e1.handle).is_some(), "flipped handle survives pressure");
        assert_eq!(store.stats().evictions, 0);
        assert_eq!(pin.entry().version, 1, "in-flight snapshot untouched");

        // Pin released: the retired charge purges and eviction resumes.
        drop(pin);
        assert_eq!(store.bytes_used(), e2.bytes);
        let (e3, _) = store.register(sparse_a(96), None, &reg(), &cfg()).unwrap();
        assert!(store.checkout(e3.handle).is_some());
        assert!(store.bytes_used() <= store.budget_bytes());
    }

    /// A flip whose transient double-charge (pinned superseded version +
    /// new form) cannot fit the budget is refused outright — the store
    /// never lets accounted bytes exceed the budget, even mid-flip.
    #[test]
    fn flip_refused_when_pinned_double_charge_exceeds_budget() {
        let probe = OperandStore::new(u64::MAX);
        let (v1, _) = probe.register(sparse_a(97), None, &reg(), &cfg()).unwrap();
        let store = OperandStore::new(v1.bytes + v1.bytes / 4);
        let (e1, _) = store.register(sparse_a(97), None, &reg(), &cfg()).unwrap();
        let _pin = store.checkout(e1.handle).expect("resident");
        let alt = e1.candidates.iter().find(|c| c.algo == Algo::DenseXla).unwrap().clone();
        let err = store.reroute(&e1, &alt, &cfg()).unwrap_err();
        assert!(err.contains("budget"), "{err}");
        assert_eq!(store.bytes_used(), e1.bytes, "refused flip changes nothing");
        let cur = store.entries_snapshot().pop().unwrap();
        assert_eq!((cur.version, cur.plan.algo), (1, Algo::Gcoo));
    }

    /// Property (satellite): interleaved flip / pin / unpin sequences
    /// never drop a pinned entry version — every held pin keeps reading
    /// its own immutable snapshot (plan/operand family consistent, bytes
    /// accounted), the slot always serves the latest version, and the
    /// byte accounting matches the resident set throughout.
    #[test]
    fn prop_flip_pin_interleavings_preserve_pinned_versions() {
        let store = OperandStore::new(u64::MAX);
        let (e0, _) = store.register(sparse_a(90), None, &reg(), &cfg()).unwrap();
        check(
            Config { cases: 24, base_seed: 0xF11B, ..Default::default() },
            |g| (0..g.usize_in(4, 20)).map(|_| g.rng.next_u64() % 3).collect::<Vec<u64>>(),
            |ops| {
                let mut pins: Vec<OperandPin> = Vec::new();
                for op in ops {
                    match *op {
                        0 => {
                            // Flip the *current* version to its top-ranked
                            // alternative (alternating algo families).
                            let cur = store
                                .entries_snapshot()
                                .into_iter()
                                .find(|e| e.handle == e0.handle)
                                .expect("resident");
                            let alt = cur
                                .candidates
                                .iter()
                                .find(|c| c.algo != cur.plan.algo)
                                .expect("multi-candidate entry")
                                .clone();
                            let flipped =
                                store.reroute(&cur, &alt, &cfg()).map_err(|e| e.to_string())?;
                            if flipped.version != cur.version + 1 {
                                return Err("flip must bump the version".into());
                            }
                        }
                        1 => {
                            if let Some(p) = store.checkout(e0.handle) {
                                pins.push(p);
                            } else {
                                return Err("published handle must stay resident".into());
                            }
                        }
                        _ => {
                            pins.pop();
                        }
                    }
                    // Every held pin still reads a self-consistent
                    // immutable snapshot of its own version.
                    for p in &pins {
                        let e = p.entry();
                        let family_ok = match (&e.operand, e.plan.algo) {
                            (DeviceOperand::Gcoo(_), Algo::Gcoo | Algo::GcooNoreuse) => true,
                            (DeviceOperand::Ell(_), Algo::Csr) => true,
                            (DeviceOperand::Dense(_), Algo::DenseXla | Algo::DensePallas) => true,
                            (DeviceOperand::Cmrs(_), Algo::Cmrs) => true,
                            (DeviceOperand::RowSplit(_), Algo::RowSplit) => true,
                            _ => false,
                        };
                        if !family_ok {
                            return Err(format!(
                                "pinned v{} operand/plan family mismatch",
                                e.version
                            ));
                        }
                        if e.a.rows != 64 {
                            return Err("pinned snapshot lost its dense A".into());
                        }
                    }
                    let latest = store
                        .entries_snapshot()
                        .into_iter()
                        .find(|e| e.handle == e0.handle)
                        .expect("resident");
                    // Retired (superseded, still-pinned) versions keep
                    // their charge, so accounting is at least the
                    // published entry's bytes while pins are held…
                    if store.bytes_used() < latest.bytes {
                        return Err("byte accounting drifted across flips".into());
                    }
                }
                // …and collapses back to exactly the published entry once
                // every pin is released.
                pins.clear();
                let latest = store
                    .entries_snapshot()
                    .into_iter()
                    .find(|e| e.handle == e0.handle)
                    .expect("resident");
                if store.bytes_used() != latest.bytes {
                    return Err("released pins must purge every retired charge".into());
                }
                Ok(())
            },
        );
    }

    /// Property: under random register / checkout / remove interleavings
    /// the byte budget is never exceeded, accounting stays exact, and a
    /// held pin is never evicted.
    #[test]
    fn prop_budget_and_pin_invariants() {
        let (e_probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(50), None, &reg(), &cfg())
            .unwrap();
        let entry_bytes = e_probe.bytes;
        check(
            Config { cases: 16, base_seed: 0x570E, ..Default::default() },
            |g| {
                let slots = g.usize_in(2, 4); // budget in whole entries
                let ops: Vec<u8> = (0..g.usize_in(4, 16)).map(|_| g.rng.next_u64() as u8).collect();
                (slots, ops)
            },
            |(slots, ops)| {
                let store = OperandStore::new(entry_bytes * (*slots as u64) + entry_bytes / 2);
                let mut pins = Vec::new();
                let mut handles = Vec::new();
                for (i, op) in ops.iter().enumerate() {
                    match op % 4 {
                        0 | 1 => {
                            // Register fresh content; failure is legal only
                            // when everything resident is pinned.
                            match store.register(sparse_a(1000 + i as u64), None, &reg(), &cfg()) {
                                Ok((e, _)) => handles.push(e.handle),
                                Err(msg) => {
                                    if !msg.contains("pinned") {
                                        return Err(format!("unexpected register failure: {msg}"));
                                    }
                                }
                            }
                        }
                        2 => {
                            if let Some(&h) = handles.get(i % handles.len().max(1)) {
                                if let Some(p) = store.checkout(h) {
                                    pins.push(p);
                                }
                            }
                        }
                        _ => {
                            pins.pop(); // release an arbitrary pin
                        }
                    }
                    if store.bytes_used() > store.budget_bytes() {
                        return Err("byte budget exceeded".into());
                    }
                    for p in &pins {
                        if store.checkout(p.entry().handle).is_none() {
                            return Err("pinned entry was evicted".into());
                        }
                    }
                    // checkout() above pinned again and dropped immediately;
                    // drain those transient pins via the returned guards.
                }
                let expected: u64 =
                    store.list().iter().map(|s| s.bytes).sum();
                if store.bytes_used() != expected {
                    return Err("byte accounting drifted".into());
                }
                Ok(())
            },
        );
    }

    /// Cluster id admission: a sharded store only assigns handles its
    /// ring position owns, an unsharded store keeps the dense sequence,
    /// and K=1 sharding degenerates to exactly that dense sequence.
    #[test]
    fn sharded_store_assigns_only_owned_handles() {
        use super::super::shard::{Ring, ShardSpec};
        let plain = OperandStore::new(64 << 20);
        for (i, seed) in [21u64, 22, 23].iter().enumerate() {
            let (e, _) = plain.register(sparse_a(*seed), None, &reg(), &cfg()).unwrap();
            assert_eq!(e.handle, OperandId(i as u64 + 1), "unsharded: dense 1, 2, 3…");
        }
        let single = OperandStore::new(64 << 20);
        let k1 = CoordinatorConfig { shard: Some(ShardSpec::node_of(0, 1)), ..cfg() };
        for (i, seed) in [21u64, 22, 23].iter().enumerate() {
            let (e, _) = single.register(sparse_a(*seed), None, &reg(), &k1).unwrap();
            assert_eq!(e.handle, OperandId(i as u64 + 1), "K=1 is bit-for-bit the dense sequence");
        }
        // Three shards of one cluster: every assigned handle hashes back
        // to its assigner, and the three id partitions are disjoint.
        let ring = Ring::new(3, super::super::shard::DEFAULT_VNODES, super::super::shard::DEFAULT_RING_SEED);
        let mut seen = std::collections::HashSet::new();
        for node in 0..3u32 {
            let store = OperandStore::new(64 << 20);
            let shard = CoordinatorConfig { shard: Some(ShardSpec::node_of(node, 3)), ..cfg() };
            for seed in [31u64, 32, 33, 34] {
                let (e, _) = store.register(sparse_a(seed), None, &reg(), &shard).unwrap();
                assert_eq!(ring.owner(e.handle.0), node, "assigner owns its handles");
                assert!(seen.insert(e.handle.0), "id partitions are disjoint across nodes");
            }
        }
    }

    /// Slice isolation (ISSUE 9 acceptance b): one tenant's registration
    /// pressure evicts only its own entries, never another tenant's, and
    /// an unsatisfiable slice is a typed `QUOTA_EXCEEDED` error.
    #[test]
    fn tenant_slices_isolate_eviction_and_type_quota_errors() {
        use super::super::tenant::{TenantRegistry, TenantSpec};
        use super::super::tuner::ScriptedClock;
        let (probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(200), None, &reg(), &cfg())
            .unwrap();
        let eb = probe.bytes;
        let spec = |name: &str, slice: u64| TenantSpec {
            name: name.to_string(),
            weight: 1,
            rate_per_s: 0.0,
            burst: 0.0,
            store_slice_bytes: slice,
        };
        let clock = Arc::new(ScriptedClock::new(vec![]));
        let tenants = Arc::new(TenantRegistry::new(
            &[spec("alpha", eb * 3 / 2), spec("beta", eb * 3 / 2)],
            clock,
        ));
        let store = OperandStore::with_tiers(eb * 4, Some(tenants), None);
        let (ea, _) = store.register_for("alpha", sparse_a(201), None, &reg(), &cfg()).unwrap();
        let (eb1, _) = store.register_for("beta", sparse_a(202), None, &reg(), &cfg()).unwrap();
        assert_eq!((ea.tenant.as_str(), eb1.tenant.as_str()), ("alpha", "beta"));
        // alpha's second registration exceeds its slice: it must evict
        // alpha's own LRU entry and leave beta untouched.
        let (ea2, _) = store.register_for("alpha", sparse_a(203), None, &reg(), &cfg()).unwrap();
        assert!(store.checkout(eb1.handle).is_some(), "beta untouched by alpha's pressure");
        assert!(store.checkout(ea.handle).is_none(), "alpha evicted its own LRU entry");
        assert!(store.checkout(ea2.handle).is_some());
        assert!(store.tenant_bytes_of("alpha") <= eb * 3 / 2, "slice gauge holds");
        assert!(store.tenant_bytes_of("beta") > 0);
        // With alpha's only resident pinned, the next alpha registration
        // cannot fit its slice: typed quota error, nothing evicted.
        let _pin = store.checkout(ea2.handle).unwrap();
        let before = store.stats().evictions;
        let err = store.register_for("alpha", sparse_a(204), None, &reg(), &cfg()).unwrap_err();
        assert!(err.starts_with(QUOTA_EXCEEDED), "typed quota error, got: {err}");
        assert!(err.contains("`alpha`"), "{err}");
        assert_eq!(store.stats().evictions, before, "failed registration evicts nothing");
        assert!(store.checkout(eb1.handle).is_some(), "beta still resident");
    }

    /// Spill tier behind the store: eviction demotes the full entry to
    /// disk, `peek_dims` still answers, and a later checkout promotes it
    /// back bitwise — same sig, same dense bits, same version — with the
    /// spill gauges tracking every move.
    #[test]
    fn eviction_demotes_to_spill_and_checkout_promotes_bitwise() {
        let dir = std::env::temp_dir()
            .join(format!("gcoospdm_store_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (probe, _) = OperandStore::new(u64::MAX)
            .register(sparse_a(210), None, &reg(), &cfg())
            .unwrap();
        let ebytes = probe.bytes;
        let spill = SpillStore::new(&dir, 0).unwrap();
        let store = OperandStore::with_tiers(ebytes * 5 / 2, None, Some(spill));
        let (e1, _) = store.register(sparse_a(210), None, &reg(), &cfg()).unwrap();
        let sig1 = e1.sig;
        let a1_bits: Vec<u32> = e1.a.data.iter().map(|v| v.to_bits()).collect();
        let (e2, _) = store.register(sparse_a(211), None, &reg(), &cfg()).unwrap();
        drop(store.checkout(e2.handle)); // e1 becomes the LRU victim
        let (e3, _) = store.register(sparse_a(212), None, &reg(), &cfg()).unwrap();
        let st = store.stats();
        assert_eq!((st.evictions, st.spill_writes), (1, 1), "eviction demoted e1");
        assert!(st.spill_bytes > 0);
        let listed = store.list();
        assert_eq!(listed.iter().filter(|s| s.tier == "spilled").count(), 1);
        assert_eq!(listed.iter().filter(|s| s.tier == "ram").count(), 2);
        let spilled_row = listed.iter().find(|s| s.handle == e1.handle).unwrap();
        assert_eq!((spilled_row.tier, spilled_row.n), ("spilled", 64));
        assert_eq!(store.peek_dims(e1.handle), Some(64), "spilled handle answers dims");
        // Checkout promotes by one sequential read: bitwise dense A, same
        // sig and version, served as a hit. Making room demotes the LRU
        // RAM resident (e2) in cascade.
        let pin = store.checkout(e1.handle).expect("promoted");
        assert_eq!(pin.entry().sig, sig1);
        assert_eq!(pin.entry().version, 1);
        let bits: Vec<u32> = pin.entry().a.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, a1_bits, "promoted dense A is bitwise identical");
        let st = store.stats();
        assert_eq!(st.spill_promotes, 1);
        assert_eq!(st.spill_writes, 2, "promotion demoted the RAM LRU in cascade");
        assert!(store.bytes_used() <= store.budget_bytes());
        assert!(store.checkout(e3.handle).is_some(), "most-recent resident survived");
        drop(pin);
        // An explicit drop reaches the spill tier too.
        assert!(store.remove(e2.handle), "spilled handle drops");
        assert!(store.checkout(e2.handle).is_none());
        assert_eq!(store.stats().spill_bytes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Cluster replication hook: the replica installs under the original
    /// handle with bitwise-identical dense content and the same plan,
    /// charges its own budget, counts no hit/miss gauges, and is
    /// idempotent.
    #[test]
    fn register_replica_is_forced_handle_bitwise_and_idempotent() {
        use super::super::shard::ShardSpec;
        let owner = OperandStore::new(64 << 20);
        let owner_cfg = CoordinatorConfig { shard: Some(ShardSpec::node_of(0, 3)), ..cfg() };
        let (src, _) = owner.register(sparse_a(40), None, &reg(), &owner_cfg).unwrap();

        let replica = OperandStore::new(64 << 20);
        let replica_cfg = CoordinatorConfig { shard: Some(ShardSpec::node_of(1, 3)), ..cfg() };
        let e = replica.register_replica(&src, &replica_cfg).unwrap();
        assert_eq!(e.handle, src.handle, "replica keeps the owner's handle");
        assert_eq!(e.a.data, src.a.data, "shipped A is bitwise identical");
        assert_eq!(e.plan.algo, src.plan.algo);
        assert_eq!(e.plan.artifact, src.plan.artifact);
        assert_eq!(e.bytes, src.bytes, "deterministic conversion, same footprint");
        assert_eq!(replica.bytes_used(), e.bytes);
        let st = replica.stats();
        assert_eq!((st.hits, st.misses), (0, 0), "replication is control-plane: no gauges");
        // Idempotent: same resident entry, no second charge.
        let e2 = replica.register_replica(&src, &replica_cfg).unwrap();
        assert!(Arc::ptr_eq(&e, &e2));
        assert_eq!(replica.bytes_used(), e.bytes);
        // The replica serves checkouts exactly like a local registration.
        let pin = replica.checkout(src.handle).expect("replica serves the handle");
        assert_eq!(pin.a.data, src.a.data);
    }
}
