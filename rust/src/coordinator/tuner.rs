//! Adaptive measured routing — the tuner behind `Selector::plan_with_model`
//! and the operand store's route flips.
//!
//! The paper picks GCOO vs dense by fixed sparsity/size crossovers measured
//! on three specific GPUs and names auto-tuning the selection parameters as
//! future work; Yang et al. (PAPERS.md) show the winning algorithm is
//! input-structure-dependent in ways no static threshold captures. This
//! module lets the serving path *measure* its way to the best plan:
//!
//! * [`PerfModel`] keeps per-key (registered operand or inline signature),
//!   per-algorithm EWMA estimates of measured convert+kernel cost **per
//!   executed column** (so width-1 and fused-batch observations are
//!   comparable), each clamped to its observed sample bounds and gated
//!   behind a minimum sample count — an ungated estimate is never consulted.
//! * [`explore_draw`] is the seeded exploration policy: a **pure function**
//!   of (seed, key, request index), so every routing decision a live
//!   coordinator makes can be mirrored exactly by a test.
//! * [`Tuner`] owns the model, the per-key request counters, the
//!   exploration/flip counters surfaced in `/stats`, and the injected
//!   [`Clock`] the pipeline brackets executions with — production uses
//!   [`RealClock`]; tests use [`ScriptedClock`] so measured latencies (and
//!   therefore every choice, including the exact flip request index) are
//!   deterministic.
//!
//! Routing can change **choices**, never **results**: every algorithm
//! family accumulates each output element over ascending k in f32 (the
//! reference kernels and the dense oracle share that order), so a route
//! flip or exploration changes the response's algo/artifact provenance
//! while C stays bitwise identical — the invariant
//! `tests/routing_differential.rs` locks down.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::store::{OperandEntry, OperandId};
use crate::runtime::{Algo, ExecPlan};

/// Injected time source for latency measurement. Production brackets
/// executions with [`RealClock`]; tests script every read.
pub trait Clock: Send + Sync {
    /// Monotonic seconds since an arbitrary origin.
    fn now_s(&self) -> f64;
}

/// Monotonic wall clock (origin = construction).
pub struct RealClock(Instant);

impl RealClock {
    pub fn new() -> Self {
        RealClock(Instant::now())
    }
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock::new()
    }
}

impl Clock for RealClock {
    fn now_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

struct ScriptState {
    /// Absolute times handed out in order; when exhausted, each read
    /// advances `last` by `step` (so reads stay deterministic forever).
    script: std::collections::VecDeque<f64>,
    last: f64,
    step: f64,
    reads: u64,
    /// Where `push_latency` appends its next bracketing pair.
    cursor: f64,
}

/// Fully scripted clock: each `now_s` read pops the next scripted absolute
/// time; once the script is exhausted, reads advance by a fixed step. The
/// pipeline performs exactly **two reads per observed execution** (start +
/// end), so a test scripting pairs controls every measured latency —
/// [`ScriptedClock::push_latency`] appends one such pair.
pub struct ScriptedClock {
    state: Mutex<ScriptState>,
}

impl ScriptedClock {
    /// Scripted reads from `script` (absolute seconds), then a fixed
    /// `1e-3` step per read.
    pub fn new(script: Vec<f64>) -> Self {
        ScriptedClock::with_step(script, 1e-3)
    }

    pub fn with_step(script: Vec<f64>, step: f64) -> Self {
        let cursor = script.iter().copied().fold(0.0, f64::max) + 1.0;
        ScriptedClock {
            state: Mutex::new(ScriptState {
                script: script.into(),
                last: 0.0,
                step,
                reads: 0,
                cursor,
            }),
        }
    }

    /// Append one bracketing pair (t, t + `latency_s`): the next observed
    /// execution will measure exactly `latency_s`. Use exactly-representable
    /// latencies (powers of two) when mirroring EWMA arithmetic in a test.
    pub fn push_latency(&self, latency_s: f64) {
        let mut g = self.state.lock().unwrap();
        let t = g.cursor;
        g.script.push_back(t);
        g.script.push_back(t + latency_s);
        g.cursor = t + latency_s + 1.0;
    }

    /// Reads consumed so far (test diagnostics).
    pub fn reads(&self) -> u64 {
        self.state.lock().unwrap().reads
    }
}

impl Clock for ScriptedClock {
    fn now_s(&self) -> f64 {
        let mut g = self.state.lock().unwrap();
        g.reads += 1;
        match g.script.pop_front() {
            Some(t) => {
                g.last = t;
                t
            }
            None => {
                g.last += g.step;
                g.last
            }
        }
    }
}

/// Tuning knobs (Copy — embedded in `CoordinatorConfig`). Disabled by
/// default: static paper-threshold routing is the contract every earlier
/// suite pins; adaptive serving opts in.
#[derive(Clone, Copy, Debug)]
pub struct TunerConfig {
    /// Master switch: false ⇒ the pipeline behaves exactly as static.
    pub enabled: bool,
    /// EWMA weight of each new sample (0 < alpha ≤ 1).
    pub alpha: f64,
    /// Samples required before an estimate may be consulted (the gate).
    pub min_samples: u64,
    /// Explore the non-incumbent candidate when the seeded draw fires,
    /// ~1-in-`explore_every` requests (0 disables exploration).
    pub explore_every: u64,
    /// Seed of the pure exploration draw.
    pub seed: u64,
    /// `put_a` measured refinement: how many exploration-tail candidates
    /// the trace-derived cost oracle (`simgpu::TraceOracle`, deterministic
    /// at a fixed seed) measures to rank them (0 = off).
    pub register_refine_budget: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            enabled: false,
            alpha: 0.25,
            min_samples: 3,
            explore_every: 8,
            seed: 0x7E57_5EED,
            register_refine_budget: 0,
        }
    }
}

/// What the model keys estimates by: a registered operand (handle) or an
/// inline request's content signature. The top bit namespaces the two so a
/// small handle id can never alias a signature hash.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelKey(pub u64);

impl ModelKey {
    pub fn operand(h: OperandId) -> ModelKey {
        ModelKey(h.0 | 1 << 63)
    }

    pub fn signature(hash: u64) -> ModelKey {
        ModelKey(hash & !(1 << 63))
    }
}

/// Pure seeded exploration draw: whether request `idx` against `key`
/// explores the non-incumbent candidate. Deterministic by construction —
/// tests mirror live routing by calling this with the same arguments.
pub fn explore_draw(seed: u64, key: ModelKey, idx: u64, every: u64) -> bool {
    if every == 0 {
        return false;
    }
    let mut s = seed
        ^ key.0.rotate_left(17)
        ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    crate::rng::splitmix64(&mut s) % every == 0
}

/// One (key, algo) online estimate: EWMA mean clamped into the observed
/// sample hull, plus the gate count.
#[derive(Clone, Copy, Debug)]
struct Estimate {
    mean: f64,
    samples: u64,
    lo: f64,
    hi: f64,
}

/// Fixed, deterministic order estimates are reported in (ties in measured
/// cost must not depend on hash-map iteration order).
const ALGO_ORDER: [Algo; 7] = [
    Algo::Gcoo,
    Algo::Csr,
    Algo::DenseXla,
    Algo::GcooNoreuse,
    Algo::DensePallas,
    Algo::Cmrs,
    Algo::RowSplit,
];

/// Per-key, per-algo EWMA latency model (seconds per executed column).
pub struct PerfModel {
    alpha: f64,
    min_samples: u64,
    estimates: Mutex<HashMap<(ModelKey, Algo), Estimate>>,
}

impl PerfModel {
    pub fn new(alpha: f64, min_samples: u64) -> Self {
        PerfModel { alpha, min_samples, estimates: Mutex::new(HashMap::new()) }
    }

    /// Fold one measured cost-per-column sample in.
    pub fn observe(&self, key: ModelKey, algo: Algo, cost_per_col: f64) {
        let x = cost_per_col.max(0.0);
        let mut g = self.estimates.lock().unwrap();
        let e = g.entry((key, algo)).or_insert(Estimate { mean: x, samples: 0, lo: x, hi: x });
        e.lo = e.lo.min(x);
        e.hi = e.hi.max(x);
        // EWMA, clamped into the observed hull so the "estimates stay
        // within sample bounds" invariant holds exactly (fp rounding of
        // mean + α·(x − mean) could otherwise drift an ulp outside).
        e.mean = (e.mean + self.alpha * (x - e.mean)).clamp(e.lo, e.hi);
        e.samples += 1;
    }

    /// Sample-count-gated estimate: `None` until `min_samples` have been
    /// observed — callers can never consult an under-sampled mean.
    pub fn estimate(&self, key: ModelKey, algo: Algo) -> Option<f64> {
        self.estimates
            .lock()
            .unwrap()
            .get(&(key, algo))
            .filter(|e| e.samples >= self.min_samples)
            .map(|e| e.mean)
    }

    /// All gated estimates for `key`, in the fixed [`ALGO_ORDER`] (the
    /// deterministic tie-break `plan_with_model` relies on).
    pub fn estimates_for(&self, key: ModelKey) -> Vec<(Algo, f64)> {
        let g = self.estimates.lock().unwrap();
        ALGO_ORDER
            .iter()
            .filter_map(|&algo| {
                g.get(&(key, algo))
                    .filter(|e| e.samples >= self.min_samples)
                    .map(|e| (algo, e.mean))
            })
            .collect()
    }

    /// Ungated view for observability (`explain`): (algo, mean, samples,
    /// gated) in the fixed order.
    pub fn view(&self, key: ModelKey) -> Vec<(Algo, f64, u64, bool)> {
        let g = self.estimates.lock().unwrap();
        ALGO_ORDER
            .iter()
            .filter_map(|&algo| {
                g.get(&(key, algo))
                    .map(|e| (algo, e.mean, e.samples, e.samples >= self.min_samples))
            })
            .collect()
    }
}

/// The adaptive-routing subsystem one coordinator owns: clock, model,
/// per-key request counters, and the exploration/flip counters `/stats`
/// and `explain` surface.
pub struct Tuner {
    cfg: TunerConfig,
    clock: Arc<dyn Clock>,
    model: PerfModel,
    indices: Mutex<HashMap<ModelKey, u64>>,
    explorations: AtomicU64,
    flips: AtomicU64,
}

impl Tuner {
    pub fn new(cfg: TunerConfig, clock: Arc<dyn Clock>) -> Self {
        Tuner {
            cfg,
            clock,
            model: PerfModel::new(cfg.alpha.clamp(f64::MIN_POSITIVE, 1.0), cfg.min_samples),
            indices: Mutex::new(HashMap::new()),
            explorations: AtomicU64::new(0),
            flips: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn config(&self) -> TunerConfig {
        self.cfg
    }

    /// One clock read (the pipeline brackets each observed execution with
    /// exactly two of these).
    pub fn now_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Claim the next request index for `key` (the exploration draw's
    /// third argument).
    pub fn next_index(&self, key: ModelKey) -> u64 {
        let mut g = self.indices.lock().unwrap();
        let c = g.entry(key).or_insert(0);
        let idx = *c;
        *c += 1;
        idx
    }

    /// Requests routed against `key` so far (observability).
    pub fn requests_for(&self, key: ModelKey) -> u64 {
        self.indices.lock().unwrap().get(&key).copied().unwrap_or(0)
    }

    /// The seeded draw for this tuner's seed/period.
    pub fn draw(&self, key: ModelKey, idx: u64) -> bool {
        explore_draw(self.cfg.seed, key, idx, self.cfg.explore_every)
    }

    /// Fold one bracketed execution in: `dt_s` covered `cols` executed
    /// B columns (width · n_exec for a fused batch).
    pub fn observe(&self, key: ModelKey, algo: Algo, cols: usize, dt_s: f64) {
        self.model.observe(key, algo, dt_s.max(0.0) / cols.max(1) as f64);
    }

    /// Gated estimate (seconds per executed column).
    pub fn estimate(&self, key: ModelKey, algo: Algo) -> Option<f64> {
        self.model.estimate(key, algo)
    }

    /// Gated estimates in deterministic order (the `plan_with_model` feed).
    pub fn estimates_for(&self, key: ModelKey) -> Vec<(Algo, f64)> {
        self.model.estimates_for(key)
    }

    /// Ungated estimate view for `explain`.
    pub fn estimates_view(&self, key: ModelKey) -> Vec<(Algo, f64, u64, bool)> {
        self.model.view(key)
    }

    /// The measured route-flip rule: with the incumbent's estimate gated,
    /// the cheapest gated non-incumbent candidate that is strictly faster
    /// wins. Returns the candidate plan (width 1, reason "measured-flip")
    /// the entry should be republished under, or `None`.
    pub fn best_alternative(&self, key: ModelKey, entry: &OperandEntry) -> Option<ExecPlan> {
        let incumbent = self.estimate(key, entry.plan.algo)?;
        let mut best: Option<(f64, &ExecPlan)> = None;
        for cand in &entry.candidates {
            if cand.algo == entry.plan.algo {
                continue;
            }
            if let Some(m) = self.estimate(key, cand.algo) {
                if m < incumbent && best.map_or(true, |(bm, _)| m < bm) {
                    best = Some((m, cand));
                }
            }
        }
        best.map(|(_, p)| {
            let mut p = p.clone();
            p.reason = "measured-flip";
            p.width = 1;
            p
        })
    }

    pub fn record_exploration(&self) {
        self.explorations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn explorations_total(&self) -> u64 {
        self.explorations.load(Ordering::Relaxed)
    }

    pub fn record_flip(&self) {
        self.flips.fetch_add(1, Ordering::Relaxed);
    }

    pub fn route_flips(&self) -> u64 {
        self.flips.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Config};

    fn key(x: u64) -> ModelKey {
        ModelKey::signature(x)
    }

    #[test]
    fn scripted_clock_replays_script_then_steps() {
        let c = ScriptedClock::with_step(vec![1.0, 3.5], 0.5);
        assert_eq!(c.now_s(), 1.0);
        assert_eq!(c.now_s(), 3.5);
        assert_eq!(c.now_s(), 4.0, "exhausted script advances by the step");
        assert_eq!(c.now_s(), 4.5);
        assert_eq!(c.reads(), 4);
        // push_latency appends an exact bracketing pair.
        c.push_latency(0.5);
        let t0 = c.now_s();
        let t1 = c.now_s();
        assert_eq!(t1 - t0, 0.5);
    }

    #[test]
    fn model_keys_namespace_handles_and_signatures() {
        // A small handle id must never alias a signature with the same
        // low bits.
        assert_ne!(ModelKey::operand(OperandId(7)), ModelKey::signature(7));
        assert_eq!(ModelKey::operand(OperandId(7)), ModelKey::operand(OperandId(7)));
    }

    /// Property (satellite): EWMA estimates stay within the observed
    /// sample bounds, whatever the sample sequence and alpha.
    #[test]
    fn prop_ewma_within_observed_bounds() {
        check(
            Config { cases: 64, base_seed: 0x73B4, ..Default::default() },
            |g| {
                let alpha = g.f64_in(0.05, 1.0);
                let xs: Vec<f64> =
                    (0..g.usize_in(1, 24)).map(|_| g.f64_in(1e-9, 1e-2)).collect();
                (alpha, xs)
            },
            |(alpha, xs)| {
                let m = PerfModel::new(*alpha, 1);
                let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
                for &x in xs {
                    m.observe(key(1), Algo::Gcoo, x);
                    lo = lo.min(x);
                    hi = hi.max(x);
                    let e = m.estimate(key(1), Algo::Gcoo).expect("min_samples=1");
                    if !(lo..=hi).contains(&e) {
                        return Err(format!("estimate {e} outside [{lo}, {hi}]"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Property (satellite): the sample-count gate never exposes an
    /// estimate below the threshold, and opens exactly at it.
    #[test]
    fn prop_gate_never_consults_undersampled_estimates() {
        check(
            Config { cases: 32, base_seed: 0x6A7E, ..Default::default() },
            |g| (g.usize_in(1, 8) as u64, g.usize_in(0, 12)),
            |(min_samples, observations)| {
                let m = PerfModel::new(0.5, *min_samples);
                for i in 0..*observations {
                    let gated_before = m.estimate(key(9), Algo::Csr).is_some();
                    if (i as u64) < *min_samples && gated_before {
                        return Err(format!("gate opened at {i} < {min_samples}"));
                    }
                    m.observe(key(9), Algo::Csr, 1e-6 * (i + 1) as f64);
                }
                let gated = m.estimate(key(9), Algo::Csr).is_some();
                if gated != (*observations as u64 >= *min_samples) {
                    return Err(format!(
                        "gate after {observations} samples (min {min_samples}): {gated}"
                    ));
                }
                // estimates_for must agree with the per-algo gate.
                if m.estimates_for(key(9)).is_empty() == gated {
                    return Err("estimates_for disagrees with the gate".into());
                }
                Ok(())
            },
        );
    }

    /// Property (satellite): exploration draws are a pure function of
    /// (seed, key, index) — same inputs, same draw, across tuners.
    #[test]
    fn prop_exploration_draw_is_pure() {
        check(
            Config { cases: 64, base_seed: 0xD4A3, ..Default::default() },
            |g| {
                (
                    g.rng.next_u64(),
                    g.rng.next_u64(),
                    g.rng.next_u64() % 1000,
                    g.usize_in(0, 9) as u64,
                )
            },
            |(seed, k, idx, every)| {
                let a = explore_draw(*seed, key(*k), *idx, *every);
                let b = explore_draw(*seed, key(*k), *idx, *every);
                if a != b {
                    return Err("draw not deterministic".into());
                }
                if *every == 0 && a {
                    return Err("explore_every=0 must never draw".into());
                }
                // A live tuner's draw is the same pure function.
                let t = Tuner::new(
                    TunerConfig {
                        enabled: true,
                        seed: *seed,
                        explore_every: *every,
                        ..Default::default()
                    },
                    Arc::new(ScriptedClock::new(vec![])),
                );
                if t.draw(key(*k), *idx) != a {
                    return Err("Tuner::draw diverges from explore_draw".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn draw_fires_and_skips_over_a_window() {
        // Sanity: with every=4, a 64-request window both explores and
        // exploits (the draw is pseudo-random, not a fixed stride).
        let fired: Vec<bool> =
            (0..64).map(|i| explore_draw(42, key(5), i, 4)).collect();
        assert!(fired.iter().any(|&b| b));
        assert!(fired.iter().any(|&b| !b));
    }

    #[test]
    fn estimates_for_reports_in_fixed_order() {
        let m = PerfModel::new(0.5, 1);
        m.observe(key(2), Algo::DenseXla, 3e-6);
        m.observe(key(2), Algo::Gcoo, 3e-6);
        m.observe(key(2), Algo::Csr, 3e-6);
        let algos: Vec<Algo> = m.estimates_for(key(2)).iter().map(|(a, _)| *a).collect();
        assert_eq!(algos, vec![Algo::Gcoo, Algo::Csr, Algo::DenseXla]);
    }

    #[test]
    fn request_indices_count_per_key() {
        let t = Tuner::new(TunerConfig::default(), Arc::new(ScriptedClock::new(vec![])));
        assert_eq!(t.next_index(key(1)), 0);
        assert_eq!(t.next_index(key(1)), 1);
        assert_eq!(t.next_index(key(2)), 0, "indices are per key");
        assert_eq!(t.requests_for(key(1)), 2);
        assert_eq!(t.requests_for(key(3)), 0);
    }

    #[test]
    fn observe_normalizes_per_column() {
        let t = Tuner::new(
            TunerConfig { min_samples: 1, alpha: 1.0, ..Default::default() },
            Arc::new(ScriptedClock::new(vec![])),
        );
        // 64 columns in 6.4e-3 s and 128 columns in 1.28e-2 s are the same
        // per-column cost.
        t.observe(key(4), Algo::Gcoo, 64, 6.4e-3);
        let e1 = t.estimate(key(4), Algo::Gcoo).unwrap();
        t.observe(key(4), Algo::Gcoo, 128, 1.28e-2);
        let e2 = t.estimate(key(4), Algo::Gcoo).unwrap();
        assert!((e1 - 1e-4).abs() < 1e-12);
        assert!((e2 - 1e-4).abs() < 1e-12);
    }

    // best_alternative needs OperandEntry fixtures; its flip-rule coverage
    // lives in store.rs (reroute tests) and in
    // tests/routing_differential.rs (exact flip index under a scripted
    // clock through a live coordinator).
}
