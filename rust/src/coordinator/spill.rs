//! Disk-backed spill tier behind the operand store (ISSUE 9).
//!
//! The paper's economics — pay the conversion overhead (EO) once,
//! amortize it across every reuse — stop at the RAM budget today: an
//! eviction destroys the converted slabs and the next reference pays a
//! full O(n²) rescan + reconvert. The spill tier extends the storage
//! hierarchy one level: on eviction the entry's **already-converted**
//! [`DeviceOperand`] serializes to a length-prefixed slab file (raw
//! little-endian, the same codec discipline as wire v3) together with its
//! `ASig`, plan, candidates, stats, and dense A; a later handle miss
//! checks the spill index before failing and **promotes** the entry back
//! by one sequential read — no rescan, no reconvert — then verifies the
//! content signature bit-for-bit before serving. Residency moves, result
//! bits never do.
//!
//! File format (version 1, all integers little-endian; `str` = u16 byte
//! length + UTF-8; `slab` = u64 byte length + raw LE elements):
//!
//! | section    | layout                                                   |
//! |------------|----------------------------------------------------------|
//! | header     | magic `GSPL` (4) · version u8                            |
//! | identity   | tenant str · handle u64 · entry version u64              |
//! | sig        | rows u64 · cols u64 · nnz u64 · hash u64                 |
//! | hint       | u8 (0 = none, else algo byte)                            |
//! | plan       | algo u8 · n_exec u64 · cap u64 · width u64 · artifact str · reason str |
//! | candidates | u16 count · plan …                                       |
//! | stats      | rows u64 · cols u64 · p u64 · nnz u64 · max_row_nnz u64 · u32 count · u32 … |
//! | convert_s  | f64                                                      |
//! | dense A    | rows u64 · cols u64 · f32 slab                           |
//! | operand    | tag u8 (0 gcoo · 1 ell · 2 dense · 3 cmrs · 4 rowsplit) · geometry · slabs |
//! | footer     | entry bytes u64                                          |
//!
//! The dense A is serialized outright rather than reconstructed from the
//! slabs on promote: the nnz scan drops explicit `-0.0` entries, so a
//! slab-reconstructed A could differ from the registered A in sign bits
//! and break the `ASig` bit-hash — and the oracle/fallback paths need
//! the exact dense operand anyway. `ExecPlan::reason` is `&'static str`;
//! promotion interns the stored reason against the selector/tuner
//! vocabulary and falls back to `"restored"` for anything unknown.
//!
//! The tier is byte-budgeted like the RAM store: oldest spill files are
//! deleted first when the budget overflows (the tier below disk is
//! nothing — the conversion is then genuinely lost). Gauges
//! (`spill_writes` / `spill_promotes` / `spill_bytes`) surface through
//! `StoreStats` → `/stats`, `explain`, and the cluster's
//! `aggregate_snapshots`.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::job::{ASig, Algo};
use super::store::{OperandEntry, OperandId};
use crate::convert::AStats;
use crate::ndarray::Mat;
use crate::runtime::{DeviceOperand, ExecPlan};
use crate::sparse::{CmrsPadded, Ell, GcooPadded, RowSplitPadded};

const MAGIC: &[u8; 4] = b"GSPL";
const VERSION: u8 = 1;

/// Every `&'static` reason the selector/tuner stack publishes; promotion
/// interns against this vocabulary (see `intern_reason`).
const REASONS: &[&str] = &[
    "hint",
    "sparse-crossover",
    "gcoo-capacity-fallback",
    "sparse-capacity-exhausted",
    "below-crossover",
    "candidate",
    "measured",
    "explore",
    "measured-flip",
    "restored",
];

fn intern_reason(s: &str) -> &'static str {
    for r in REASONS {
        if s == *r {
            return r;
        }
    }
    "restored"
}

fn algo_byte(a: Algo) -> u8 {
    match a {
        Algo::Gcoo => 1,
        Algo::GcooNoreuse => 2,
        Algo::Csr => 3,
        Algo::DenseXla => 4,
        Algo::DensePallas => 5,
        Algo::Cmrs => 6,
        Algo::RowSplit => 7,
    }
}

fn algo_from(b: u8) -> Result<Algo, String> {
    Ok(match b {
        1 => Algo::Gcoo,
        2 => Algo::GcooNoreuse,
        3 => Algo::Csr,
        4 => Algo::DenseXla,
        5 => Algo::DensePallas,
        6 => Algo::Cmrs,
        7 => Algo::RowSplit,
        other => return Err(format!("spill: unknown algo byte {other}")),
    })
}

// ---- encoder ------------------------------------------------------------

struct Wr {
    out: Vec<u8>,
}

impl Wr {
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        assert!(s.len() <= u16::MAX as usize, "spill string too long");
        self.u16(s.len() as u16);
        self.out.extend_from_slice(s.as_bytes());
    }
    fn f32_slab(&mut self, v: &[f32]) {
        self.u64((v.len() * 4) as u64);
        for x in v {
            self.out.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn i32_slab(&mut self, v: &[i32]) {
        self.u64((v.len() * 4) as u64);
        for x in v {
            self.out.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn plan(&mut self, p: &ExecPlan) {
        self.u8(algo_byte(p.algo));
        self.u64(p.n_exec as u64);
        self.u64(p.cap as u64);
        self.u64(p.width as u64);
        self.str(&p.artifact);
        self.str(p.reason);
    }
}

// ---- decoder ------------------------------------------------------------

struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err(format!(
                "spill: truncated file (need {} bytes at offset {}, have {})",
                n,
                self.i,
                self.b.len()
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn usize(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("spill: value {v} overflows usize"))
    }
    fn str(&mut self) -> Result<String, String> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "spill: invalid UTF-8".to_string())
    }
    fn f32_slab(&mut self) -> Result<Vec<f32>, String> {
        let bytes = self.usize()?;
        if bytes % 4 != 0 {
            return Err(format!("spill: f32 slab length {bytes} not a multiple of 4"));
        }
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn i32_slab(&mut self) -> Result<Vec<i32>, String> {
        let bytes = self.usize()?;
        if bytes % 4 != 0 {
            return Err(format!("spill: i32 slab length {bytes} not a multiple of 4"));
        }
        let raw = self.take(bytes)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn plan(&mut self) -> Result<ExecPlan, String> {
        let algo = algo_from(self.u8()?)?;
        let n_exec = self.usize()?;
        let cap = self.usize()?;
        let width = self.usize()?;
        let artifact = self.str()?;
        let reason = intern_reason(&self.str()?);
        Ok(ExecPlan { algo, n_exec, cap, artifact, reason, width })
    }
    fn done(&self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err(format!(
                "spill: {} trailing bytes after decode",
                self.b.len() - self.i
            ));
        }
        Ok(())
    }
}

fn encode_entry(entry: &OperandEntry, tenant: &str) -> Vec<u8> {
    let mut w = Wr { out: Vec::with_capacity(entry.bytes as usize + 256) };
    w.out.extend_from_slice(MAGIC);
    w.u8(VERSION);
    w.str(tenant);
    w.u64(entry.handle.0);
    w.u64(entry.version);
    w.u64(entry.sig.rows as u64);
    w.u64(entry.sig.cols as u64);
    w.u64(entry.sig.nnz as u64);
    w.u64(entry.sig.hash);
    w.u8(entry.hint.map_or(0, algo_byte));
    w.plan(&entry.plan);
    assert!(entry.candidates.len() <= u16::MAX as usize);
    w.u16(entry.candidates.len() as u16);
    for c in &entry.candidates {
        w.plan(c);
    }
    w.u64(entry.stats.rows as u64);
    w.u64(entry.stats.cols as u64);
    w.u64(entry.stats.p as u64);
    w.u64(entry.stats.nnz as u64);
    w.u64(entry.stats.max_row_nnz as u64);
    w.u32(entry.stats.nnz_per_band.len() as u32);
    for &b in &entry.stats.nnz_per_band {
        w.u32(b);
    }
    w.f64(entry.convert_s);
    w.u64(entry.a.rows as u64);
    w.u64(entry.a.cols as u64);
    w.f32_slab(&entry.a.data);
    match &entry.operand {
        DeviceOperand::Gcoo(g) => {
            w.u8(0);
            w.u64(g.g as u64);
            w.u64(g.cap as u64);
            w.u64(g.p as u64);
            w.u64(g.n as u64);
            w.f32_slab(&g.vals);
            w.i32_slab(&g.rows);
            w.i32_slab(&g.cols);
        }
        DeviceOperand::Ell(e) => {
            w.u8(1);
            w.u64(e.n as u64);
            w.u64(e.rowcap as u64);
            w.f32_slab(&e.vals);
            w.i32_slab(&e.cols);
        }
        DeviceOperand::Dense(m) => {
            w.u8(2);
            w.u64(m.rows as u64);
            w.u64(m.cols as u64);
            w.f32_slab(&m.data);
        }
        DeviceOperand::Cmrs(c) => {
            w.u8(3);
            w.u64(c.g as u64);
            w.u64(c.cap as u64);
            w.u64(c.p as u64);
            w.u64(c.n as u64);
            w.f32_slab(&c.vals);
            w.i32_slab(&c.rows);
            w.i32_slab(&c.cols);
        }
        DeviceOperand::RowSplit(r) => {
            w.u8(4);
            w.u64(r.segs as u64);
            w.u64(r.cap as u64);
            w.u64(r.n as u64);
            w.f32_slab(&r.vals);
            w.i32_slab(&r.seg_rows);
            w.i32_slab(&r.cols);
        }
    }
    w.u64(entry.bytes);
    w.out
}

/// A spilled entry decoded back from disk: every field the store needs to
/// republish the operand (the store reconstructs the `OperandEntry` — its
/// pin counter is store-private).
#[derive(Debug)]
pub struct RestoredEntry {
    pub tenant: String,
    pub handle: OperandId,
    pub version: u64,
    pub a: Mat,
    pub sig: ASig,
    pub hint: Option<Algo>,
    pub stats: AStats,
    pub plan: ExecPlan,
    pub candidates: Vec<ExecPlan>,
    pub operand: DeviceOperand,
    pub convert_s: f64,
    pub bytes: u64,
}

fn decode_entry(buf: &[u8]) -> Result<RestoredEntry, String> {
    let mut r = Rd { b: buf, i: 0 };
    if r.take(4)? != MAGIC {
        return Err("spill: bad magic".to_string());
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(format!("spill: unsupported file version {version}"));
    }
    let tenant = r.str()?;
    let handle = OperandId(r.u64()?);
    let entry_version = r.u64()?;
    let sig = ASig {
        rows: r.usize()?,
        cols: r.usize()?,
        nnz: r.usize()?,
        hash: r.u64()?,
    };
    let hint = match r.u8()? {
        0 => None,
        b => Some(algo_from(b)?),
    };
    let plan = r.plan()?;
    let n_cand = r.u16()? as usize;
    let mut candidates = Vec::with_capacity(n_cand);
    for _ in 0..n_cand {
        candidates.push(r.plan()?);
    }
    let stats = AStats {
        rows: r.usize()?,
        cols: r.usize()?,
        p: r.usize()?,
        nnz: r.usize()?,
        max_row_nnz: r.usize()?,
        nnz_per_band: {
            let count = r.u32()? as usize;
            let mut v = Vec::with_capacity(count);
            for _ in 0..count {
                v.push(r.u32()?);
            }
            v
        },
    };
    let convert_s = r.f64()?;
    let a_rows = r.usize()?;
    let a_cols = r.usize()?;
    let a_data = r.f32_slab()?;
    if a_data.len() != a_rows * a_cols {
        return Err(format!(
            "spill: dense A slab holds {} floats for a {a_rows}x{a_cols} matrix",
            a_data.len()
        ));
    }
    let a = Mat { rows: a_rows, cols: a_cols, data: a_data };
    let operand = match r.u8()? {
        0 => DeviceOperand::Gcoo(GcooPadded {
            g: r.usize()?,
            cap: r.usize()?,
            p: r.usize()?,
            n: r.usize()?,
            vals: r.f32_slab()?,
            rows: r.i32_slab()?,
            cols: r.i32_slab()?,
        }),
        1 => DeviceOperand::Ell(Ell {
            n: r.usize()?,
            rowcap: r.usize()?,
            vals: r.f32_slab()?,
            cols: r.i32_slab()?,
        }),
        2 => {
            let rows = r.usize()?;
            let cols = r.usize()?;
            let data = r.f32_slab()?;
            if data.len() != rows * cols {
                return Err("spill: dense operand slab/geometry mismatch".to_string());
            }
            DeviceOperand::Dense(Mat { rows, cols, data })
        }
        3 => DeviceOperand::Cmrs(CmrsPadded {
            g: r.usize()?,
            cap: r.usize()?,
            p: r.usize()?,
            n: r.usize()?,
            vals: r.f32_slab()?,
            rows: r.i32_slab()?,
            cols: r.i32_slab()?,
        }),
        4 => DeviceOperand::RowSplit(RowSplitPadded {
            segs: r.usize()?,
            cap: r.usize()?,
            n: r.usize()?,
            vals: r.f32_slab()?,
            seg_rows: r.i32_slab()?,
            cols: r.i32_slab()?,
        }),
        other => return Err(format!("spill: unknown operand tag {other}")),
    };
    let bytes = r.u64()?;
    r.done()?;
    Ok(RestoredEntry {
        tenant,
        handle,
        version: entry_version,
        a,
        sig,
        hint,
        stats,
        plan,
        candidates,
        operand,
        convert_s,
        bytes,
    })
}

// ---- the tier -----------------------------------------------------------

/// One spilled entry's index row (`list_a` tier = `spilled`).
#[derive(Clone, Debug)]
pub struct SpillRow {
    pub handle: OperandId,
    pub tenant: String,
    pub n: usize,
    pub nnz: usize,
    pub algo: Algo,
    pub artifact: String,
    /// RAM bytes the entry will charge again when promoted.
    pub entry_bytes: u64,
    /// The store tick the entry was last used at before demotion.
    pub last_used_seq: u64,
}

struct Meta {
    row: SpillRow,
    path: PathBuf,
    file_bytes: u64,
    seq: u64,
}

struct SpillInner {
    index: HashMap<u64, Meta>,
    /// Demotion order (sequence numbers) for oldest-first budget eviction.
    order: VecDeque<u64>,
    bytes: u64,
    next_seq: u64,
}

/// Point-in-time spill gauges (merged into [`super::store::StoreStats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpillStats {
    pub writes: u64,
    pub promotes: u64,
    pub bytes: u64,
}

/// The disk spill tier: an in-memory index over length-prefixed slab
/// files in `dir`. Files not recorded in the index (stale runs sharing
/// the directory) are never read — the index is authoritative, so
/// startup deletes any pre-existing `.spill` files in `dir` outright
/// (they are unreachable orphans from a run that did not shut down
/// cleanly) and garbage-collects stale sibling `gcoospdm_spill_*`
/// directories whose owning pid is gone.
pub struct SpillStore {
    dir: PathBuf,
    /// File-byte budget; 0 = unbounded.
    budget: u64,
    writes: AtomicU64,
    promotes: AtomicU64,
    inner: Mutex<SpillInner>,
}

/// Startup GC half 1: `.spill` files already in `dir` are unreachable
/// (the in-memory index starts empty and is the only read path), so a
/// crashed predecessor's files would otherwise accumulate forever.
fn gc_orphan_files(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for ent in entries.flatten() {
        let path = ent.path();
        if path.extension().is_some_and(|e| e == "spill") && path.is_file() {
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Startup GC half 2: spill directories are pid-keyed
/// (`gcoospdm_spill_<pid>…`), so a crashed run strands its whole
/// directory with a name no later run reuses. Remove any sibling whose
/// embedded pid no longer exists. Live pids — including ours — are never
/// touched; without `/proc` (non-Linux) the sweep is a no-op.
fn gc_stale_siblings(dir: &Path) {
    if !Path::new("/proc").is_dir() {
        return;
    }
    let Some(parent) = dir.parent() else { return };
    let me = std::process::id();
    let Ok(entries) = std::fs::read_dir(parent) else { return };
    for ent in entries.flatten() {
        let path = ent.path();
        if !path.is_dir() {
            continue;
        }
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(rest) = name.strip_prefix("gcoospdm_spill_") else { continue };
        let pid_digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        let Ok(pid) = pid_digits.parse::<u32>() else { continue };
        if pid == me || Path::new("/proc").join(pid_digits).exists() {
            continue;
        }
        let _ = std::fs::remove_dir_all(&path);
    }
}

impl SpillStore {
    pub fn new(dir: &Path, budget_bytes: u64) -> Result<SpillStore, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("spill: cannot create {}: {e}", dir.display()))?;
        gc_orphan_files(dir);
        gc_stale_siblings(dir);
        Ok(SpillStore {
            dir: dir.to_path_buf(),
            budget: budget_bytes,
            writes: AtomicU64::new(0),
            promotes: AtomicU64::new(0),
            inner: Mutex::new(SpillInner {
                index: HashMap::new(),
                order: VecDeque::new(),
                bytes: 0,
                next_seq: 0,
            }),
        })
    }

    /// Demote an evicted entry to disk: serialize the full entry (dense A
    /// + converted device form + plan/stats/sig) to one slab file, then
    /// trim the tier oldest-first if the file budget overflowed. A demote
    /// failure is reported but non-fatal to eviction — the tier is a
    /// cache under the store, never a correctness dependency.
    pub fn demote(&self, entry: &OperandEntry, tenant: &str, last_used_seq: u64) -> Result<(), String> {
        let buf = encode_entry(entry, tenant);
        let path = self.dir.join(format!("a{}.spill", entry.handle.0));
        std::fs::write(&path, &buf)
            .map_err(|e| format!("spill: write {} failed: {e}", path.display()))?;
        let file_bytes = buf.len() as u64;
        let mut g = self.inner.lock().unwrap();
        // Replace any stale record for this handle (re-demotion).
        if let Some(old) = g.index.remove(&entry.handle.0) {
            g.bytes -= old.file_bytes;
        }
        g.next_seq += 1;
        let seq = g.next_seq;
        g.index.insert(
            entry.handle.0,
            Meta {
                row: SpillRow {
                    handle: entry.handle,
                    tenant: tenant.to_string(),
                    n: entry.a.rows,
                    nnz: entry.sig.nnz,
                    algo: entry.plan.algo,
                    artifact: entry.plan.artifact.clone(),
                    entry_bytes: entry.bytes,
                    last_used_seq,
                },
                path,
                file_bytes,
                seq,
            },
        );
        g.order.push_back(seq);
        g.bytes += file_bytes;
        self.writes.fetch_add(1, Ordering::Relaxed);
        // Oldest-first trim: the tier below disk is nothing, so a trimmed
        // conversion is genuinely lost.
        if self.budget > 0 {
            while g.bytes > self.budget {
                let Some(oldest_seq) = g.order.pop_front() else { break };
                let victim = g
                    .index
                    .iter()
                    .find(|(_, m)| m.seq == oldest_seq)
                    .map(|(&id, _)| id);
                if let Some(id) = victim {
                    let meta = g.index.remove(&id).unwrap();
                    g.bytes -= meta.file_bytes;
                    let _ = std::fs::remove_file(&meta.path);
                }
            }
        }
        Ok(())
    }

    /// Whether the tier holds this handle.
    pub fn contains(&self, h: OperandId) -> bool {
        self.inner.lock().unwrap().index.contains_key(&h.0)
    }

    /// Index row for a spilled handle (no file I/O).
    pub fn meta(&self, h: OperandId) -> Option<SpillRow> {
        self.inner.lock().unwrap().index.get(&h.0).map(|m| m.row.clone())
    }

    /// Promote a spilled handle: one sequential file read, full decode,
    /// then **signature verification** — the dense A is re-hashed and
    /// must reproduce the stored `ASig` bit-for-bit (a corrupt file is
    /// dropped from the tier and reported, never served). On success the
    /// file is consumed (the entry moves back up the hierarchy).
    pub fn promote(&self, h: OperandId) -> Result<RestoredEntry, String> {
        let path = {
            let g = self.inner.lock().unwrap();
            match g.index.get(&h.0) {
                Some(m) => m.path.clone(),
                None => return Err(format!("spill: {h} not in the spill index")),
            }
        };
        let buf = std::fs::read(&path)
            .map_err(|e| format!("spill: read {} failed: {e}", path.display()))?;
        let restored = match decode_entry(&buf) {
            Ok(r) => r,
            Err(e) => {
                self.discard(h);
                return Err(e);
            }
        };
        if restored.handle != h {
            self.discard(h);
            return Err(format!(
                "spill: file for {h} names handle {}",
                restored.handle
            ));
        }
        let recomputed = ASig::of(&restored.a);
        if recomputed != restored.sig {
            self.discard(h);
            return Err(format!("spill: {h} failed signature verification"));
        }
        self.discard(h);
        self.promotes.fetch_add(1, Ordering::Relaxed);
        Ok(restored)
    }

    /// Drop a spilled handle (file + index row); used by `drop_a`, by
    /// promotion (the file is consumed), and on verification failure.
    pub fn discard(&self, h: OperandId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.index.remove(&h.0) {
            Some(meta) => {
                g.bytes -= meta.file_bytes;
                let _ = std::fs::remove_file(&meta.path);
                true
            }
            None => false,
        }
    }

    /// Every spilled row, ordered by handle.
    pub fn list(&self) -> Vec<SpillRow> {
        let g = self.inner.lock().unwrap();
        let mut rows: Vec<SpillRow> = g.index.values().map(|m| m.row.clone()).collect();
        rows.sort_by_key(|r| r.handle);
        rows
    }

    pub fn stats(&self) -> SpillStats {
        SpillStats {
            writes: self.writes.load(Ordering::Relaxed),
            promotes: self.promotes.load(Ordering::Relaxed),
            bytes: self.inner.lock().unwrap().bytes,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Delete every spilled file and clear the index. Called from
    /// coordinator shutdown and from `Drop`, so a clean exit leaves no
    /// `.spill` files behind; the directory itself is removed once empty
    /// (`remove_dir` refuses a non-empty directory, so unrelated files
    /// sharing it survive).
    pub fn sweep(&self) {
        let mut g = self.inner.lock().unwrap();
        for (_, meta) in g.index.drain() {
            let _ = std::fs::remove_file(&meta.path);
        }
        g.order.clear();
        g.bytes = 0;
        drop(g);
        let _ = std::fs::remove_dir(&self.dir);
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        self.sweep();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::CoordinatorConfig;
    use crate::coordinator::store::OperandStore;
    use crate::gen;
    use crate::rng::Rng;
    use crate::runtime::Registry;

    fn reg() -> Registry {
        let manifest = r#"{"artifacts": [
            {"name": "gcoo_n64_cap64", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "gcoo_n64_cap512", "algo": "gcoo", "n": 64,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "csr_n64_rowcap64", "algo": "csr", "n": 64,
             "params": {"rp": 8, "rowcap": 64}, "inputs": [], "file": "stub.hlo.txt"},
            {"name": "dense_xla_n64", "algo": "dense_xla", "n": 64,
             "params": {}, "inputs": [], "file": "stub.hlo.txt"}
        ]}"#;
        Registry::from_manifest_json(manifest, std::path::PathBuf::from("/nope")).unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("gcoospdm_spill_{}_{name}", std::process::id()))
    }

    fn sparse_a(seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        gen::uniform(64, 0.99, &mut rng)
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn operand_bitwise_eq(x: &DeviceOperand, y: &DeviceOperand) -> bool {
        match (x, y) {
            (DeviceOperand::Gcoo(a), DeviceOperand::Gcoo(b)) => {
                (a.g, a.cap, a.p, a.n) == (b.g, b.cap, b.p, b.n)
                    && bits(&a.vals) == bits(&b.vals)
                    && a.rows == b.rows
                    && a.cols == b.cols
            }
            (DeviceOperand::Ell(a), DeviceOperand::Ell(b)) => {
                (a.n, a.rowcap) == (b.n, b.rowcap)
                    && bits(&a.vals) == bits(&b.vals)
                    && a.cols == b.cols
            }
            (DeviceOperand::Dense(a), DeviceOperand::Dense(b)) => {
                (a.rows, a.cols) == (b.rows, b.cols) && bits(&a.data) == bits(&b.data)
            }
            (DeviceOperand::Cmrs(a), DeviceOperand::Cmrs(b)) => {
                (a.g, a.cap, a.p, a.n) == (b.g, b.cap, b.p, b.n)
                    && bits(&a.vals) == bits(&b.vals)
                    && a.rows == b.rows
                    && a.cols == b.cols
            }
            (DeviceOperand::RowSplit(a), DeviceOperand::RowSplit(b)) => {
                (a.segs, a.cap, a.n) == (b.segs, b.cap, b.n)
                    && bits(&a.vals) == bits(&b.vals)
                    && a.seg_rows == b.seg_rows
                    && a.cols == b.cols
            }
            _ => false,
        }
    }

    #[test]
    fn demote_promote_round_trip_is_bitwise_and_counts_gauges() {
        let dir = tmp("round_trip");
        let spill = SpillStore::new(&dir, 0).unwrap();
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();
        let (e, _) = store.register(sparse_a(1), None, &reg(), &cfg).unwrap();
        spill.demote(&e, "alpha", 7).unwrap();
        assert!(spill.contains(e.handle));
        let row = spill.meta(e.handle).unwrap();
        assert_eq!((row.n, row.nnz, row.tenant.as_str(), row.last_used_seq), (64, e.sig.nnz, "alpha", 7));
        let st = spill.stats();
        assert_eq!(st.writes, 1);
        assert!(st.bytes > 0);

        let r = spill.promote(e.handle).unwrap();
        assert_eq!(r.tenant, "alpha");
        assert_eq!(r.handle, e.handle);
        assert_eq!(r.sig, e.sig);
        assert_eq!(r.version, e.version);
        assert_eq!(bits(&r.a.data), bits(&e.a.data), "dense A survives bit-for-bit");
        assert!(operand_bitwise_eq(&r.operand, &e.operand), "device slabs survive bit-for-bit");
        assert_eq!(r.plan, e.plan, "plan survives (reason interned)");
        assert_eq!(r.candidates, e.candidates);
        assert_eq!(r.stats.nnz_per_band, e.stats.nnz_per_band);
        assert_eq!(r.bytes, e.bytes);
        assert_eq!(r.convert_s.to_bits(), e.convert_s.to_bits());
        // Promotion consumes the file.
        assert!(!spill.contains(e.handle));
        let st = spill.stats();
        assert_eq!((st.promotes, st.bytes), (1, 0));
        assert!(spill.promote(e.handle).is_err(), "double promote misses");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_fails_verification_and_is_discarded() {
        let dir = tmp("corrupt");
        let spill = SpillStore::new(&dir, 0).unwrap();
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();
        let (e, _) = store.register(sparse_a(2), None, &reg(), &cfg).unwrap();
        spill.demote(&e, "default", 1).unwrap();
        // Flip one byte inside the dense-A slab: the recomputed ASig must
        // catch it.
        let path = dir.join(format!("a{}.spill", e.handle.0));
        let mut buf = std::fs::read(&path).unwrap();
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        std::fs::write(&path, &buf).unwrap();
        let err = spill.promote(e.handle).unwrap_err();
        assert!(
            err.contains("verification") || err.contains("spill:"),
            "typed spill error, got: {err}"
        );
        assert!(!spill.contains(e.handle), "corrupt entry leaves the index");
        assert_eq!(spill.stats().promotes, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_trims_oldest_first() {
        let dir = tmp("budget");
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();
        let (e1, _) = store.register(sparse_a(3), None, &reg(), &cfg).unwrap();
        let (e2, _) = store.register(sparse_a(4), None, &reg(), &cfg).unwrap();
        let (e3, _) = store.register(sparse_a(5), None, &reg(), &cfg).unwrap();
        let one_file = encode_entry(&e1, "default").len() as u64;
        // Room for about two files.
        let spill = SpillStore::new(&dir, one_file * 5 / 2).unwrap();
        spill.demote(&e1, "default", 1).unwrap();
        spill.demote(&e2, "default", 2).unwrap();
        spill.demote(&e3, "default", 3).unwrap();
        assert!(!spill.contains(e1.handle), "oldest spill file trimmed");
        assert!(spill.contains(e2.handle));
        assert!(spill.contains(e3.handle));
        assert!(spill.stats().bytes <= one_file * 5 / 2);
        assert_eq!(spill.stats().writes, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_and_garbage_files_error_not_panic() {
        let dir = tmp("truncate");
        let spill = SpillStore::new(&dir, 0).unwrap();
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();
        let (e, _) = store.register(sparse_a(6), None, &reg(), &cfg).unwrap();
        spill.demote(&e, "default", 1).unwrap();
        let path = dir.join(format!("a{}.spill", e.handle.0));
        let buf = std::fs::read(&path).unwrap();
        for cut in [0usize, 3, 4, 5, 20, buf.len() / 2, buf.len() - 1] {
            assert!(decode_entry(&buf[..cut]).is_err(), "prefix of {cut} bytes must error");
        }
        let mut extended = buf.clone();
        extended.push(0);
        assert!(decode_entry(&extended).is_err(), "trailing byte must error");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cmrs_and_rowsplit_operands_round_trip_bitwise() {
        use crate::sparse::{Cmrs, RowSplit};
        let dir = tmp("family_round_trip");
        let spill = SpillStore::new(&dir, 0).unwrap();
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();

        let (mut e, _) = store.register(sparse_a(11), None, &reg(), &cfg).unwrap();
        let cm = Cmrs::from_dense(&e.a, 8);
        e.operand = DeviceOperand::Cmrs(cm.pad(cm.max_strip_nnz().max(1)).unwrap());
        e.plan.algo = Algo::Cmrs;
        spill.demote(&e, "alpha", 1).unwrap();
        let r = spill.promote(e.handle).unwrap();
        assert!(operand_bitwise_eq(&r.operand, &e.operand), "cmrs slabs survive bit-for-bit");
        assert_eq!(r.plan.algo, Algo::Cmrs, "algo byte 6 round-trips");

        let (mut e2, _) = store.register(sparse_a(12), None, &reg(), &cfg).unwrap();
        let rs = RowSplit::from_dense(&e2.a, 4).unwrap();
        e2.operand = DeviceOperand::RowSplit(rs.pad());
        e2.plan.algo = Algo::RowSplit;
        spill.demote(&e2, "beta", 2).unwrap();
        let r2 = spill.promote(e2.handle).unwrap();
        assert!(operand_bitwise_eq(&r2.operand, &e2.operand), "rowsplit slabs survive bit-for-bit");
        assert_eq!(r2.plan.algo, Algo::RowSplit, "algo byte 7 round-trips");
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn spill_files(dir: &Path) -> usize {
        std::fs::read_dir(dir)
            .map(|it| {
                it.flatten()
                    .filter(|e| e.path().extension().is_some_and(|x| x == "spill"))
                    .count()
            })
            .unwrap_or(0)
    }

    #[test]
    fn no_spill_files_leak_across_drop_shutdown_and_restart() {
        let dir = tmp("lifecycle");
        let store = OperandStore::new(64 << 20);
        let cfg = CoordinatorConfig::default();
        let (e1, _) = store.register(sparse_a(21), None, &reg(), &cfg).unwrap();
        let (e2, _) = store.register(sparse_a(22), None, &reg(), &cfg).unwrap();
        {
            let spill = SpillStore::new(&dir, 0).unwrap();
            spill.demote(&e1, "default", 1).unwrap();
            spill.demote(&e2, "default", 2).unwrap();
            assert_eq!(spill_files(&dir), 2);
            // drop_a path: the file goes with the handle.
            assert!(spill.discard(e1.handle));
            assert_eq!(spill_files(&dir), 1, "drop_a deletes the slab file");
            // A crashed predecessor's file the index never knew about.
            std::fs::write(dir.join("a999999.spill"), b"GSPLjunk").unwrap();
            assert_eq!(spill_files(&dir), 2);
            spill.sweep();
            assert_eq!(spill_files(&dir), 1, "shutdown sweep removes every indexed file");
            // `spill` drops here; Drop re-sweeps without touching the orphan.
        }
        assert_eq!(spill_files(&dir), 1);
        // Restart over the same directory: startup GC clears the orphan.
        let spill = SpillStore::new(&dir, 0).unwrap();
        assert_eq!(spill_files(&dir), 0, "startup GC deletes unreachable .spill files");
        drop(spill);
        assert!(
            !dir.exists(),
            "empty spill dir is removed on drop"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn startup_gc_removes_dead_pid_sibling_dirs() {
        if !Path::new("/proc").is_dir() {
            return; // pid-liveness probe needs procfs
        }
        // 4291234567 is a valid u32 far above any real pid_max.
        let stale = std::env::temp_dir().join("gcoospdm_spill_4291234567_stale");
        std::fs::create_dir_all(&stale).unwrap();
        std::fs::write(stale.join("a1.spill"), b"junk").unwrap();
        let live = tmp("gc_live"); // embeds our (live) pid — must survive
        std::fs::create_dir_all(&live).unwrap();
        let dir = tmp("gc_self");
        let spill = SpillStore::new(&dir, 0).unwrap();
        assert!(!stale.exists(), "dead-pid sibling dir GCed at startup");
        assert!(live.exists(), "live-pid sibling untouched");
        drop(spill);
        let _ = std::fs::remove_dir_all(&live);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reason_interning_covers_the_selector_vocabulary() {
        for r in REASONS {
            assert_eq!(intern_reason(r), *r);
        }
        assert_eq!(intern_reason("never-heard-of-it"), "restored");
    }
}
