//! Coordinator — the L3 serving layer: bounded job queue with backpressure,
//! algorithm selection (the sparsity/size routing policy the paper's
//! conclusions prescribe), shape-affinity batching, a worker pool executing
//! on the shared PJRT engine, and metrics.
//!
//! The paper's contribution is the kernel, so this layer is deliberately a
//! *thin but real* serving stack (DESIGN.md §1 L3): everything a downstream
//! user needs to put GCOOSpDM behind a request boundary.

mod job;
mod queue;
mod selector;
mod metrics;
mod pool;

pub use job::{Algo, SpdmRequest, SpdmResponse};
pub use queue::BoundedQueue;
pub use selector::{Selector, SelectorPolicy, Plan};
pub use metrics::{Metrics, MetricsSnapshot};
pub use pool::{Coordinator, CoordinatorConfig};
