//! Coordinator — the L3 serving layer: bounded job queue with backpressure,
//! plan-first algorithm selection (the sparsity/size routing policy the
//! paper's conclusions prescribe as the **prior**, resolved to a concrete
//! artifact before any conversion), an adaptive tuner (`tuner.rs`:
//! clock-injected per-operand latency model, seeded exploration, and
//! model-driven route flips that republish store entries — the measured
//! routing the paper names as future work), a converted-operand store
//! (`put_a` once, multiply-by-handle forever — registration pays the one
//! conversion, handle traffic executes from cached slabs; entries are
//! versioned so flips never touch an in-flight pin), operand-keyed
//! batching with fused multi-B execution (one conversion + one wide kernel
//! per batch; no conversion at all for cached operands), a configurable
//! time-window admission policy (`queue.rs::pop_batch_windowed`: hold a
//! partial affine batch open for a bounded clock-injected window so
//! open-loop traffic fuses wide), a worker pool with per-worker engines +
//! workspace arenas, and metrics.
//!
//! The paper's contribution is the kernel, so this layer is deliberately a
//! *thin but real* serving stack (DESIGN.md §1 L3): everything a downstream
//! user needs to put GCOOSpDM behind a request boundary.

mod job;
mod queue;
mod selector;
mod metrics;
mod pool;
mod shard;
mod spill;
mod store;
mod tenant;
mod tuner;
mod workspace;

pub use job::{AOperand, ASig, Algo, SpdmRequest, SpdmResponse};
pub use queue::{BoundedQueue, WindowOutcome};
pub use selector::{Selector, SelectorPolicy};
pub use metrics::{Metrics, MetricsSnapshot, TenantStat};
pub use pool::{
    batch_affine, process_batch_tuned, process_batch_ws, process_one, process_one_tuned,
    process_one_ws, BatchJob, Coordinator, CoordinatorConfig, SubmitError, TuneCtx,
};
pub use shard::{Ring, ShardSpec, DEFAULT_RING_SEED, DEFAULT_VNODES};
pub use spill::{RestoredEntry, SpillRow, SpillStats, SpillStore};
pub use store::{
    OperandEntry, OperandId, OperandPin, OperandStore, OperandSummary, StoreStats,
};
pub use tenant::{
    TenantRegistry, TenantSpec, DEFAULT_TENANT, MAX_TENANT_LEN, QUOTA_EXCEEDED, RATE_LIMITED,
};
pub use tuner::{
    explore_draw, Clock, ModelKey, PerfModel, RealClock, ScriptedClock, Tuner, TunerConfig,
};
pub use workspace::Workspace;
// The selector's output type lives next to the engine (`runtime::plan`);
// keep the old `coordinator::Plan` name working.
pub use crate::runtime::ExecPlan;
pub use crate::runtime::ExecPlan as Plan;
