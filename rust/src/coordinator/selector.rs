//! Algorithm selection — the routing policy distilled from the paper's
//! conclusions plus artifact availability:
//!
//! * sparsity ≥ `gcoo_crossover` (paper: **0.98**) → GCOOSpDM beats dense;
//! * sparsity ≥ `csr_crossover` (paper: 0.995) is where cuSPARSE would break
//!   even — we still prefer GCOO there (it dominates CSR in Figs 7–12);
//! * below the crossover, or when the matrix is too small for the sparse
//!   path to amortize conversion (paper §IV-B: n < 1500 favors cuBLAS,
//!   scaled to our artifact grid), route dense;
//! * capacity fallback: if no compiled gcoo capacity fits the matrix's band
//!   skew, degrade gcoo → csr → dense rather than failing.
//!
//! The selector now emits a fully resolved [`ExecPlan`] — algorithm,
//! execution size, **and** the concrete artifact with its capacity — from
//! the fused stats scan alone, before any conversion happens. The pipeline
//! then converts A exactly once, straight into slabs of `plan.cap`.

use super::job::Algo;
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{ExecPlan, Registry};

/// Tunable thresholds (defaults = the paper's findings).
#[derive(Clone, Copy, Debug)]
pub struct SelectorPolicy {
    /// Sparsity above which GCOO beats the dense baseline (paper: 0.98).
    pub gcoo_crossover: f64,
    /// Smallest n for which the sparse path amortizes conversion.
    pub min_sparse_n: usize,
}

impl Default for SelectorPolicy {
    fn default() -> Self {
        SelectorPolicy { gcoo_crossover: 0.98, min_sparse_n: 256 }
    }
}

pub struct Selector {
    pub policy: SelectorPolicy,
}

impl Selector {
    pub fn new(policy: SelectorPolicy) -> Self {
        Selector { policy }
    }

    /// Decide algorithm, execution size, and artifact for A (n×n, sparsity
    /// s). `max_band_nnz`/`max_row_nnz` come from the fused stats scan and
    /// gate capacity feasibility — no conversion is needed to plan.
    pub fn plan(
        &self,
        reg: &Registry,
        n: usize,
        sparsity: f64,
        max_band_nnz: usize,
        max_row_nnz: usize,
        hint: Option<Algo>,
    ) -> Result<ExecPlan, String> {
        // Resolve the padded execution size per algorithm family.
        let fit = |algo: &str| reg.fit_size(algo, n);

        if let Some(algo) = hint {
            let n_exec = fit(algo.as_str())
                .ok_or_else(|| format!("no {} artifact fits n={}", algo.as_str(), n))?;
            let need = match algo {
                Algo::Gcoo | Algo::GcooNoreuse => max_band_nnz,
                Algo::Csr => max_row_nnz,
                Algo::DenseXla | Algo::DensePallas => 0,
            };
            return ExecPlan::resolve(reg, algo, n_exec, need, "hint")
                .map_err(|e| e.to_string());
        }

        let sparse_ok = n >= self.policy.min_sparse_n.min(reg.sizes("gcoo").first().copied().unwrap_or(usize::MAX));
        if sparsity >= self.policy.gcoo_crossover && sparse_ok {
            // GCOO first, capacity permitting.
            if let Some(n_exec) = fit("gcoo") {
                if let Ok(plan) =
                    ExecPlan::resolve(reg, Algo::Gcoo, n_exec, max_band_nnz, "sparse-crossover")
                {
                    return Ok(plan);
                }
            }
            if let Some(n_exec) = fit("csr") {
                if let Ok(plan) = ExecPlan::resolve(
                    reg,
                    Algo::Csr,
                    n_exec,
                    max_row_nnz,
                    "gcoo-capacity-fallback",
                ) {
                    return Ok(plan);
                }
            }
        }
        let n_exec = fit("dense_xla").ok_or_else(|| format!("no dense artifact fits n={n}"))?;
        let reason = if sparsity >= self.policy.gcoo_crossover {
            "sparse-capacity-exhausted"
        } else {
            "below-crossover"
        };
        ExecPlan::resolve(reg, Algo::DenseXla, n_exec, 0, reason).map_err(|e| e.to_string())
    }

    /// Convenience: plan directly from a dense A via one fused stats scan
    /// (no conversion, unlike the old GCOO+CSR double build).
    pub fn plan_for(
        &self,
        reg: &Registry,
        a: &Mat,
        p: usize,
        hint: Option<Algo>,
    ) -> Result<ExecPlan, String> {
        let stats = convert::scan_stats(a, p, 1);
        self.plan(reg, a.rows, stats.sparsity(), stats.max_band_nnz(), stats.max_row_nnz, hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;
    use std::path::PathBuf;

    fn reg() -> Registry {
        let manifest = r#"{
          "artifacts": [
            {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "a.hlo.txt"},
            {"name": "gcoo_n256_cap512", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "b.hlo.txt"},
            {"name": "csr_n256_rowcap128", "algo": "csr", "n": 256,
             "params": {"rp": 8, "rowcap": 128}, "inputs": [], "file": "c.hlo.txt"},
            {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
             "params": {}, "inputs": [], "file": "d.hlo.txt"},
            {"name": "dense_xla_n512", "algo": "dense_xla", "n": 512,
             "params": {}, "inputs": [], "file": "e.hlo.txt"}
          ]
        }"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    fn sel() -> Selector {
        Selector::new(SelectorPolicy::default())
    }

    #[test]
    fn high_sparsity_routes_gcoo() {
        let plan = sel().plan(&reg(), 256, 0.99, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
        assert_eq!(plan.n_exec, 256);
        assert_eq!(plan.reason, "sparse-crossover");
        // The plan is fully resolved: smallest cap ≥ 100 is 512.
        assert_eq!(plan.cap, 512);
        assert_eq!(plan.artifact, "gcoo_n256_cap512");
    }

    #[test]
    fn tight_band_skew_picks_small_capacity() {
        let plan = sel().plan(&reg(), 256, 0.995, 40, 20, None).unwrap();
        assert_eq!(plan.cap, 64);
        assert_eq!(plan.artifact, "gcoo_n256_cap64");
    }

    #[test]
    fn low_sparsity_routes_dense() {
        let plan = sel().plan(&reg(), 256, 0.5, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.reason, "below-crossover");
        assert_eq!(plan.cap, 0);
    }

    #[test]
    fn crossover_boundary_is_inclusive() {
        let plan = sel().plan(&reg(), 256, 0.98, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
    }

    #[test]
    fn capacity_overflow_falls_back_to_csr_then_dense() {
        // band nnz 600 > largest gcoo cap 512 → csr if rows fit
        let plan = sel().plan(&reg(), 256, 0.99, 600, 100, None).unwrap();
        assert_eq!(plan.algo, Algo::Csr);
        assert_eq!(plan.reason, "gcoo-capacity-fallback");
        assert_eq!(plan.cap, 128);
        // rows also overflow → dense
        let plan = sel().plan(&reg(), 256, 0.99, 600, 200, None).unwrap();
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.reason, "sparse-capacity-exhausted");
    }

    #[test]
    fn hint_overrides_policy() {
        let plan = sel().plan(&reg(), 256, 0.1, 10, 10, Some(Algo::Csr)).unwrap();
        assert_eq!(plan.algo, Algo::Csr);
        assert_eq!(plan.reason, "hint");
        assert_eq!(plan.cap, 128);
    }

    #[test]
    fn hint_with_impossible_capacity_errors_at_plan_time() {
        // Capacity infeasibility surfaces from the planning pass itself —
        // no conversion has happened yet when this fails.
        let err = sel().plan(&reg(), 256, 0.99, 9999, 10, Some(Algo::Gcoo)).unwrap_err();
        assert!(err.contains("gcoo"), "{err}");
    }

    #[test]
    fn odd_sizes_pad_up() {
        let plan = sel().plan(&reg(), 300, 0.99, 10, 10, None).unwrap();
        // only dense_xla exists at 512; gcoo tops out at 256 → dense at 512
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.n_exec, 512);
        assert_eq!(plan.artifact, "dense_xla_n512");
    }

    #[test]
    fn impossible_request_errors() {
        assert!(sel().plan(&reg(), 4096, 0.99, 10, 10, None).is_err());
    }

    #[test]
    fn plan_for_uses_fused_stats() {
        let mut rng = crate::rng::Rng::new(5);
        let a = crate::gen::uniform(256, 0.995, &mut rng);
        let plan = sel().plan_for(&reg(), &a, 8, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
        // The resolved cap must cover the matrix's actual band skew.
        let stats = crate::convert::scan_stats(&a, 8, 1);
        assert!(plan.cap >= stats.max_band_nnz());
    }
}
