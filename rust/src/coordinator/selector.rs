//! Algorithm selection — the routing policy distilled from the paper's
//! conclusions plus artifact availability:
//!
//! * sparsity ≥ `gcoo_crossover` (paper: **0.98**) → GCOOSpDM beats dense;
//! * sparsity ≥ `csr_crossover` (paper: 0.995) is where cuSPARSE would break
//!   even — we still prefer GCOO there (it dominates CSR in Figs 7–12);
//! * below the crossover, or when the matrix is too small for the sparse
//!   path to amortize conversion (paper §IV-B: n < 1500 favors cuBLAS,
//!   scaled to our artifact grid), route dense;
//! * capacity fallback: if no compiled gcoo capacity fits the matrix's band
//!   skew, degrade gcoo → csr → dense rather than failing.
//!
//! The selector now emits a fully resolved [`ExecPlan`] — algorithm,
//! execution size, **and** the concrete artifact with its capacity — from
//! the fused stats scan alone, before any conversion happens. The pipeline
//! then converts A exactly once, straight into slabs of `plan.cap`.
//!
//! The paper thresholds are the **prior**, not the last word:
//! [`Selector::plan_with_model`] defers to the tuner's sample-gated
//! measured estimates once they exist (trying them in measured-cost order,
//! with the capacity-fallback chain intact — a measured favorite with no
//! fitting artifact falls through to the next estimate, then back to the
//! prior), and [`Selector::plan_candidates`] publishes the full resolvable
//! plan list the tuner explores and flips between.

use super::job::Algo;
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{ExecPlan, Registry};

/// Tunable thresholds (defaults = the paper's findings).
#[derive(Clone, Copy, Debug)]
pub struct SelectorPolicy {
    /// Sparsity above which GCOO beats the dense baseline (paper: 0.98).
    pub gcoo_crossover: f64,
    /// Smallest n for which the sparse path amortizes conversion.
    pub min_sparse_n: usize,
}

impl Default for SelectorPolicy {
    fn default() -> Self {
        SelectorPolicy { gcoo_crossover: 0.98, min_sparse_n: 256 }
    }
}

pub struct Selector {
    pub policy: SelectorPolicy,
}

/// Device-capacity requirement of `algo` for a matrix with these scan
/// stats (band cap for GCOO, row cap for CSR/ELL, none for dense) — the
/// one definition every planning path resolves artifacts against.
///
/// CMRS strips are bands of `p` rows, so its strip capacity requirement is
/// exactly the GCOO band requirement. Row-split re-segments rows at the
/// artifact's capacity and so fits *any* matrix — its need is 1 (the
/// smallest compiled segment capacity always works; smaller caps just mean
/// more segments).
fn capacity_need(algo: Algo, max_band_nnz: usize, max_row_nnz: usize) -> usize {
    match algo {
        Algo::Gcoo | Algo::GcooNoreuse | Algo::Cmrs => max_band_nnz,
        Algo::Csr => max_row_nnz,
        Algo::RowSplit => 1,
        Algo::DenseXla | Algo::DensePallas => 0,
    }
}

impl Selector {
    pub fn new(policy: SelectorPolicy) -> Self {
        Selector { policy }
    }

    /// Decide algorithm, execution size, and artifact for A (n×n, sparsity
    /// s). `max_band_nnz`/`max_row_nnz` come from the fused stats scan and
    /// gate capacity feasibility — no conversion is needed to plan.
    pub fn plan(
        &self,
        reg: &Registry,
        n: usize,
        sparsity: f64,
        max_band_nnz: usize,
        max_row_nnz: usize,
        hint: Option<Algo>,
    ) -> Result<ExecPlan, String> {
        // Resolve the padded execution size per algorithm family.
        let fit = |algo: &str| reg.fit_size(algo, n);

        if let Some(algo) = hint {
            let n_exec = fit(algo.as_str())
                .ok_or_else(|| format!("no {} artifact fits n={}", algo.as_str(), n))?;
            let need = capacity_need(algo, max_band_nnz, max_row_nnz);
            return ExecPlan::resolve(reg, algo, n_exec, need, "hint")
                .map_err(|e| e.to_string());
        }

        let sparse_ok = n >= self.policy.min_sparse_n.min(reg.sizes("gcoo").first().copied().unwrap_or(usize::MAX));
        if sparsity >= self.policy.gcoo_crossover && sparse_ok {
            // GCOO first, capacity permitting.
            if let Some(n_exec) = fit("gcoo") {
                if let Ok(plan) =
                    ExecPlan::resolve(reg, Algo::Gcoo, n_exec, max_band_nnz, "sparse-crossover")
                {
                    return Ok(plan);
                }
            }
            if let Some(n_exec) = fit("csr") {
                if let Ok(plan) = ExecPlan::resolve(
                    reg,
                    Algo::Csr,
                    n_exec,
                    max_row_nnz,
                    "gcoo-capacity-fallback",
                ) {
                    return Ok(plan);
                }
            }
        }
        let n_exec = fit("dense_xla").ok_or_else(|| format!("no dense artifact fits n={n}"))?;
        let reason = if sparsity >= self.policy.gcoo_crossover {
            "sparse-capacity-exhausted"
        } else {
            "below-crossover"
        };
        ExecPlan::resolve(reg, Algo::DenseXla, n_exec, 0, reason).map_err(|e| e.to_string())
    }

    /// Every resolvable plan for this operand, ranked by the paper prior —
    /// the same order [`Selector::plan`] walks (sparse families first at or
    /// above the crossover, dense first below it), so the head is exactly
    /// the plan `plan` resolves when it succeeds. The tail is the tuner's
    /// exploration list: alternatives whose artifacts genuinely fit, ready
    /// to execute without re-planning.
    pub fn plan_candidates(
        &self,
        reg: &Registry,
        n: usize,
        sparsity: f64,
        max_band_nnz: usize,
        max_row_nnz: usize,
    ) -> Vec<ExecPlan> {
        let sparse_ok = n
            >= self
                .policy
                .min_sparse_n
                .min(reg.sizes("gcoo").first().copied().unwrap_or(usize::MAX));
        // The paper prior ranks only the original three families; CMRS and
        // row-split enter as trailing exploration candidates — the measured
        // router promotes them when their estimates win, the static prior
        // never picks them head-of-list.
        let order: [Algo; 5] = if sparsity >= self.policy.gcoo_crossover && sparse_ok {
            [Algo::Gcoo, Algo::Csr, Algo::DenseXla, Algo::Cmrs, Algo::RowSplit]
        } else {
            [Algo::DenseXla, Algo::Gcoo, Algo::Csr, Algo::Cmrs, Algo::RowSplit]
        };
        order
            .iter()
            .filter_map(|&algo| {
                let need = capacity_need(algo, max_band_nnz, max_row_nnz);
                let n_exec = reg.fit_size(algo.as_str(), n)?;
                ExecPlan::resolve(reg, algo, n_exec, need, "candidate").ok()
            })
            .collect()
    }

    /// Adaptive planning: the paper-threshold prior seeds routing, but
    /// sample-gated measured estimates win once they exist. `measured` is
    /// the tuner's gated (algo, cost) list; candidates are tried in
    /// measured-cost order (stable on ties, so the caller's fixed algo
    /// order breaks them deterministically) with the capacity fallback
    /// intact — a measured favorite with no fitting artifact falls through
    /// to the next estimate, and an empty/unresolvable list falls back to
    /// [`Selector::plan`]. An explicit hint always wins outright.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_with_model(
        &self,
        reg: &Registry,
        n: usize,
        sparsity: f64,
        max_band_nnz: usize,
        max_row_nnz: usize,
        hint: Option<Algo>,
        measured: &[(Algo, f64)],
    ) -> Result<ExecPlan, String> {
        if hint.is_some() || measured.is_empty() {
            return self.plan(reg, n, sparsity, max_band_nnz, max_row_nnz, hint);
        }
        let mut ranked = measured.to_vec();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        for &(algo, _) in &ranked {
            let need = capacity_need(algo, max_band_nnz, max_row_nnz);
            if let Some(n_exec) = reg.fit_size(algo.as_str(), n) {
                if let Ok(plan) = ExecPlan::resolve(reg, algo, n_exec, need, "measured") {
                    return Ok(plan);
                }
            }
        }
        self.plan(reg, n, sparsity, max_band_nnz, max_row_nnz, None)
    }

    /// Convenience: plan directly from a dense A via one fused stats scan
    /// (no conversion, unlike the old GCOO+CSR double build).
    pub fn plan_for(
        &self,
        reg: &Registry,
        a: &Mat,
        p: usize,
        hint: Option<Algo>,
    ) -> Result<ExecPlan, String> {
        let stats = convert::scan_stats(a, p, 1);
        self.plan(reg, a.rows, stats.sparsity(), stats.max_band_nnz(), stats.max_row_nnz, hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;
    use std::path::PathBuf;

    fn reg() -> Registry {
        let manifest = r#"{
          "artifacts": [
            {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "a.hlo.txt"},
            {"name": "gcoo_n256_cap512", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "b.hlo.txt"},
            {"name": "csr_n256_rowcap128", "algo": "csr", "n": 256,
             "params": {"rp": 8, "rowcap": 128}, "inputs": [], "file": "c.hlo.txt"},
            {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
             "params": {}, "inputs": [], "file": "d.hlo.txt"},
            {"name": "dense_xla_n512", "algo": "dense_xla", "n": 512,
             "params": {}, "inputs": [], "file": "e.hlo.txt"},
            {"name": "cmrs_n256_cap512", "algo": "cmrs", "n": 256,
             "params": {"p": 8, "cap": 512}, "inputs": [], "file": "f.hlo.txt"},
            {"name": "rowsplit_n256_cap64", "algo": "rowsplit", "n": 256,
             "params": {"cap": 64}, "inputs": [], "file": "g.hlo.txt"}
          ]
        }"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    fn sel() -> Selector {
        Selector::new(SelectorPolicy::default())
    }

    #[test]
    fn high_sparsity_routes_gcoo() {
        let plan = sel().plan(&reg(), 256, 0.99, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
        assert_eq!(plan.n_exec, 256);
        assert_eq!(plan.reason, "sparse-crossover");
        // The plan is fully resolved: smallest cap ≥ 100 is 512.
        assert_eq!(plan.cap, 512);
        assert_eq!(plan.artifact, "gcoo_n256_cap512");
    }

    #[test]
    fn tight_band_skew_picks_small_capacity() {
        let plan = sel().plan(&reg(), 256, 0.995, 40, 20, None).unwrap();
        assert_eq!(plan.cap, 64);
        assert_eq!(plan.artifact, "gcoo_n256_cap64");
    }

    #[test]
    fn low_sparsity_routes_dense() {
        let plan = sel().plan(&reg(), 256, 0.5, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.reason, "below-crossover");
        assert_eq!(plan.cap, 0);
    }

    #[test]
    fn crossover_boundary_is_inclusive() {
        let plan = sel().plan(&reg(), 256, 0.98, 100, 50, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
    }

    #[test]
    fn capacity_overflow_falls_back_to_csr_then_dense() {
        // band nnz 600 > largest gcoo cap 512 → csr if rows fit
        let plan = sel().plan(&reg(), 256, 0.99, 600, 100, None).unwrap();
        assert_eq!(plan.algo, Algo::Csr);
        assert_eq!(plan.reason, "gcoo-capacity-fallback");
        assert_eq!(plan.cap, 128);
        // rows also overflow → dense
        let plan = sel().plan(&reg(), 256, 0.99, 600, 200, None).unwrap();
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.reason, "sparse-capacity-exhausted");
    }

    #[test]
    fn hint_overrides_policy() {
        let plan = sel().plan(&reg(), 256, 0.1, 10, 10, Some(Algo::Csr)).unwrap();
        assert_eq!(plan.algo, Algo::Csr);
        assert_eq!(plan.reason, "hint");
        assert_eq!(plan.cap, 128);
    }

    #[test]
    fn hint_with_impossible_capacity_errors_at_plan_time() {
        // Capacity infeasibility surfaces from the planning pass itself —
        // no conversion has happened yet when this fails.
        let err = sel().plan(&reg(), 256, 0.99, 9999, 10, Some(Algo::Gcoo)).unwrap_err();
        assert!(err.contains("gcoo"), "{err}");
    }

    #[test]
    fn odd_sizes_pad_up() {
        let plan = sel().plan(&reg(), 300, 0.99, 10, 10, None).unwrap();
        // only dense_xla exists at 512; gcoo tops out at 256 → dense at 512
        assert_eq!(plan.algo, Algo::DenseXla);
        assert_eq!(plan.n_exec, 512);
        assert_eq!(plan.artifact, "dense_xla_n512");
    }

    #[test]
    fn impossible_request_errors() {
        assert!(sel().plan(&reg(), 4096, 0.99, 10, 10, None).is_err());
    }

    /// Registry with no csr family at all: the capacity-fallback chain
    /// must degrade gcoo → dense directly (the middle link is optional).
    fn reg_no_csr() -> Registry {
        let manifest = r#"{
          "artifacts": [
            {"name": "gcoo_n256_cap64", "algo": "gcoo", "n": 256,
             "params": {"p": 8, "cap": 64}, "inputs": [], "file": "a.hlo.txt"},
            {"name": "dense_xla_n256", "algo": "dense_xla", "n": 256,
             "params": {}, "inputs": [], "file": "d.hlo.txt"}
          ]
        }"#;
        Registry::from_manifest_json(manifest, PathBuf::from("/nope")).unwrap()
    }

    /// Satellite: the full capacity-fallback chain, link by link. A band
    /// skew no gcoo capacity fits degrades to csr when the rows fit, to
    /// dense when they don't, and skips the csr link entirely when no csr
    /// artifact exists — never failing while a dense artifact remains.
    #[test]
    fn capacity_fallback_chain_degrades_gcoo_csr_dense() {
        let r = reg();
        // All links available: gcoo wins outright when its cap fits.
        let plan = sel().plan(&r, 256, 0.99, 500, 100, None).unwrap();
        assert_eq!((plan.algo, plan.cap), (Algo::Gcoo, 512));
        // gcoo caps exhausted (600 > 512) → csr (100 ≤ rowcap 128).
        let plan = sel().plan(&r, 256, 0.99, 600, 100, None).unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Csr, "gcoo-capacity-fallback"));
        // csr rows exhausted too (200 > 128) → dense.
        let plan = sel().plan(&r, 256, 0.99, 600, 200, None).unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::DenseXla, "sparse-capacity-exhausted"));
        // No csr family: the chain skips the middle link.
        let plan = sel().plan(&reg_no_csr(), 256, 0.99, 600, 10, None).unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::DenseXla, "sparse-capacity-exhausted"));
        // …and still prefers gcoo when its one capacity fits.
        let plan = sel().plan(&reg_no_csr(), 256, 0.99, 40, 10, None).unwrap();
        assert_eq!((plan.algo, plan.cap), (Algo::Gcoo, 64));
    }

    #[test]
    fn candidates_head_matches_plan_and_tail_ranks_alternatives() {
        let r = reg();
        // Above the crossover: sparse-first order, all five resolvable —
        // the new families trail as exploration candidates, never the head.
        let cands = sel().plan_candidates(&r, 256, 0.99, 100, 50);
        let algos: Vec<Algo> = cands.iter().map(|c| c.algo).collect();
        assert_eq!(
            algos,
            vec![Algo::Gcoo, Algo::Csr, Algo::DenseXla, Algo::Cmrs, Algo::RowSplit]
        );
        let plan = sel().plan(&r, 256, 0.99, 100, 50, None).unwrap();
        assert_eq!(cands[0].algo, plan.algo);
        assert_eq!(cands[0].artifact, plan.artifact, "head is exactly the prior's choice");
        // Below the crossover: dense-first.
        let cands = sel().plan_candidates(&r, 256, 0.5, 100, 50);
        assert_eq!(cands[0].algo, Algo::DenseXla);
        assert_eq!(cands[0].algo, sel().plan(&r, 256, 0.5, 100, 50, None).unwrap().algo);
        // Capacity infeasibility filters a family out of the list. CMRS
        // shares the band-skew requirement so 600 drops it with gcoo;
        // row-split re-segments and survives any skew.
        let cands = sel().plan_candidates(&r, 256, 0.99, 600, 100);
        let algos: Vec<Algo> = cands.iter().map(|c| c.algo).collect();
        assert_eq!(
            algos,
            vec![Algo::Csr, Algo::DenseXla, Algo::RowSplit],
            "gcoo+cmrs band skew 600 > 512 drops both"
        );
    }

    /// Tentpole: the measured router can promote the new families even
    /// though the static prior never ranks them first — exactly the flip
    /// path `routing_differential` drives end-to-end.
    #[test]
    fn measured_estimates_promote_cmrs_and_rowsplit() {
        let r = reg();
        let measured = [(Algo::Cmrs, 1e-6), (Algo::Gcoo, 5e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 100, 50, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Cmrs, "measured"));
        assert_eq!(plan.cap, 512);
        assert_eq!(plan.artifact, "cmrs_n256_cap512");
        // Row-split's need is 1: it resolves even under band skew that
        // exhausts every gcoo/cmrs capacity.
        let measured = [(Algo::RowSplit, 1e-6), (Algo::Gcoo, 5e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 600, 200, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::RowSplit, "measured"));
        assert_eq!(plan.cap, 64);
        assert_eq!(plan.artifact, "rowsplit_n256_cap64");
        // A measured cmrs favorite whose strip skew fits no compiled cap
        // falls through the chain instead of erroring.
        let measured = [(Algo::Cmrs, 1e-6), (Algo::Csr, 2e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 600, 100, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Csr, "measured"));
    }

    /// Satellite: `plan_with_model` defers to gated measured estimates —
    /// and keeps the capacity-fallback chain when the measured favorite
    /// has no fitting artifact.
    #[test]
    fn plan_with_model_prefers_measured_and_falls_back_on_capacity() {
        let r = reg();
        // Measured says dense beats gcoo for this 0.99-sparse matrix: the
        // model overrides the prior.
        let measured = [(Algo::Gcoo, 5e-6), (Algo::DenseXla, 1e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 100, 50, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::DenseXla, "measured"));
        // Measured favorite gcoo, but its band skew fits no compiled cap:
        // fall through to the next measured estimate (csr), not to error.
        let measured = [(Algo::Gcoo, 1e-6), (Algo::Csr, 2e-6), (Algo::DenseXla, 3e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 600, 100, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Csr, "measured"));
        // Every measured favorite unresolvable → the paper prior decides.
        let measured = [(Algo::Gcoo, 1e-6), (Algo::Csr, 2e-6)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 600, 200, None, &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::DenseXla, "sparse-capacity-exhausted"));
        // No estimates → exactly the prior.
        let plan = sel().plan_with_model(&r, 256, 0.99, 100, 50, None, &[]).unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Gcoo, "sparse-crossover"));
        // An explicit hint wins over any estimate.
        let measured = [(Algo::DenseXla, 1e-9)];
        let plan = sel()
            .plan_with_model(&r, 256, 0.99, 100, 50, Some(Algo::Csr), &measured)
            .unwrap();
        assert_eq!((plan.algo, plan.reason), (Algo::Csr, "hint"));
    }

    #[test]
    fn plan_for_uses_fused_stats() {
        let mut rng = crate::rng::Rng::new(5);
        let a = crate::gen::uniform(256, 0.995, &mut rng);
        let plan = sel().plan_for(&reg(), &a, 8, None).unwrap();
        assert_eq!(plan.algo, Algo::Gcoo);
        // The resolved cap must cover the matrix's actual band skew.
        let stats = crate::convert::scan_stats(&a, 8, 1);
        assert!(plan.cap >= stats.max_band_nnz());
    }
}
