//! The coordinator proper: worker pool over the bounded queue, executing
//! **fused operand-affine batches** on per-worker engines according to the
//! selector's plan — or straight from the operand store's cached slabs
//! for multiply-by-handle traffic.
//!
//! Request lifecycle (the zero-copy pipeline, batch-fused):
//!   submit (inline: A-signature computed; handle: store entry resolved +
//!   pinned, its signature copied in) → queue (backpressure) → batch
//!   dequeue keyed on [`batch_affine`] (equal operand + equal algo hint,
//!   so the batch provably shares one A) → **one fused stats scan** and
//!   **one plan** for the whole batch (handle batches: the registered
//!   plan, no scan) → convert A **once** into the worker's workspace slabs
//!   (EO, amortized over the batch; handle batches: **zero** conversions —
//!   EO was paid at `put_a`) → stack the batch's B operands column-wise
//!   into one wide `n_exec × width·n_exec` matrix → **one wide kernel**
//!   (KC; matching-cap = zero slab copies) → scatter the C column blocks
//!   back per request → optional verification vs the CPU oracle → reply +
//!   metrics (copy counters, batch-width histogram, conversions amortized
//!   + total, store gauges). Width-1 batches take [`process_one_ws`], the
//!   sequential special case the differential suites compare against.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::job::{AOperand, Algo, SpdmRequest, SpdmResponse};
use super::metrics::{Metrics, MetricsSnapshot};
use super::queue::BoundedQueue;
use super::selector::{Selector, SelectorPolicy};
use super::shard::ShardSpec;
use super::spill::SpillStore;
use super::store::{OperandEntry, OperandId, OperandPin, OperandStore, OperandSummary};
use super::tenant::{TenantRegistry, TenantSpec, DEFAULT_TENANT};
use super::tuner::{Clock, ModelKey, RealClock, Tuner, TunerConfig};
use super::workspace::Workspace;
use crate::convert::{self, AStats};
use crate::json::{self, Value};
use crate::ndarray::Mat;
use crate::runtime::{Engine, ExecPlan, Registry, SpdmOutput};
use crate::sparse::{CmrsSlabs, EllSlabs, GcooSlabs, RowSplitSlabs};

/// Coordinator tuning knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub queue_cap: usize,
    /// Max jobs one worker claims per batch (shape-affine).
    pub batch_max: usize,
    pub policy: SelectorPolicy,
    /// Band height used for conversions (must match exported artifacts).
    pub gcoo_p: usize,
    /// Threads used inside one conversion.
    pub convert_threads: usize,
    /// Byte budget of the converted-operand store (registered As plus
    /// their device slabs; LRU-evicted under pressure).
    pub store_budget_bytes: u64,
    /// Adaptive measured routing (tuner.rs): disabled by default, in which
    /// case routing is exactly the static paper-threshold policy.
    pub tuning: TunerConfig,
    /// Batch admission window in microseconds: a worker holding a partial
    /// affine batch keeps it open this long (on the injected clock) so
    /// open-loop traffic fuses wide. 0 (the default) disables the window —
    /// instant `pop_batch` semantics, bit-for-bit, with zero clock reads
    /// (see `queue.rs::pop_batch_windowed`).
    pub admission_window_us: u64,
    /// Cluster shard membership (`None` = not clustered). When set, the
    /// operand store assigns only handle ids this node owns on the
    /// consistent-hash ring (`shard.rs`), so a stateless router can
    /// resolve any handle's owner by hashing the id — no translation
    /// maps. `None` keeps the dense 1, 2, 3… sequence bit-for-bit.
    pub shard: Option<ShardSpec>,
    /// Tenant specs (ISSUE 9): per-tenant DRR weight, token-bucket rate,
    /// and store slice. Empty (the default) = the unlimited `default`
    /// tenant only — laneless queue, no rate limiting, whole-budget
    /// slice, bit-for-bit pre-tenancy behavior.
    pub tenants: Vec<TenantSpec>,
    /// Directory for the disk spill tier (`None` = no tier: evictions
    /// destroy the conversion, the pre-spill behavior).
    pub spill_dir: Option<PathBuf>,
    /// File-byte budget of the spill tier (0 = unbounded).
    pub spill_budget_bytes: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_cap: 64,
            batch_max: 8,
            policy: SelectorPolicy::default(),
            gcoo_p: 8,
            convert_threads: 4,
            store_budget_bytes: 256 << 20,
            tuning: TunerConfig::default(),
            admission_window_us: 0,
            shard: None,
            tenants: Vec::new(),
            spill_dir: None,
            spill_budget_bytes: 256 << 20,
        }
    }
}

/// The adaptive-routing context a worker threads through the pipeline:
/// the tuner (model + clock + counters), the operand store (route flips
/// republish entries through it), and the metrics sink (a flip's fresh
/// conversion is an EO event). Absent (or with the tuner disabled), every
/// pipeline function behaves exactly as static routing.
pub struct TuneCtx<'a> {
    pub tuner: &'a Tuner,
    pub store: &'a OperandStore,
    pub metrics: &'a Metrics,
}

/// Typed submission failure — the coordinator refusing a request is an
/// expected condition (shutdown race, unregistered operand, a tenant over
/// its token bucket), not a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator's queue is closed (shutdown started or completed).
    ShutDown,
    /// The request references an operand handle that is not registered
    /// (never was, was dropped, or was evicted).
    UnknownHandle(OperandId),
    /// The tenant's token bucket is empty (ISSUE 9). The payload is the
    /// full typed message (`RATE_LIMITED: …`) the wire layers forward
    /// verbatim; the connection stays open and the bucket refills with
    /// time — retry, don't reconnect.
    RateLimited(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
            SubmitError::UnknownHandle(h) => write!(f, "unknown operand handle {h}"),
            SubmitError::RateLimited(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    req: SpdmRequest,
    /// The resolved, pinned store entry for handle requests (pin taken at
    /// submit, released after the reply — the store's eviction barrier).
    pin: Option<OperandPin>,
    enqueued: Instant,
    reply: mpsc::Sender<SpdmResponse>,
}

/// One slot of a dequeued batch as the pipeline sees it: the request plus
/// its resolved store entry (handle requests) and enqueue time. Inline
/// callers build slots with [`BatchJob::inline`].
#[derive(Clone, Copy)]
pub struct BatchJob<'a> {
    pub req: &'a SpdmRequest,
    /// Resolved entry for `AOperand::Handle` requests; `None` for inline.
    pub entry: Option<&'a OperandEntry>,
    pub enqueued: Instant,
}

impl<'a> BatchJob<'a> {
    /// An inline-operand slot (no store entry).
    pub fn inline(req: &'a SpdmRequest, enqueued: Instant) -> Self {
        BatchJob { req, entry: None, enqueued }
    }
}

/// The serving coordinator.
///
/// **Each worker owns a full engine, compile cache, and workspace arena** —
/// the per-worker device-context pattern of GPU serving stacks (under PJRT
/// the client handles are `!Send`, so sharing one engine across threads is
/// not an option; the substrate engine keeps the same ownership shape, and
/// the workspace must never be shared — see `workspace.rs`). The batcher
/// keeps signature-affine jobs (one shared A) on one worker, which then
/// executes each batch fused — one A conversion, one wide kernel — while
/// per-worker compile caches and arena buffers stay hot at one geometry.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Job>>,
    metrics: Arc<Metrics>,
    store: Arc<OperandStore>,
    tuner: Arc<Tuner>,
    tenants: Arc<TenantRegistry>,
    registry: Arc<Registry>,
    cfg: CoordinatorConfig,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(registry: Arc<Registry>, cfg: CoordinatorConfig) -> Self {
        Coordinator::with_clock(registry, cfg, Arc::new(RealClock::new()))
    }

    /// Build a coordinator with an injected latency clock — production
    /// uses [`Coordinator::new`] (monotonic wall clock); tests inject a
    /// `ScriptedClock` so every measured latency, and therefore every
    /// adaptive routing decision, is deterministic.
    pub fn with_clock(
        registry: Arc<Registry>,
        cfg: CoordinatorConfig,
        clock: Arc<dyn Clock>,
    ) -> Self {
        // Tenancy (ISSUE 9): one registry drives all three planes — DRR
        // lanes in the queue, token buckets at submit, store slices at
        // eviction. With no tenants configured the registry is the single
        // unlimited `default` tenant, `lanes()` is empty, and every path
        // below is bit-for-bit the pre-tenancy coordinator.
        let tenants = Arc::new(TenantRegistry::new(&cfg.tenants, Arc::clone(&clock)));
        let lanes = tenants.lanes();
        let queue = Arc::new(if lanes.is_empty() {
            BoundedQueue::<Job>::new(cfg.queue_cap)
        } else {
            BoundedQueue::<Job>::with_lanes(cfg.queue_cap, &lanes)
        });
        let metrics = Arc::new(Metrics::new());
        // Spill tier: best-effort — an unusable directory degrades to the
        // pre-spill behavior (evictions destroy the conversion) rather
        // than failing construction.
        let spill = cfg.spill_dir.as_ref().and_then(|dir| {
            match SpillStore::new(dir, cfg.spill_budget_bytes) {
                Ok(s) => Some(s),
                Err(e) => {
                    eprintln!("spill tier disabled: {e}");
                    None
                }
            }
        });
        let store = Arc::new(OperandStore::with_tiers(
            cfg.store_budget_bytes,
            Some(Arc::clone(&tenants)),
            spill,
        ));
        let tuner = Arc::new(Tuner::new(cfg.tuning, Arc::clone(&clock)));
        let handles = (0..cfg.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let registry = Arc::clone(&registry);
                let store = Arc::clone(&store);
                let tuner = Arc::clone(&tuner);
                let clock = Arc::clone(&clock);
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("coordinator-{w}"))
                    .spawn(move || {
                        // Per-worker PJRT engine (see struct docs).
                        let engine = match Engine::new() {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every job this worker would take.
                                while let Some(batch) = queue.pop_batch(1, |_, _| false) {
                                    for job in batch {
                                        metrics.record_error();
                                        let _ = job.reply.send(SpdmResponse::failed(
                                            job.req.id,
                                            Algo::DenseXla,
                                            format!("engine init failed: {e}"),
                                        ));
                                    }
                                }
                                return;
                            }
                        };
                        // Per-worker workspace arena, owned next to the
                        // engine: reused across this worker's requests,
                        // never shared (workspace.rs ownership rule).
                        let mut ws = Workspace::new();
                        // Batch by A-signature (not rows: equal dimensions
                        // alone would fuse different As — the regression
                        // the signature key exists to prevent). A batch
                        // shares one A, so the worker converts once and
                        // runs one wide kernel over the stacked Bs. With an
                        // admission window configured, a partial batch is
                        // held open so late-arriving affine singles fuse in.
                        let window_s = cfg.admission_window_us as f64 * 1e-6;
                        while let Some((batch, outcome)) = queue.pop_batch_windowed(
                            cfg.batch_max,
                            |h, c| batch_affine(&h.req, &c.req),
                            window_s,
                            clock.as_ref(),
                        ) {
                            metrics.record_window(outcome);
                            metrics.record_batch(batch.len());
                            let jobs: Vec<BatchJob<'_>> = batch
                                .iter()
                                .map(|j| BatchJob {
                                    req: &j.req,
                                    entry: j.pin.as_ref().map(|p| p.entry()),
                                    enqueued: j.enqueued,
                                })
                                .collect();
                            let tune =
                                TuneCtx { tuner: &tuner, store: &store, metrics: &metrics };
                            let resps = process_batch_tuned(
                                &engine, &mut ws, &registry, &cfg, &jobs, Some(&tune),
                            );
                            drop(jobs);
                            // Credit only conversions actually skipped:
                            // jobs that would convert solo (inline sparse,
                            // or a handle whose hint the entry cannot
                            // serve) minus what the batch really paid.
                            // Pure handle traffic converts zero either way
                            // (EO was paid at put_a) and credits nothing.
                            let solo = batch
                                .iter()
                                .zip(resps.iter())
                                .filter(|(job, r)| {
                                    r.ok()
                                        && r.algo.is_sparse()
                                        && match (&job.req.a, job.pin.as_ref()) {
                                            (AOperand::Inline(_), _) => true,
                                            (AOperand::Handle(_), Some(p)) => {
                                                !p.entry().serves_hint(job.req.algo_hint)
                                            }
                                            (AOperand::Handle(_), None) => false,
                                        }
                                })
                                .count() as u64;
                            let actual: u64 = resps.iter().map(|r| r.conversions).sum();
                            metrics.record_amortized(solo.saturating_sub(actual));
                            for (job, resp) in batch.iter().zip(resps) {
                                metrics.record_conversions(resp.conversions);
                                if resp.ok() {
                                    metrics.record_completion(
                                        resp.algo.as_str(),
                                        resp.total_s,
                                        resp.kernel_s,
                                        resp.convert_s,
                                    );
                                    metrics.record_copy_traffic(
                                        resp.bytes_copied,
                                        resp.copies_avoided,
                                    );
                                    if resp.verified == Some(false) {
                                        metrics.record_verify_failure();
                                    }
                                } else {
                                    metrics.record_error();
                                }
                                let _ = job.reply.send(resp);
                            }
                            // `batch` drops here, releasing the operand
                            // pins the jobs held in flight.
                        }
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        Coordinator { queue, metrics, store, tuner, tenants, registry, cfg, handles }
    }

    /// Enqueue a request; the receiver yields the response when done.
    /// Blocks when the queue is full (backpressure). Returns
    /// [`SubmitError::ShutDown`] instead of panicking when racing shutdown.
    ///
    /// Handle requests are resolved here: the store entry is looked up,
    /// **pinned for the life of the job** (so eviction pressure cannot drop
    /// an operand mid-flight), and its content signature is copied into the
    /// request so handle and inline traffic sharing one A batch together.
    /// An unregistered/dropped handle fails fast with
    /// [`SubmitError::UnknownHandle`].
    pub fn submit(&self, mut req: SpdmRequest) -> Result<mpsc::Receiver<SpdmResponse>, SubmitError> {
        // Token-bucket admission first (ISSUE 9): a rate-limited request
        // must not touch the store (no checkout, no promotion, no gauge
        // drift) — the refusal is pure backpressure. Unlimited tenants
        // (and the untenanted default) admit with zero clock reads.
        if let Err(e) = self.tenants.admit(&req.tenant) {
            self.metrics.record_rate_limited(&self.tenants.resolve_owned(&req.tenant));
            return Err(SubmitError::RateLimited(e));
        }
        let pin = match &req.a {
            AOperand::Handle(h) => match self.store.checkout(*h) {
                Some(p) => {
                    req.a_sig = p.entry().sig;
                    Some(p)
                }
                None => return Err(SubmitError::UnknownHandle(*h)),
            },
            AOperand::Inline(_) => None,
        };
        let (tx, rx) = mpsc::channel();
        // Count before pushing so `submitted >= completed` always holds in
        // snapshots; undo on rejection.
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let lane = self.tenants.resolve_owned(&req.tenant);
        if !self.queue.push_to(&lane, Job { req, pin, enqueued: Instant::now(), reply: tx }) {
            self.metrics.submitted.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            return Err(SubmitError::ShutDown);
        }
        Ok(rx)
    }

    /// Submit and wait. Never panics: shutdown races and dropped reply
    /// channels come back as failed responses (which `serve` maps to JSON
    /// error replies).
    pub fn run_sync(&self, req: SpdmRequest) -> SpdmResponse {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                SpdmResponse::failed(id, Algo::DenseXla, "worker dropped reply channel".into())
            }),
            Err(e) => SpdmResponse::failed(id, Algo::DenseXla, e.to_string()),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Metrics snapshot with the operand-store gauges and the tuner's
    /// route-flip/exploration counters merged in (the serve
    /// `stats`/`metrics` endpoints report through this).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        let st = self.store.stats();
        snap.store_entries = st.entries;
        snap.store_bytes = st.bytes;
        snap.store_budget_bytes = st.budget_bytes;
        snap.store_hits = st.hits;
        snap.store_misses = st.misses;
        snap.store_evictions = st.evictions;
        snap.spill_writes = st.spill_writes;
        snap.spill_promotes = st.spill_promotes;
        snap.spill_bytes = st.spill_bytes;
        snap.route_flips = self.tuner.route_flips();
        snap.explorations = self.tuner.explorations_total();
        // Per-tenant splits (ISSUE 10): one full row per configured lane —
        // store bytes vs slice, both rejection counters, live DRR lane
        // depth/deficit. Untenanted coordinators keep the counter-only rows
        // the bare metrics snapshot produced (usually none).
        if self.tenants.is_multi() {
            let rejections = self.metrics.tenant_rejections();
            let lanes = self.queue.lane_stats();
            snap.tenants = self
                .tenants
                .lanes()
                .into_iter()
                .map(|(name, _w)| {
                    let (rl, qe) = rejections.get(&name).copied().unwrap_or((0, 0));
                    let (depth, deficit) = lanes
                        .iter()
                        .find(|(n, _, _)| *n == name)
                        .map(|&(_, d, def)| (d as u64, def))
                        .unwrap_or((0, 0));
                    super::metrics::TenantStat {
                        name: name.clone(),
                        bytes: self.store.tenant_bytes_of(&name),
                        slice_budget_bytes: self.tenants.slice_of(&name),
                        rate_limited: rl,
                        quota_exceeded: qe,
                        lane_depth: depth,
                        lane_deficit: deficit,
                    }
                })
                .collect();
        }
        snap
    }

    /// The adaptive-routing subsystem (tests script and inspect it).
    pub fn tuner(&self) -> Arc<Tuner> {
        Arc::clone(&self.tuner)
    }

    /// The `explain` payload: the routing policy in force, the adaptive
    /// counters, and one row per registered operand — published version,
    /// incumbent routing, ranked candidates, and the tuner's per-algo
    /// estimates (mean seconds per executed column, sample count, whether
    /// the sample gate has opened).
    pub fn explain_json(&self) -> String {
        let tcfg = self.tuner.config();
        let policy = Value::obj()
            .field("gcoo_crossover", self.cfg.policy.gcoo_crossover)
            .field("min_sparse_n", self.cfg.policy.min_sparse_n)
            .field("tuning_enabled", tcfg.enabled)
            .field("alpha", tcfg.alpha)
            .field("min_samples", tcfg.min_samples)
            .field("explore_every", tcfg.explore_every)
            .field("seed", tcfg.seed)
            .build();
        let entries: Vec<Value> = self
            .store
            .entries_snapshot()
            .iter()
            .map(|e| {
                let key = ModelKey::operand(e.handle);
                let candidates = Value::Arr(
                    e.candidates
                        .iter()
                        .map(|c| {
                            Value::obj()
                                .field("algo", c.algo.as_str())
                                .field("artifact", c.artifact.as_str())
                                .field("n_exec", c.n_exec)
                                .field("cap", c.cap)
                                .build()
                        })
                        .collect(),
                );
                let estimates = Value::Arr(
                    self.tuner
                        .estimates_view(key)
                        .into_iter()
                        .map(|(algo, mean, samples, gated)| {
                            Value::obj()
                                .field("algo", algo.as_str())
                                .field("mean_s_per_col", mean)
                                .field("samples", samples)
                                .field("gated", gated)
                                .build()
                        })
                        .collect(),
                );
                Value::obj()
                    .field("a_handle", e.handle.0)
                    .field("version", e.version)
                    .field("n", e.a.rows)
                    .field("algo", e.plan.algo.as_str())
                    .field("artifact", e.plan.artifact.as_str())
                    .field("reason", e.plan.reason)
                    .field("requests", self.tuner.requests_for(key))
                    .field("candidates", candidates)
                    .field("estimates", estimates)
                    .build()
            })
            .collect();
        json::write(
            &Value::obj()
                .field("policy", policy)
                .field("route_flips", self.tuner.route_flips())
                .field("explorations", self.tuner.explorations_total())
                .field("entries", Value::Arr(entries))
                .build(),
        )
    }

    /// Register an A operand: one signature, one stats scan, one resolved
    /// plan, one conversion — then every `spdm` by the returned handle
    /// executes from the cached slabs. Registering content already resident
    /// (same bytes, same hint) dedups to the existing handle.
    pub fn put_a(&self, a: Mat, hint: Option<Algo>) -> Result<Arc<OperandEntry>, String> {
        self.put_a_for(DEFAULT_TENANT, a, hint)
    }

    /// [`Coordinator::put_a`] on behalf of a tenant (ISSUE 9): the
    /// registration passes the tenant's token bucket (`RATE_LIMITED: …`
    /// errors when flooding) and charges the tenant's store slice
    /// (`QUOTA_EXCEEDED: …` when the slice cannot fit it) — both typed
    /// string errors the wire layers forward without closing the
    /// connection.
    pub fn put_a_for(
        &self,
        tenant: &str,
        a: Mat,
        hint: Option<Algo>,
    ) -> Result<Arc<OperandEntry>, String> {
        let owner = self.tenants.resolve_owned(tenant);
        if let Err(e) = self.tenants.admit(tenant) {
            self.metrics.record_rate_limited(&owner);
            return Err(e);
        }
        let (entry, converted) =
            match self.store.register_for(tenant, a, hint, &self.registry, &self.cfg) {
                Ok(v) => v,
                Err(e) => {
                    if e.starts_with(super::tenant::QUOTA_EXCEEDED) {
                        self.metrics.record_quota_exceeded(&owner);
                    }
                    return Err(e);
                }
            };
        if converted {
            self.metrics.record_conversions(1);
        }
        Ok(entry)
    }

    /// The tenant registry (wire layers resolve ids and tests inspect it).
    pub fn tenants(&self) -> Arc<TenantRegistry> {
        Arc::clone(&self.tenants)
    }

    /// Cluster replication (DESIGN.md §Cluster): install a copy of an
    /// owner node's entry under its original handle. The store
    /// re-converts from the shipped A — a real EO event on this node, so
    /// it is recorded like any other conversion (only when the entry was
    /// actually installed; the idempotent resident case performs none).
    pub fn replicate_entry(&self, src: &OperandEntry) -> Result<Arc<OperandEntry>, String> {
        let already = self.store.peek_entry(src.handle).is_some();
        let entry = self.store.register_replica(src, &self.cfg)?;
        if !already && entry.plan.algo.is_sparse() {
            self.metrics.record_conversions(1);
        }
        Ok(entry)
    }

    /// Drop a registered operand. In-flight jobs finish against their
    /// pinned snapshot; subsequent handle requests fail fast.
    pub fn drop_a(&self, h: OperandId) -> bool {
        self.store.remove(h)
    }

    /// Summaries of every registered operand (routing introspection).
    pub fn list_a(&self) -> Vec<OperandSummary> {
        self.store.list()
    }

    /// Dimension of a registered A (no LRU side effects; symmetric gauge
    /// accounting — a resolved probe counts a store hit, an unknown
    /// handle a miss) — the serve layer sizes synthetic B operands with
    /// this and rejects unknown handles here.
    pub fn operand_dims(&self, h: OperandId) -> Option<usize> {
        self.store.peek_dims(h)
    }

    /// The converted-operand store (shared; tests reach in for invariants).
    pub fn store(&self) -> Arc<OperandStore> {
        Arc::clone(&self.store)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Deterministic spill hygiene: the tier's files die with the
        // coordinator (shutdown consumes self, so this covers both
        // paths), not at whatever later point the last store Arc —
        // possibly held by a test or a detached server thread — drops.
        if let Some(spill) = self.store.spill() {
            spill.sweep();
        }
    }
}

/// Batch-affinity predicate: two requests may share a fused batch only if
/// they provably multiply by the same A and agree on the algorithm hint,
/// so one plan covers the whole batch.
///
/// Two handle requests are affine iff their [`OperandId`]s are equal —
/// store entries are immutable, so handle equality *is* content equality
/// and no O(n²) re-screen is needed on the all-handle path. Everything
/// else (inline/inline and mixed handle/inline, the handle side carrying
/// the entry's signature since submit) keys on the submit-time [`ASig`]
/// (dims + nnz + content hash). Rows-only matching is NOT sufficient: it
/// would fuse different As and silently answer k−1 requests with the
/// wrong product. For signature-keyed pairs the hash is the cheap dequeue
/// key, not the proof — [`process_batch_ws`] re-screens with a full
/// element-data comparison before fusing, so even a constructed hash
/// collision cannot cross-wire results.
pub fn batch_affine(a: &SpdmRequest, b: &SpdmRequest) -> bool {
    // Fusion never crosses a tenant boundary (ISSUE 9): a fused batch is
    // one scheduling unit, so cross-tenant fusion would let one tenant's
    // traffic ride another's lane and defeat weighted-fair dequeue.
    a.tenant == b.tenant
        && a.algo_hint == b.algo_hint
        && match (&a.a, &b.a) {
            (AOperand::Handle(x), AOperand::Handle(y)) => x == y,
            _ => a.a_sig == b.a_sig,
        }
}

/// Trim an m×m result back to n×n (fresh allocation: the trimmed matrix is
/// the caller-owned response payload).
fn trim_mat(c: &Mat, n: usize) -> Mat {
    let mut out = Mat::zeros(0, 0);
    out.trim_from(c, n);
    out
}

/// Execute one inline request end to end with a throwaway workspace — the
/// CLI/one-shot entry point. Serving workers use [`process_one_ws`] with
/// their per-worker arena (and resolved store entries for handle traffic).
pub fn process_one(
    engine: &Engine,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let mut ws = Workspace::new();
    process_one_ws(engine, &mut ws, registry, cfg, req, None, enqueued)
}

/// Execute one request through the zero-copy pipeline: one fused stats
/// scan, one plan (resolved before any conversion), **at most one
/// conversion of A on every path** (directly into the workspace's device
/// slabs), and zero slab copies when the planned capacity matches the
/// artifact — which the plan guarantees by construction.
///
/// Handle requests (`entry` = the resolved store entry) skip all of that:
/// the registered plan is reused and the engine borrows the entry's cached
/// device slabs directly — no scan, no conversion, no A-side copy. A
/// request whose hint the entry cannot serve (see
/// [`OperandEntry::serves_hint`]) falls back to the convert-per-request
/// path over the entry's dense A.
pub fn process_one_ws(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    entry: Option<&OperandEntry>,
    enqueued: Instant,
) -> SpdmResponse {
    process_one_tuned(engine, ws, registry, cfg, req, entry, enqueued, None)
}

/// [`process_one_ws`] with the adaptive-routing context threaded through.
/// With `tune` absent (or the tuner disabled) the behavior is exactly the
/// static pipeline. With it enabled, **unhinted** requests engage the
/// tuner: inline traffic plans through `Selector::plan_with_model` (gated
/// measured estimates outrank the paper prior) and may take a seeded
/// exploration draw toward the top alternative; cached-operand traffic
/// runs [`exec_cached_adaptive`] (exploration + observation + the
/// model-driven route flip). Hinted requests never consult the tuner —
/// the hint is the contract. Routing can change the response's
/// algo/artifact provenance, never its numbers: every family accumulates
/// each output element over ascending k in f32, so the result is bitwise
/// identical whichever plan runs (`tests/routing_differential.rs`).
#[allow(clippy::too_many_arguments)]
pub fn process_one_tuned(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    entry: Option<&OperandEntry>,
    enqueued: Instant,
    tune: Option<&TuneCtx<'_>>,
) -> SpdmResponse {
    let tune = tune.filter(|t| t.tuner.enabled());
    let Some(a) = req.a_mat(entry) else {
        let msg = match &req.a {
            AOperand::Handle(h) => format!("unresolved operand handle {h}"),
            AOperand::Inline(_) => "inline operand unavailable".to_string(),
        };
        return SpdmResponse::failed(req.id, req.algo_hint.unwrap_or(Algo::DenseXla), msg);
    };
    let n = a.rows;
    if a.cols != n || req.b.rows != n || req.b.cols != n {
        return SpdmResponse::failed(
            req.id,
            Algo::DenseXla,
            format!("non-square or mismatched shapes: A {}x{}, B {}x{}", a.rows, a.cols, req.b.rows, req.b.cols),
        );
    }

    // --- cached-operand fast path: registered plan + cached device slabs ---
    if let Some(e) = entry {
        if e.serves_hint(req.algo_hint) {
            if let Some(t) = tune {
                if req.algo_hint.is_none() {
                    return exec_cached_adaptive(engine, ws, registry, cfg, req, e, t, enqueued);
                }
            }
            return exec_cached_one(engine, ws, registry, req, e, enqueued);
        }
    }

    // --- fused stats scan: sparsity + max row nnz + band nnz, one pass ---
    // (This is also Algorithm 1's counting pass: the scatter below reuses
    // the band counts, so conversion never re-scans A for sizes. Its time
    // is billed into convert_s on the sparse paths only — there it
    // replaces the counting pass that pre-refactor conversion timed
    // itself, keeping EO comparable; dense requests convert nothing, as
    // before.)
    let t_stats = Instant::now();
    let stats = convert::scan_stats(a, cfg.gcoo_p, cfg.convert_threads);
    let stats_s = t_stats.elapsed().as_secs_f64();
    let sparsity = stats.sparsity();

    // --- plan once, before any conversion: the static prior, or the
    // measured model for unhinted inline traffic under an enabled tuner ---
    let selector = Selector::new(cfg.policy);
    let adaptive = tune.filter(|_| entry.is_none() && req.algo_hint.is_none());
    let key = ModelKey::signature(req.a_sig.hash);
    let planned = match adaptive {
        Some(t) => selector.plan_with_model(
            registry,
            n,
            sparsity,
            stats.max_band_nnz(),
            stats.max_row_nnz,
            None,
            &t.tuner.estimates_for(key),
        ),
        None => selector.plan(
            registry,
            n,
            sparsity,
            stats.max_band_nnz(),
            stats.max_row_nnz,
            req.algo_hint,
        ),
    };
    let mut plan = match planned {
        Ok(p) => p,
        Err(e) => {
            return SpdmResponse::failed(req.id, req.algo_hint.unwrap_or(Algo::DenseXla), e)
        }
    };
    // Seeded exploration: override toward the top resolvable alternative
    // so the model gathers samples for the non-incumbent too.
    if let Some(t) = adaptive {
        let idx = t.tuner.next_index(key);
        if t.tuner.draw(key, idx) {
            if let Some(mut alt) = selector
                .plan_candidates(registry, n, sparsity, stats.max_band_nnz(), stats.max_row_nnz)
                .into_iter()
                .find(|c| c.algo != plan.algo)
            {
                alt.reason = "explore";
                t.tuner.record_exploration();
                plan = alt;
            }
        }
    }
    match adaptive {
        Some(t) => {
            // Bracket the execution with the injected clock (exactly two
            // reads) and feed the per-column cost into the model.
            let t0 = t.tuner.now_s();
            let resp =
                exec_planned(engine, ws, registry, cfg, req, a, &plan, &stats, stats_s, enqueued);
            let dt = t.tuner.now_s() - t0;
            if resp.ok() {
                t.tuner.observe(key, resp.algo, plan.n_exec, dt);
            }
            resp
        }
        None => exec_planned(engine, ws, registry, cfg, req, a, &plan, &stats, stats_s, enqueued),
    }
}

/// The post-plan half of the zero-copy pipeline: execute one request under
/// an already-resolved plan — at most one conversion of A (straight into
/// the workspace's device slabs) and zero slab copies at the planned
/// capacity. Shared by static routing, the measured model, and the
/// exploration/fallback paths, so every route runs identical code.
#[allow(clippy::too_many_arguments)]
fn exec_planned(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    a: &Mat,
    plan: &ExecPlan,
    stats: &AStats,
    stats_s: f64,
    enqueued: Instant,
) -> SpdmResponse {
    let n = a.rows;
    let mut bytes_copied = 0u64;
    let mut copies_avoided = 0u64;
    let mut convert_s = 0.0;
    let mut conversions = 0u64;

    // B: borrow the request's matrix when it is already at the execution
    // size; otherwise pad into the arena (no fresh allocation steady-state).
    let b_exec: &Mat = if req.b.rows == plan.n_exec && req.b.cols == plan.n_exec {
        copies_avoided += 1;
        &req.b
    } else {
        ws.b_pad.pad_from(&req.b, plan.n_exec);
        bytes_copied += (req.b.rows * req.b.cols * 4) as u64;
        &ws.b_pad
    };

    let exec = match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            // The one conversion of A: scatter straight into device slabs
            // at the planned capacity (timed: the paper's EO). Padded A is
            // never materialized.
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_slabs_into(
                a,
                stats,
                plan.n_exec,
                plan.cap,
                cfg.convert_threads,
                &mut ws.gcoo_vals,
                &mut ws.gcoo_rows,
                &mut ws.gcoo_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            conversions += 1;
            let slabs = GcooSlabs {
                g: plan.n_exec.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: plan.n_exec,
                vals: &ws.gcoo_vals,
                rows: &ws.gcoo_rows,
                cols: &ws.gcoo_cols,
            };
            engine.run_gcoo_slabs(registry, slabs, b_exec, plan.algo == Algo::Gcoo)
        }
        Algo::Csr => {
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_ell_into(
                a,
                plan.n_exec,
                plan.cap,
                &mut ws.ell_vals,
                &mut ws.ell_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            conversions += 1;
            let slabs = EllSlabs {
                n: plan.n_exec,
                rowcap: plan.cap,
                vals: &ws.ell_vals,
                cols: &ws.ell_cols,
            };
            engine.run_ell_slabs(registry, slabs, b_exec)
        }
        Algo::Cmrs => {
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_cmrs_into(
                a,
                stats,
                plan.n_exec,
                plan.cap,
                &mut ws.cmrs_vals,
                &mut ws.cmrs_rows,
                &mut ws.cmrs_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            conversions += 1;
            let slabs = CmrsSlabs {
                g: plan.n_exec.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: plan.n_exec,
                vals: &ws.cmrs_vals,
                rows: &ws.cmrs_rows,
                cols: &ws.cmrs_cols,
            };
            engine.run_cmrs_slabs(registry, slabs, b_exec)
        }
        Algo::RowSplit => {
            let t0 = Instant::now();
            let segs = match convert::dense_to_rowsplit_into(
                a,
                plan.n_exec,
                plan.cap,
                &mut ws.rowsplit_vals,
                &mut ws.rowsplit_rows,
                &mut ws.rowsplit_cols,
            ) {
                Ok(s) => s,
                Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
            };
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            conversions += 1;
            let slabs = RowSplitSlabs {
                segs,
                cap: plan.cap,
                n: plan.n_exec,
                vals: &ws.rowsplit_vals,
                seg_rows: &ws.rowsplit_rows,
                cols: &ws.rowsplit_cols,
            };
            engine.run_rowsplit_slabs(registry, slabs, b_exec)
        }
        Algo::DenseXla | Algo::DensePallas => {
            let t0 = Instant::now();
            let a_exec: &Mat = if n == plan.n_exec {
                copies_avoided += 1;
                a
            } else {
                ws.a_pad.pad_from(a, plan.n_exec);
                bytes_copied += (n * n * 4) as u64;
                &ws.a_pad
            };
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_dense(registry, plan.algo.as_str(), a_exec, b_exec)
        }
    };

    let out = match exec {
        Ok(o) => o,
        Err(e) => {
            // A kernel failure does not un-convert A: keep the EO event
            // this request already performed in the accounting.
            let mut r = SpdmResponse::failed(req.id, plan.algo, e.to_string());
            r.conversions = conversions;
            return r;
        }
    };
    finish_single(
        req,
        a,
        plan.algo,
        plan.n_exec,
        out,
        convert_s,
        conversions,
        bytes_copied,
        copies_avoided,
        enqueued,
    )
}

/// Shared epilogue of the single-request paths ([`process_one_ws`] and
/// [`exec_cached_one`]): fold the engine's copy stats in, move C out when
/// it is already n×n (trim otherwise), run the optional oracle, and
/// assemble the response. One definition keeps the copy accounting and
/// oracle tolerances identical on the inline and handle paths — the
/// bitwise parity the differential suite locks down.
#[allow(clippy::too_many_arguments)]
fn finish_single(
    req: &SpdmRequest,
    a: &Mat,
    algo: Algo,
    n_exec: usize,
    out: SpdmOutput,
    convert_s: f64,
    conversions: u64,
    mut bytes_copied: u64,
    mut copies_avoided: u64,
    enqueued: Instant,
) -> SpdmResponse {
    let n = a.rows;
    bytes_copied += out.copy.bytes_copied;
    copies_avoided += out.copy.copies_avoided;
    // Move the result out when it is already n×n; trim otherwise.
    let c = if out.c.rows == n && out.c.cols == n {
        copies_avoided += 1;
        out.c
    } else {
        bytes_copied += (n * n * 4) as u64;
        trim_mat(&out.c, n)
    };
    let verified = if req.verify {
        let oracle = a.matmul(&req.b);
        Some(c.allclose(&oracle, 1e-3, 1e-2))
    } else {
        None
    };
    SpdmResponse {
        id: req.id,
        algo,
        artifact: out.artifact,
        n_exec,
        convert_s,
        kernel_s: out.kernel_s,
        total_s: enqueued.elapsed().as_secs_f64(),
        verified,
        error: None,
        c: Some(c),
        bytes_copied,
        copies_avoided,
        conversions,
    }
}

/// The cached-operand execution core: reuse the registered [`ExecPlan`]
/// and run the engine straight over the store entry's device slabs. No
/// stats scan, no conversion (EO was paid at registration), no A-side
/// copy — only B is padded if the request is below the execution size.
fn exec_cached_one(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    req: &SpdmRequest,
    e: &OperandEntry,
    enqueued: Instant,
) -> SpdmResponse {
    let plan = &e.plan;
    let mut bytes_copied = 0u64;
    let mut copies_avoided = 0u64;
    let b_exec: &Mat = if req.b.rows == plan.n_exec && req.b.cols == plan.n_exec {
        copies_avoided += 1;
        &req.b
    } else {
        ws.b_pad.pad_from(&req.b, plan.n_exec);
        bytes_copied += (req.b.rows * req.b.cols * 4) as u64;
        &ws.b_pad
    };
    let out = match engine.run_operand(registry, plan, &e.operand, b_exec) {
        Ok(o) => o,
        Err(err) => return SpdmResponse::failed(req.id, plan.algo, err.to_string()),
    };
    // convert_s 0.0 / conversions 0: EO was paid at registration.
    finish_single(req, &e.a, plan.algo, plan.n_exec, out, 0.0, 0, bytes_copied, copies_avoided, enqueued)
}

/// The cached-operand path under an enabled tuner (unhinted requests
/// only): claim the entry's next request index, take the seeded
/// exploration draw — executing the top-ranked non-incumbent candidate
/// via a one-off conversion over the entry's dense A when it fires, the
/// cached incumbent otherwise — feed the bracketed per-column cost into
/// the model, and finally apply the route-flip rule: once the gated
/// estimates name a strictly faster candidate, the entry is republished
/// under it ([`OperandStore::reroute`]). Every branch produces bitwise
/// the same C; only algo/artifact provenance differs.
#[allow(clippy::too_many_arguments)]
fn exec_cached_adaptive(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    e: &OperandEntry,
    t: &TuneCtx<'_>,
    enqueued: Instant,
) -> SpdmResponse {
    let key = ModelKey::operand(e.handle);
    let idx = t.tuner.next_index(key);
    let explored: Option<ExecPlan> = if t.tuner.draw(key, idx) {
        e.candidates.iter().find(|c| c.algo != e.plan.algo).cloned()
    } else {
        None
    };
    let resp = match explored {
        Some(mut alt) => {
            alt.reason = "explore";
            alt.width = 1;
            t.tuner.record_exploration();
            // The exploration sample: convert-per-request over the
            // entry's dense A under the alternative's resolved plan (no
            // re-planning, and no re-scan — the candidate already pins
            // its artifact and the immutable entry carries its
            // registration-time stats; the scan was billed at put_a).
            let t0 = t.tuner.now_s();
            let resp = exec_planned(
                engine, ws, registry, cfg, req, &e.a, &alt, &e.stats, 0.0, enqueued,
            );
            let dt = t.tuner.now_s() - t0;
            if resp.ok() {
                t.tuner.observe(key, resp.algo, alt.n_exec, dt);
            }
            resp
        }
        None => {
            let t0 = t.tuner.now_s();
            let resp = exec_cached_one(engine, ws, registry, req, e, enqueued);
            let dt = t.tuner.now_s() - t0;
            if resp.ok() {
                t.tuner.observe(key, resp.algo, e.plan.n_exec, dt);
            }
            resp
        }
    };
    flip_if_ready(t, e, cfg, key);
    resp
}

/// Apply the measured route-flip rule after an observation: republish the
/// entry under the gated measured favorite when one strictly beats the
/// incumbent. The store refuses stale flips (this job may hold an older
/// pinned version than the published one), so the check is safe to run
/// after every request; a successful flip performs one fresh conversion —
/// an EO event the metrics record.
fn flip_if_ready(t: &TuneCtx<'_>, e: &OperandEntry, cfg: &CoordinatorConfig, key: ModelKey) {
    if let Some(alt) = t.tuner.best_alternative(key, e) {
        if t.store.reroute(e, &alt, cfg).is_ok() {
            t.tuner.record_flip();
            t.metrics.record_conversions(1);
        }
    }
}

/// Execute one shape-affine batch as a fused unit: convert the shared A
/// **once** (or reuse a registered operand's cached slabs and convert not
/// at all), stack the batch's B operands column-wise into one wide dense
/// matrix, run **one** wide kernel, and scatter the C column blocks back
/// into per-request responses (input order preserved).
///
/// Width 1 is the sequential special case ([`process_one_ws`]). The queue
/// predicate ([`batch_affine`]) guarantees affinity, but this function is
/// public, so it re-screens defensively: any job whose A operand, shape,
/// or algorithm hint cannot join the head's fused unit is re-anchored on
/// a fused unit of its own (recursively, preserving input order) instead
/// of poisoning the batch. Handle/handle pairs re-screen on
/// [`OperandId`] equality alone — store entries are immutable, so no
/// element comparison is needed; signature-keyed pairs (inline and mixed
/// handle/inline) still get the full element-data comparison, and a mixed
/// pair additionally requires the entry's registered routing to match the
/// batch hint so inline riders never execute under a plan they only
/// inherited from co-batched handle traffic.
pub fn process_batch_ws(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    batch: &[BatchJob<'_>],
) -> Vec<SpdmResponse> {
    process_batch_tuned(engine, ws, registry, cfg, batch, None)
}

/// [`process_batch_ws`] with the adaptive-routing context threaded
/// through: width-1 slots and re-screen singles take
/// [`process_one_tuned`] (full adaptivity), fused units plan through the
/// measured model and feed it one observation per batch. Absent (or
/// disabled), behavior is exactly the static pipeline.
pub fn process_batch_tuned(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    batch: &[BatchJob<'_>],
    tune: Option<&TuneCtx<'_>>,
) -> Vec<SpdmResponse> {
    let tune = tune.filter(|t| t.tuner.enabled());
    if batch.is_empty() {
        return Vec::new();
    }
    if batch.len() == 1 {
        let j = &batch[0];
        return vec![process_one_tuned(engine, ws, registry, cfg, j.req, j.entry, j.enqueued, tune)];
    }
    let head = &batch[0];
    let head_a = head.req.a_mat(head.entry);
    // A head that cannot anchor a fused unit (unresolved handle or
    // non-square A) sends every job through its individual path, which
    // reports the precise failure.
    let n = match head_a {
        Some(ha) if ha.rows == ha.cols && ha.rows > 0 => ha.rows,
        _ => 0,
    };
    if n == 0 {
        return batch
            .iter()
            .map(|j| process_one_tuned(engine, ws, registry, cfg, j.req, j.entry, j.enqueued, tune))
            .collect();
    }
    let mut out: Vec<Option<SpdmResponse>> = batch.iter().map(|_| None).collect();
    let mut fused: Vec<usize> = Vec::new();
    // A hint-forced registration serves *handle* requests by the
    // registered-routing contract, but an inline request never opted into
    // that contract: adopting such an entry's cached plan for a mixed
    // batch would make the inline rider's algo/artifact depend on what it
    // happened to co-batch with. Exactly the divergent combination — an
    // entry registered under an explicit hint, batch unhinted — is kept
    // out of mixed fusion (the handle job runs individually under its own
    // contract); every other combination resolves to the same plan on both
    // paths, or the entry is never consulted as the cache.
    let entry_fuses_with_inline = |e: Option<&OperandEntry>| match e {
        Some(e) => {
            e.hint.is_none()
                || e.hint == head.req.algo_hint
                || !e.serves_hint(head.req.algo_hint)
        }
        None => true,
    };
    let mut rest: Vec<usize> = Vec::new();
    for (i, j) in batch.iter().enumerate() {
        let fusable = j.req.algo_hint == head.req.algo_hint
            && j.req.b.rows == n
            && j.req.b.cols == n
            && match (&head.req.a, &j.req.a) {
                // Immutable store entries: handle equality is content
                // equality (and equal dims) — no re-screen needed. The
                // rider must still carry its resolved entry, though: an
                // unresolved handle cannot execute in a fused unit and
                // reports its failure individually instead.
                (AOperand::Handle(x), AOperand::Handle(y)) => {
                    x == y && j.req.a_mat(j.entry).is_some()
                }
                _ => match j.req.a_mat(j.entry) {
                    Some(ja) => {
                        ja.rows == n
                            && ja.cols == n
                            && j.req.a_sig == head.req.a_sig
                            && ja.data == head_a.expect("n > 0 implies head A").data
                            && entry_fuses_with_inline(head.entry)
                            && entry_fuses_with_inline(j.entry)
                    }
                    None => false,
                },
            };
        if fusable {
            fused.push(i);
        } else if i == 0 {
            // The head failed its own screen (e.g. mis-shaped B): answer it
            // individually so the recursion below — which is anchored on
            // the head never re-entering `rest` — always terminates.
            out[i] =
                Some(process_one_tuned(engine, ws, registry, cfg, j.req, j.entry, j.enqueued, tune));
        } else {
            rest.push(i);
        }
    }
    if fused.len() == 1 {
        let i = fused[0];
        let j = &batch[i];
        out[i] = Some(process_one_tuned(engine, ws, registry, cfg, j.req, j.entry, j.enqueued, tune));
    } else if !fused.is_empty() {
        let jobs: Vec<BatchJob<'_>> = fused.iter().map(|&i| batch[i]).collect();
        let resps = process_fused(engine, ws, registry, cfg, &jobs, tune);
        for (&i, resp) in fused.iter().zip(resps) {
            out[i] = Some(resp);
        }
    }
    // Jobs the head could not anchor may still be mutually fusable — e.g.
    // inline riders expelled from a hint-conflicted mixed batch, or
    // same-content jobs behind the defensive re-screen. Re-anchor them on
    // their own first job instead of serializing each individually; the
    // recursion terminates because the head always joins its own fused
    // set, so `rest` strictly shrinks.
    if !rest.is_empty() {
        let jobs: Vec<BatchJob<'_>> = rest.iter().map(|&i| batch[i]).collect();
        let resps = process_batch_tuned(engine, ws, registry, cfg, &jobs, tune);
        for (&i, resp) in rest.iter().zip(resps) {
            out[i] = Some(resp);
        }
    }
    out.into_iter().map(|r| r.expect("every batch slot answered")).collect()
}

/// The fused execution core: all jobs share one square n×n A (equal
/// operands, pre-screened by the caller) and one algorithm hint;
/// `jobs.len() >= 2`.
fn process_fused(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    jobs: &[BatchJob<'_>],
    tune: Option<&TuneCtx<'_>>,
) -> Vec<SpdmResponse> {
    let head = &jobs[0];
    // Adaptivity engages for unhinted batches only (the hint is the
    // contract). Fused units never explore or flip — they feed the model
    // one observation per batch; flips happen on width-1 traffic.
    let tune = tune.filter(|t| t.tuner.enabled() && head.req.algo_hint.is_none());
    let a = head
        .req
        .a_mat(head.entry)
        .expect("caller screened the batch head");
    let n = a.rows;
    let k = jobs.len();
    // `conversions` = EO events the batch already performed before the
    // failure, billed to job 0 exactly like the success path — a kernel
    // failure does not un-convert A, so the accounting keeps it.
    let fail_all = |algo: Algo, msg: String, conversions: u64| -> Vec<SpdmResponse> {
        jobs.iter()
            .enumerate()
            .map(|(j, job)| {
                let mut r = SpdmResponse::failed(job.req.id, algo, msg.clone());
                if j == 0 {
                    r.conversions = conversions;
                }
                r
            })
            .collect()
    };

    debug_assert!(jobs
        .iter()
        .all(|j| j.req.a_mat(j.entry).map(|m| m.data == a.data).unwrap_or(
            matches!((&j.req.a, &head.req.a),
                (AOperand::Handle(x), AOperand::Handle(y)) if x == y)
        )));

    // A cached store entry anywhere in the batch serves the whole fused
    // unit (the batch provably shares one A and one hint, and the caller's
    // screen guarantees any entry here routes identically to what the
    // batch would resolve): reuse its registered plan and device slabs —
    // zero conversions for the batch.
    let cached: Option<&OperandEntry> = jobs
        .iter()
        .find_map(|j| j.entry.filter(|e| e.serves_hint(head.req.algo_hint)));

    // One plan for the whole batch: the cached entry's, or one resolved
    // from a fresh fused stats scan.
    let (mut plan, stats, stats_s) = match cached {
        Some(e) => (e.plan.clone(), None, 0.0),
        None => {
            let t_stats = Instant::now();
            let stats = convert::scan_stats(a, cfg.gcoo_p, cfg.convert_threads);
            let stats_s = t_stats.elapsed().as_secs_f64();
            let selector = Selector::new(cfg.policy);
            // Unhinted adaptive batches plan through the measured model
            // (same fallback chain; an empty model is exactly the prior).
            let planned = match tune {
                Some(t) => selector.plan_with_model(
                    registry,
                    n,
                    stats.sparsity(),
                    stats.max_band_nnz(),
                    stats.max_row_nnz,
                    None,
                    &t.tuner.estimates_for(ModelKey::signature(head.req.a_sig.hash)),
                ),
                None => selector.plan(
                    registry,
                    n,
                    stats.sparsity(),
                    stats.max_band_nnz(),
                    stats.max_row_nnz,
                    head.req.algo_hint,
                ),
            };
            let plan = match planned {
                Ok(p) => p,
                Err(e) => return fail_all(head.req.algo_hint.unwrap_or(Algo::DenseXla), e, 0),
            };
            (plan, Some(stats), stats_s)
        }
    };
    plan.width = k;
    let ne = plan.n_exec;
    let model_key = cached
        .map(|e| ModelKey::operand(e.handle))
        .unwrap_or_else(|| ModelKey::signature(head.req.a_sig.hash));

    // Stack the B operands column-wise: wide B = [B_0 | B_1 | … | B_{k−1}],
    // each block zero-padded from n to ne. Rows n..ne stay zero — A has no
    // entries in those columns, so they contribute nothing to any product.
    ws.b_stack.zero_into(ne, plan.width * ne);
    for (j, job) in jobs.iter().enumerate() {
        for i in 0..n {
            ws.b_stack.row_mut(i)[j * ne..j * ne + n].copy_from_slice(job.req.b.row(i));
        }
    }
    let b_bytes_each = (n * n * 4) as u64;

    // Same EO accounting as `process_one_ws`: the stats scan bills into
    // convert_s on the sparse paths only (dense converts nothing), and a
    // cached-operand batch converts nothing at all.
    let mut convert_s = 0.0;
    let mut conversions = 0u64;
    let mut head_bytes = 0u64; // once-per-batch copies (slab repad, dense A pad)
    // Bracket the fused execution with the injected clock (one
    // observation per batch; a failing batch leaves its start read
    // unpaired, which only matters to scripts that also script failures).
    let t_exec = tune.map(|t| t.tuner.now_s());
    let (kernel_s, artifact, copy) = if let Some(e) = cached {
        // One wide kernel straight over the registered device slabs.
        match engine.run_operand_into(registry, &plan, &e.operand, &ws.b_stack, &mut ws.c_stack) {
            Ok(s) => (s.kernel_s, s.artifact, s.copy),
            Err(err) => return fail_all(plan.algo, err.to_string(), 0),
        }
    } else {
        let stats = stats.as_ref().expect("uncached batch carries stats");
        match plan.algo {
            Algo::Gcoo | Algo::GcooNoreuse => {
                // The batch's one and only A conversion — the invariant the
                // differential suite asserts via convert_s/conversions_amortized.
                let t0 = Instant::now();
                if let Err(e) = convert::dense_to_slabs_into(
                    a,
                    stats,
                    ne,
                    plan.cap,
                    cfg.convert_threads,
                    &mut ws.gcoo_vals,
                    &mut ws.gcoo_rows,
                    &mut ws.gcoo_cols,
                ) {
                    return fail_all(plan.algo, e.to_string(), 0);
                }
                convert_s += stats_s + t0.elapsed().as_secs_f64();
                conversions += 1;
                let slabs = GcooSlabs {
                    g: ne.div_ceil(cfg.gcoo_p),
                    cap: plan.cap,
                    p: cfg.gcoo_p,
                    n: ne,
                    vals: &ws.gcoo_vals,
                    rows: &ws.gcoo_rows,
                    cols: &ws.gcoo_cols,
                };
                match engine.run_gcoo_slabs_into(
                    registry,
                    slabs,
                    &ws.b_stack,
                    plan.algo == Algo::Gcoo,
                    &mut ws.c_stack,
                ) {
                    Ok(s) => (s.kernel_s, s.artifact, s.copy),
                    Err(e) => return fail_all(plan.algo, e.to_string(), conversions),
                }
            }
            Algo::Csr => {
                let t0 = Instant::now();
                if let Err(e) = convert::dense_to_ell_into(
                    a,
                    ne,
                    plan.cap,
                    &mut ws.ell_vals,
                    &mut ws.ell_cols,
                ) {
                    return fail_all(plan.algo, e.to_string(), 0);
                }
                convert_s += stats_s + t0.elapsed().as_secs_f64();
                conversions += 1;
                let slabs = EllSlabs {
                    n: ne,
                    rowcap: plan.cap,
                    vals: &ws.ell_vals,
                    cols: &ws.ell_cols,
                };
                match engine.run_ell_slabs_into(registry, slabs, &ws.b_stack, &mut ws.c_stack) {
                    Ok(s) => (s.kernel_s, s.artifact, s.copy),
                    Err(e) => return fail_all(plan.algo, e.to_string(), conversions),
                }
            }
            Algo::Cmrs => {
                let t0 = Instant::now();
                if let Err(e) = convert::dense_to_cmrs_into(
                    a,
                    stats,
                    ne,
                    plan.cap,
                    &mut ws.cmrs_vals,
                    &mut ws.cmrs_rows,
                    &mut ws.cmrs_cols,
                ) {
                    return fail_all(plan.algo, e.to_string(), 0);
                }
                convert_s += stats_s + t0.elapsed().as_secs_f64();
                conversions += 1;
                let slabs = CmrsSlabs {
                    g: ne.div_ceil(cfg.gcoo_p),
                    cap: plan.cap,
                    p: cfg.gcoo_p,
                    n: ne,
                    vals: &ws.cmrs_vals,
                    rows: &ws.cmrs_rows,
                    cols: &ws.cmrs_cols,
                };
                match engine.run_cmrs_slabs_into(registry, slabs, &ws.b_stack, &mut ws.c_stack) {
                    Ok(s) => (s.kernel_s, s.artifact, s.copy),
                    Err(e) => return fail_all(plan.algo, e.to_string(), conversions),
                }
            }
            Algo::RowSplit => {
                let t0 = Instant::now();
                let segs = match convert::dense_to_rowsplit_into(
                    a,
                    ne,
                    plan.cap,
                    &mut ws.rowsplit_vals,
                    &mut ws.rowsplit_rows,
                    &mut ws.rowsplit_cols,
                ) {
                    Ok(s) => s,
                    Err(e) => return fail_all(plan.algo, e.to_string(), 0),
                };
                convert_s += stats_s + t0.elapsed().as_secs_f64();
                conversions += 1;
                let slabs = RowSplitSlabs {
                    segs,
                    cap: plan.cap,
                    n: ne,
                    vals: &ws.rowsplit_vals,
                    seg_rows: &ws.rowsplit_rows,
                    cols: &ws.rowsplit_cols,
                };
                match engine.run_rowsplit_slabs_into(registry, slabs, &ws.b_stack, &mut ws.c_stack)
                {
                    Ok(s) => (s.kernel_s, s.artifact, s.copy),
                    Err(e) => return fail_all(plan.algo, e.to_string(), conversions),
                }
            }
            Algo::DenseXla | Algo::DensePallas => {
                let t0 = Instant::now();
                let a_exec: &Mat = if n == ne {
                    a
                } else {
                    ws.a_pad.pad_from(a, ne);
                    head_bytes += (n * n * 4) as u64;
                    &ws.a_pad
                };
                convert_s += t0.elapsed().as_secs_f64();
                match engine.run_dense(registry, plan.algo.as_str(), a_exec, &ws.b_stack) {
                    Ok(o) => {
                        let (ks, art, cp) = (o.kernel_s, o.artifact, o.copy);
                        // Dense kernels return an owned wide C; stage it where
                        // the scatter reads (replaces the staging allocation).
                        ws.c_stack = o.c;
                        (ks, art, cp)
                    }
                    Err(e) => return fail_all(plan.algo, e.to_string(), conversions),
                }
            }
        }
    };
    head_bytes += copy.bytes_copied;
    if let (Some(t), Some(t0)) = (tune, t_exec) {
        let dt = t.tuner.now_s() - t0;
        t.tuner.observe(model_key, plan.algo, plan.width * ne, dt);
    }

    // Scatter: request j's C is the n×n top-left block of wide-C's j-th
    // ne-column slice. Each output column accumulated the same ordered f32
    // sum a width-1 run would have, so the scatter is bitwise-faithful to
    // sequential execution.
    let kernel_each = kernel_s / plan.width as f64;
    let mut resps = Vec::with_capacity(k);
    for (j, job) in jobs.iter().enumerate() {
        let req = job.req;
        let mut c = Mat::zeros(n, n);
        for i in 0..n {
            c.row_mut(i).copy_from_slice(&ws.c_stack.row(i)[j * ne..j * ne + n]);
        }
        let verified = if req.verify {
            let oracle = a.matmul(&req.b);
            Some(c.allclose(&oracle, 1e-3, 1e-2))
        } else {
            None
        };
        resps.push(SpdmResponse {
            id: req.id,
            algo: plan.algo,
            artifact: artifact.clone(),
            n_exec: ne,
            // The batch's one conversion (stats scan included) is billed to
            // its first job; the other k−1 ride it for free — they are the
            // conversions the amortized counter credits. Cached-operand
            // batches bill none: EO was paid at registration.
            convert_s: if j == 0 { convert_s } else { 0.0 },
            kernel_s: kernel_each,
            total_s: job.enqueued.elapsed().as_secs_f64(),
            verified,
            error: None,
            c: Some(c),
            // Stacking B in and scattering C out are inherent to fusion and
            // billed per job; once-per-batch copies go to the first job.
            bytes_copied: b_bytes_each
                + (n * n * 4) as u64
                + if j == 0 { head_bytes } else { 0 },
            copies_avoided: if j == 0 { copy.copies_avoided } else { 0 },
            conversions: if j == 0 { conversions } else { 0 },
        });
    }
    resps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn padding_preserves_product() {
        // (pad A · pad B) trimmed == A · B — the identity the coordinator
        // relies on for odd request sizes.
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 6, &mut rng);
        let b = Mat::randn(6, 6, &mut rng);
        let c_direct = a.matmul(&b);
        let mut ws = Workspace::new();
        ws.a_pad.pad_from(&a, 8);
        ws.b_pad.pad_from(&b, 8);
        let c_padded = trim_mat(&ws.a_pad.matmul(&ws.b_pad), 6);
        assert!(c_direct.allclose(&c_padded, 1e-6, 1e-6));
    }

    #[test]
    fn submit_error_is_typed_and_displayable() {
        assert_eq!(SubmitError::ShutDown.to_string(), "coordinator is shut down");
    }

    /// Regression for the rows-only affinity bug: two different As with
    /// equal row counts must never share a fused batch. The old predicate
    /// (`h.req.a.rows == c.req.a.rows`) grouped them, which under fused
    /// execution would answer k−1 requests with the wrong A's product.
    #[test]
    fn different_a_same_rows_never_share_a_batch() {
        use super::super::job::ASig;
        let mut rng = Rng::new(31);
        let b = Mat::randn(16, 16, &mut rng);
        let a1 = Mat::randn(16, 16, &mut rng);
        let a2 = Mat::randn(16, 16, &mut rng);
        let mk = |id: u64, a: &Mat| SpdmRequest::new(id, a.clone(), b.clone());
        assert!(
            !batch_affine(&mk(0, &a1), &mk(1, &a2)),
            "equal row counts must not imply batch affinity"
        );
        assert!(batch_affine(&mk(0, &a1), &mk(1, &a1)));
        // A hint mismatch blocks fusion even with identical A.
        let mut hinted = mk(2, &a1);
        hinted.algo_hint = Some(Algo::Csr);
        assert!(!batch_affine(&mk(0, &a1), &hinted));
        // Through the queue: interleaved a1/a2 jobs dequeue as pure batches.
        let q = BoundedQueue::new(8);
        for (i, &a) in [&a1, &a2, &a1, &a2, &a1].iter().enumerate() {
            assert!(q.try_push(mk(i as u64, a)).is_ok());
        }
        q.close();
        let sig1 = ASig::of(&a1);
        let mut widths = Vec::new();
        while let Some(batch) = q.pop_batch(8, |h, c| batch_affine(h, c)) {
            let first = batch[0].a_sig;
            assert!(batch.iter().all(|r| r.a_sig == first), "mixed As fused into one batch");
            widths.push((first == sig1, batch.len()));
        }
        assert_eq!(widths, vec![(true, 3), (false, 2)]);
    }

    /// Handle requests batch on operand identity: equal handles fuse
    /// without any content comparison, distinct handles never do, and an
    /// unresolved handle's placeholder signature cannot alias inline
    /// traffic.
    #[test]
    fn handle_requests_batch_on_operand_id() {
        use super::super::store::OperandId;
        let b = Mat::zeros(4, 4);
        let h1 = SpdmRequest::for_handle(1, OperandId(7), b.clone());
        let h2 = SpdmRequest::for_handle(2, OperandId(7), b.clone());
        let h3 = SpdmRequest::for_handle(3, OperandId(8), b.clone());
        assert!(batch_affine(&h1, &h2), "equal handles fuse");
        assert!(!batch_affine(&h1, &h3), "distinct handles never fuse");
        let mut hinted = SpdmRequest::for_handle(4, OperandId(7), b.clone());
        hinted.algo_hint = Some(Algo::Csr);
        assert!(!batch_affine(&h1, &hinted), "hint mismatch blocks fusion");
        let inline = SpdmRequest::new(5, Mat::zeros(4, 4), b);
        assert!(
            !batch_affine(&h1, &inline),
            "unresolved placeholder sig must not alias inline content"
        );
    }

    // Full coordinator round trips (needing PJRT + artifacts) are in
    // rust/tests/coordinator_integration.rs; zero-copy counter assertions
    // are in rust/tests/zero_copy.rs; batched-vs-sequential differential
    // coverage is in rust/tests/batch_differential.rs; handle-vs-inline
    // differential + store lifecycle coverage is in
    // rust/tests/handle_api.rs.
}
