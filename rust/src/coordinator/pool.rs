//! The coordinator proper: worker pool over the bounded queue, executing
//! requests on per-worker engines according to the selector's plan.
//!
//! Request lifecycle (the zero-copy pipeline):
//!   submit → queue (backpressure) → batch dequeue (shape affinity) →
//!   **fused stats scan** (sparsity + max row nnz + band nnz, one pass) →
//!   **plan** (algo + artifact + n_exec + cap resolved before any
//!   conversion) → convert A **once**, directly into the worker's
//!   workspace slabs at the artifact's capacity (EO) → execute on borrowed
//!   slabs (KC; matching-cap = zero slab copies) → optional verification
//!   vs the CPU oracle → trim (or move, when sizes match) → reply +
//!   metrics (including the bytes-copied / copies-avoided pair).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::job::{Algo, SpdmRequest, SpdmResponse};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::selector::{Selector, SelectorPolicy};
use super::workspace::Workspace;
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{Engine, Registry};
use crate::sparse::{EllSlabs, GcooSlabs};

/// Coordinator tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub queue_cap: usize,
    /// Max jobs one worker claims per batch (shape-affine).
    pub batch_max: usize,
    pub policy: SelectorPolicy,
    /// Band height used for conversions (must match exported artifacts).
    pub gcoo_p: usize,
    /// Threads used inside one conversion.
    pub convert_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_cap: 64,
            batch_max: 8,
            policy: SelectorPolicy::default(),
            gcoo_p: 8,
            convert_threads: 4,
        }
    }
}

/// Typed submission failure — the coordinator refusing a request is an
/// expected condition (shutdown race), not a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator's queue is closed (shutdown started or completed).
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    req: SpdmRequest,
    enqueued: Instant,
    reply: mpsc::Sender<SpdmResponse>,
}

/// The serving coordinator.
///
/// **Each worker owns a full engine, compile cache, and workspace arena** —
/// the per-worker device-context pattern of GPU serving stacks (under PJRT
/// the client handles are `!Send`, so sharing one engine across threads is
/// not an option; the substrate engine keeps the same ownership shape, and
/// the workspace must never be shared — see `workspace.rs`). The batcher
/// keeps shape-affine jobs on one worker so per-worker compile caches and
/// arena buffers stay hot at one geometry.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Job>>,
    metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(registry: Arc<Registry>, cfg: CoordinatorConfig) -> Self {
        let queue = Arc::new(BoundedQueue::<Job>::new(cfg.queue_cap));
        let metrics = Arc::new(Metrics::new());
        let handles = (0..cfg.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let registry = Arc::clone(&registry);
                std::thread::Builder::new()
                    .name(format!("coordinator-{w}"))
                    .spawn(move || {
                        // Per-worker PJRT engine (see struct docs).
                        let engine = match Engine::new() {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every job this worker would take.
                                while let Some(batch) = queue.pop_batch(1, |_, _| false) {
                                    for job in batch {
                                        metrics.record_error();
                                        let _ = job.reply.send(SpdmResponse::failed(
                                            job.req.id,
                                            Algo::DenseXla,
                                            format!("engine init failed: {e}"),
                                        ));
                                    }
                                }
                                return;
                            }
                        };
                        // Per-worker workspace arena, owned next to the
                        // engine: reused across this worker's requests,
                        // never shared (workspace.rs ownership rule).
                        let mut ws = Workspace::new();
                        // Batch by matching request dimension: jobs padded to
                        // the same artifact stay on one warm executable.
                        while let Some(batch) = queue
                            .pop_batch(cfg.batch_max, |h, c| h.req.a.rows == c.req.a.rows)
                        {
                            for job in batch {
                                let resp = process_one_ws(
                                    &engine, &mut ws, &registry, &cfg, &job.req, job.enqueued,
                                );
                                if resp.ok() {
                                    metrics.record_completion(
                                        resp.algo.as_str(),
                                        resp.total_s,
                                        resp.kernel_s,
                                        resp.convert_s,
                                    );
                                    metrics.record_copy_traffic(
                                        resp.bytes_copied,
                                        resp.copies_avoided,
                                    );
                                    if resp.verified == Some(false) {
                                        metrics.record_verify_failure();
                                    }
                                } else {
                                    metrics.record_error();
                                }
                                let _ = job.reply.send(resp);
                            }
                        }
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        Coordinator { queue, metrics, handles }
    }

    /// Enqueue a request; the receiver yields the response when done.
    /// Blocks when the queue is full (backpressure). Returns
    /// [`SubmitError::ShutDown`] instead of panicking when racing shutdown.
    pub fn submit(&self, req: SpdmRequest) -> Result<mpsc::Receiver<SpdmResponse>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        // Count before pushing so `submitted >= completed` always holds in
        // snapshots; undo on rejection.
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if !self.queue.push(Job { req, enqueued: Instant::now(), reply: tx }) {
            self.metrics.submitted.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            return Err(SubmitError::ShutDown);
        }
        Ok(rx)
    }

    /// Submit and wait. Never panics: shutdown races and dropped reply
    /// channels come back as failed responses (which `serve` maps to JSON
    /// error replies).
    pub fn run_sync(&self, req: SpdmRequest) -> SpdmResponse {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                SpdmResponse::failed(id, Algo::DenseXla, "worker dropped reply channel".into())
            }),
            Err(e) => SpdmResponse::failed(id, Algo::DenseXla, e.to_string()),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Trim an m×m result back to n×n (fresh allocation: the trimmed matrix is
/// the caller-owned response payload).
fn trim_mat(c: &Mat, n: usize) -> Mat {
    let mut out = Mat::zeros(0, 0);
    out.trim_from(c, n);
    out
}

/// Execute one request end to end with a throwaway workspace — the
/// CLI/one-shot entry point. Serving workers use [`process_one_ws`] with
/// their per-worker arena.
pub fn process_one(
    engine: &Engine,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let mut ws = Workspace::new();
    process_one_ws(engine, &mut ws, registry, cfg, req, enqueued)
}

/// Execute one request through the zero-copy pipeline: one fused stats
/// scan, one plan (resolved before any conversion), **at most one
/// conversion of A on every path** (directly into the workspace's device
/// slabs), and zero slab copies when the planned capacity matches the
/// artifact — which the plan guarantees by construction.
pub fn process_one_ws(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let n = req.a.rows;
    if req.a.cols != n || req.b.rows != n || req.b.cols != n {
        return SpdmResponse::failed(
            req.id,
            Algo::DenseXla,
            format!("non-square or mismatched shapes: A {}x{}, B {}x{}", req.a.rows, req.a.cols, req.b.rows, req.b.cols),
        );
    }

    // --- fused stats scan: sparsity + max row nnz + band nnz, one pass ---
    // (This is also Algorithm 1's counting pass: the scatter below reuses
    // the band counts, so conversion never re-scans A for sizes. Its time
    // is billed into convert_s on the sparse paths only — there it
    // replaces the counting pass that pre-refactor conversion timed
    // itself, keeping EO comparable; dense requests convert nothing, as
    // before.)
    let t_stats = Instant::now();
    let stats = convert::scan_stats(&req.a, cfg.gcoo_p, cfg.convert_threads);
    let stats_s = t_stats.elapsed().as_secs_f64();
    let sparsity = stats.sparsity();

    // --- plan once, before any conversion ---
    let selector = Selector::new(cfg.policy);
    let plan = match selector.plan(
        registry,
        n,
        sparsity,
        stats.max_band_nnz(),
        stats.max_row_nnz,
        req.algo_hint,
    ) {
        Ok(p) => p,
        Err(e) => {
            return SpdmResponse::failed(req.id, req.algo_hint.unwrap_or(Algo::DenseXla), e)
        }
    };

    let mut bytes_copied = 0u64;
    let mut copies_avoided = 0u64;
    let mut convert_s = 0.0;

    // B: borrow the request's matrix when it is already at the execution
    // size; otherwise pad into the arena (no fresh allocation steady-state).
    let b_exec: &Mat = if req.b.rows == plan.n_exec && req.b.cols == plan.n_exec {
        copies_avoided += 1;
        &req.b
    } else {
        ws.b_pad.pad_from(&req.b, plan.n_exec);
        bytes_copied += (req.b.rows * req.b.cols * 4) as u64;
        &ws.b_pad
    };

    let exec = match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            // The one conversion of A: scatter straight into device slabs
            // at the planned capacity (timed: the paper's EO). Padded A is
            // never materialized.
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_slabs_into(
                &req.a,
                &stats,
                plan.n_exec,
                plan.cap,
                cfg.convert_threads,
                &mut ws.gcoo_vals,
                &mut ws.gcoo_rows,
                &mut ws.gcoo_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = GcooSlabs {
                g: plan.n_exec.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: plan.n_exec,
                vals: &ws.gcoo_vals,
                rows: &ws.gcoo_rows,
                cols: &ws.gcoo_cols,
            };
            engine.run_gcoo_slabs(registry, slabs, b_exec, plan.algo == Algo::Gcoo)
        }
        Algo::Csr => {
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_ell_into(
                &req.a,
                plan.n_exec,
                plan.cap,
                &mut ws.ell_vals,
                &mut ws.ell_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = EllSlabs {
                n: plan.n_exec,
                rowcap: plan.cap,
                vals: &ws.ell_vals,
                cols: &ws.ell_cols,
            };
            engine.run_ell_slabs(registry, slabs, b_exec)
        }
        Algo::DenseXla | Algo::DensePallas => {
            let t0 = Instant::now();
            let a_exec: &Mat = if n == plan.n_exec {
                copies_avoided += 1;
                &req.a
            } else {
                ws.a_pad.pad_from(&req.a, plan.n_exec);
                bytes_copied += (n * n * 4) as u64;
                &ws.a_pad
            };
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_dense(registry, plan.algo.as_str(), a_exec, b_exec)
        }
    };

    let out = match exec {
        Ok(o) => o,
        Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
    };
    bytes_copied += out.copy.bytes_copied;
    copies_avoided += out.copy.copies_avoided;
    // Move the result out when it is already n×n; trim otherwise.
    let c = if out.c.rows == n && out.c.cols == n {
        copies_avoided += 1;
        out.c
    } else {
        bytes_copied += (n * n * 4) as u64;
        trim_mat(&out.c, n)
    };
    let verified = if req.verify {
        let oracle = req.a.matmul(&req.b);
        Some(c.allclose(&oracle, 1e-3, 1e-2))
    } else {
        None
    };
    SpdmResponse {
        id: req.id,
        algo: plan.algo,
        artifact: out.artifact,
        n_exec: plan.n_exec,
        convert_s,
        kernel_s: out.kernel_s,
        total_s: enqueued.elapsed().as_secs_f64(),
        verified,
        error: None,
        c: Some(c),
        bytes_copied,
        copies_avoided,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn padding_preserves_product() {
        // (pad A · pad B) trimmed == A · B — the identity the coordinator
        // relies on for odd request sizes.
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 6, &mut rng);
        let b = Mat::randn(6, 6, &mut rng);
        let c_direct = a.matmul(&b);
        let mut ws = Workspace::new();
        ws.a_pad.pad_from(&a, 8);
        ws.b_pad.pad_from(&b, 8);
        let c_padded = trim_mat(&ws.a_pad.matmul(&ws.b_pad), 6);
        assert!(c_direct.allclose(&c_padded, 1e-6, 1e-6));
    }

    #[test]
    fn submit_error_is_typed_and_displayable() {
        assert_eq!(SubmitError::ShutDown.to_string(), "coordinator is shut down");
    }

    // Full coordinator round trips (needing PJRT + artifacts) are in
    // rust/tests/coordinator_integration.rs; zero-copy counter assertions
    // are in rust/tests/zero_copy.rs.
}
