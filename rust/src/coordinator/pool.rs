//! The coordinator proper: worker pool over the bounded queue, executing
//! **fused shape-affine batches** on per-worker engines according to the
//! selector's plan.
//!
//! Request lifecycle (the zero-copy pipeline, batch-fused):
//!   submit (A-signature computed) → queue (backpressure) → batch dequeue
//!   keyed on [`batch_affine`] (equal `ASig` + equal algo hint, so the
//!   batch provably shares one A) → **one fused stats scan** and **one
//!   plan** for the whole batch → convert A **once** into the worker's
//!   workspace slabs (EO, amortized over the batch) → stack the batch's B
//!   operands column-wise into one wide `n_exec × width·n_exec` matrix →
//!   **one wide kernel** (KC; matching-cap = zero slab copies) → scatter
//!   the C column blocks back per request → optional verification vs the
//!   CPU oracle → reply + metrics (copy counters, batch-width histogram,
//!   conversions amortized). Width-1 batches take [`process_one_ws`], the
//!   sequential special case the differential suite compares against.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::job::{Algo, SpdmRequest, SpdmResponse};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::selector::{Selector, SelectorPolicy};
use super::workspace::Workspace;
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{Engine, Registry};
use crate::sparse::{EllSlabs, GcooSlabs};

/// Coordinator tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub queue_cap: usize,
    /// Max jobs one worker claims per batch (shape-affine).
    pub batch_max: usize,
    pub policy: SelectorPolicy,
    /// Band height used for conversions (must match exported artifacts).
    pub gcoo_p: usize,
    /// Threads used inside one conversion.
    pub convert_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_cap: 64,
            batch_max: 8,
            policy: SelectorPolicy::default(),
            gcoo_p: 8,
            convert_threads: 4,
        }
    }
}

/// Typed submission failure — the coordinator refusing a request is an
/// expected condition (shutdown race), not a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator's queue is closed (shutdown started or completed).
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ShutDown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Job {
    req: SpdmRequest,
    enqueued: Instant,
    reply: mpsc::Sender<SpdmResponse>,
}

/// The serving coordinator.
///
/// **Each worker owns a full engine, compile cache, and workspace arena** —
/// the per-worker device-context pattern of GPU serving stacks (under PJRT
/// the client handles are `!Send`, so sharing one engine across threads is
/// not an option; the substrate engine keeps the same ownership shape, and
/// the workspace must never be shared — see `workspace.rs`). The batcher
/// keeps signature-affine jobs (one shared A) on one worker, which then
/// executes each batch fused — one A conversion, one wide kernel — while
/// per-worker compile caches and arena buffers stay hot at one geometry.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Job>>,
    metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(registry: Arc<Registry>, cfg: CoordinatorConfig) -> Self {
        let queue = Arc::new(BoundedQueue::<Job>::new(cfg.queue_cap));
        let metrics = Arc::new(Metrics::new());
        let handles = (0..cfg.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let registry = Arc::clone(&registry);
                std::thread::Builder::new()
                    .name(format!("coordinator-{w}"))
                    .spawn(move || {
                        // Per-worker PJRT engine (see struct docs).
                        let engine = match Engine::new() {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every job this worker would take.
                                while let Some(batch) = queue.pop_batch(1, |_, _| false) {
                                    for job in batch {
                                        metrics.record_error();
                                        let _ = job.reply.send(SpdmResponse::failed(
                                            job.req.id,
                                            Algo::DenseXla,
                                            format!("engine init failed: {e}"),
                                        ));
                                    }
                                }
                                return;
                            }
                        };
                        // Per-worker workspace arena, owned next to the
                        // engine: reused across this worker's requests,
                        // never shared (workspace.rs ownership rule).
                        let mut ws = Workspace::new();
                        // Batch by A-signature (not rows: equal dimensions
                        // alone would fuse different As — the regression
                        // the signature key exists to prevent). A batch
                        // shares one A, so the worker converts once and
                        // runs one wide kernel over the stacked Bs.
                        while let Some(batch) = queue
                            .pop_batch(cfg.batch_max, |h, c| batch_affine(&h.req, &c.req))
                        {
                            metrics.record_batch(batch.len());
                            let jobs: Vec<(&SpdmRequest, Instant)> =
                                batch.iter().map(|j| (&j.req, j.enqueued)).collect();
                            let resps =
                                process_batch_ws(&engine, &mut ws, &registry, &cfg, &jobs);
                            drop(jobs);
                            for (job, resp) in batch.iter().zip(resps) {
                                if resp.ok() {
                                    metrics.record_completion(
                                        resp.algo.as_str(),
                                        resp.total_s,
                                        resp.kernel_s,
                                        resp.convert_s,
                                    );
                                    metrics.record_copy_traffic(
                                        resp.bytes_copied,
                                        resp.copies_avoided,
                                    );
                                    if resp.verified == Some(false) {
                                        metrics.record_verify_failure();
                                    }
                                } else {
                                    metrics.record_error();
                                }
                                let _ = job.reply.send(resp);
                            }
                        }
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        Coordinator { queue, metrics, handles }
    }

    /// Enqueue a request; the receiver yields the response when done.
    /// Blocks when the queue is full (backpressure). Returns
    /// [`SubmitError::ShutDown`] instead of panicking when racing shutdown.
    pub fn submit(&self, req: SpdmRequest) -> Result<mpsc::Receiver<SpdmResponse>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        // Count before pushing so `submitted >= completed` always holds in
        // snapshots; undo on rejection.
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if !self.queue.push(Job { req, enqueued: Instant::now(), reply: tx }) {
            self.metrics.submitted.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
            return Err(SubmitError::ShutDown);
        }
        Ok(rx)
    }

    /// Submit and wait. Never panics: shutdown races and dropped reply
    /// channels come back as failed responses (which `serve` maps to JSON
    /// error replies).
    pub fn run_sync(&self, req: SpdmRequest) -> SpdmResponse {
        let id = req.id;
        match self.submit(req) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                SpdmResponse::failed(id, Algo::DenseXla, "worker dropped reply channel".into())
            }),
            Err(e) => SpdmResponse::failed(id, Algo::DenseXla, e.to_string()),
        }
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Batch-affinity predicate: two requests may share a fused batch only if
/// their submit-time signatures ([`crate::coordinator::ASig`]: dims + nnz
/// + content hash) are equal and they agree on the algorithm hint, so one
/// plan covers the whole batch. Rows-only matching is NOT sufficient: it
/// would fuse different As and silently answer k−1 requests with the
/// wrong product. The hash is the cheap dequeue key, not the proof —
/// [`process_batch_ws`] re-screens with a full element-data comparison
/// before fusing, so even a constructed hash collision cannot cross-wire
/// results.
pub fn batch_affine(a: &SpdmRequest, b: &SpdmRequest) -> bool {
    a.a_sig == b.a_sig && a.algo_hint == b.algo_hint
}

/// Trim an m×m result back to n×n (fresh allocation: the trimmed matrix is
/// the caller-owned response payload).
fn trim_mat(c: &Mat, n: usize) -> Mat {
    let mut out = Mat::zeros(0, 0);
    out.trim_from(c, n);
    out
}

/// Execute one request end to end with a throwaway workspace — the
/// CLI/one-shot entry point. Serving workers use [`process_one_ws`] with
/// their per-worker arena.
pub fn process_one(
    engine: &Engine,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let mut ws = Workspace::new();
    process_one_ws(engine, &mut ws, registry, cfg, req, enqueued)
}

/// Execute one request through the zero-copy pipeline: one fused stats
/// scan, one plan (resolved before any conversion), **at most one
/// conversion of A on every path** (directly into the workspace's device
/// slabs), and zero slab copies when the planned capacity matches the
/// artifact — which the plan guarantees by construction.
pub fn process_one_ws(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let n = req.a.rows;
    if req.a.cols != n || req.b.rows != n || req.b.cols != n {
        return SpdmResponse::failed(
            req.id,
            Algo::DenseXla,
            format!("non-square or mismatched shapes: A {}x{}, B {}x{}", req.a.rows, req.a.cols, req.b.rows, req.b.cols),
        );
    }

    // --- fused stats scan: sparsity + max row nnz + band nnz, one pass ---
    // (This is also Algorithm 1's counting pass: the scatter below reuses
    // the band counts, so conversion never re-scans A for sizes. Its time
    // is billed into convert_s on the sparse paths only — there it
    // replaces the counting pass that pre-refactor conversion timed
    // itself, keeping EO comparable; dense requests convert nothing, as
    // before.)
    let t_stats = Instant::now();
    let stats = convert::scan_stats(&req.a, cfg.gcoo_p, cfg.convert_threads);
    let stats_s = t_stats.elapsed().as_secs_f64();
    let sparsity = stats.sparsity();

    // --- plan once, before any conversion ---
    let selector = Selector::new(cfg.policy);
    let plan = match selector.plan(
        registry,
        n,
        sparsity,
        stats.max_band_nnz(),
        stats.max_row_nnz,
        req.algo_hint,
    ) {
        Ok(p) => p,
        Err(e) => {
            return SpdmResponse::failed(req.id, req.algo_hint.unwrap_or(Algo::DenseXla), e)
        }
    };

    let mut bytes_copied = 0u64;
    let mut copies_avoided = 0u64;
    let mut convert_s = 0.0;

    // B: borrow the request's matrix when it is already at the execution
    // size; otherwise pad into the arena (no fresh allocation steady-state).
    let b_exec: &Mat = if req.b.rows == plan.n_exec && req.b.cols == plan.n_exec {
        copies_avoided += 1;
        &req.b
    } else {
        ws.b_pad.pad_from(&req.b, plan.n_exec);
        bytes_copied += (req.b.rows * req.b.cols * 4) as u64;
        &ws.b_pad
    };

    let exec = match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            // The one conversion of A: scatter straight into device slabs
            // at the planned capacity (timed: the paper's EO). Padded A is
            // never materialized.
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_slabs_into(
                &req.a,
                &stats,
                plan.n_exec,
                plan.cap,
                cfg.convert_threads,
                &mut ws.gcoo_vals,
                &mut ws.gcoo_rows,
                &mut ws.gcoo_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = GcooSlabs {
                g: plan.n_exec.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: plan.n_exec,
                vals: &ws.gcoo_vals,
                rows: &ws.gcoo_rows,
                cols: &ws.gcoo_cols,
            };
            engine.run_gcoo_slabs(registry, slabs, b_exec, plan.algo == Algo::Gcoo)
        }
        Algo::Csr => {
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_ell_into(
                &req.a,
                plan.n_exec,
                plan.cap,
                &mut ws.ell_vals,
                &mut ws.ell_cols,
            ) {
                return SpdmResponse::failed(req.id, plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = EllSlabs {
                n: plan.n_exec,
                rowcap: plan.cap,
                vals: &ws.ell_vals,
                cols: &ws.ell_cols,
            };
            engine.run_ell_slabs(registry, slabs, b_exec)
        }
        Algo::DenseXla | Algo::DensePallas => {
            let t0 = Instant::now();
            let a_exec: &Mat = if n == plan.n_exec {
                copies_avoided += 1;
                &req.a
            } else {
                ws.a_pad.pad_from(&req.a, plan.n_exec);
                bytes_copied += (n * n * 4) as u64;
                &ws.a_pad
            };
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_dense(registry, plan.algo.as_str(), a_exec, b_exec)
        }
    };

    let out = match exec {
        Ok(o) => o,
        Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
    };
    bytes_copied += out.copy.bytes_copied;
    copies_avoided += out.copy.copies_avoided;
    // Move the result out when it is already n×n; trim otherwise.
    let c = if out.c.rows == n && out.c.cols == n {
        copies_avoided += 1;
        out.c
    } else {
        bytes_copied += (n * n * 4) as u64;
        trim_mat(&out.c, n)
    };
    let verified = if req.verify {
        let oracle = req.a.matmul(&req.b);
        Some(c.allclose(&oracle, 1e-3, 1e-2))
    } else {
        None
    };
    SpdmResponse {
        id: req.id,
        algo: plan.algo,
        artifact: out.artifact,
        n_exec: plan.n_exec,
        convert_s,
        kernel_s: out.kernel_s,
        total_s: enqueued.elapsed().as_secs_f64(),
        verified,
        error: None,
        c: Some(c),
        bytes_copied,
        copies_avoided,
    }
}

/// Execute one shape-affine batch as a fused unit: convert the shared A
/// **once**, stack the batch's B operands column-wise into one wide dense
/// matrix, run **one** wide kernel, and scatter the C column blocks back
/// into per-request responses (input order preserved).
///
/// Width 1 is the sequential special case ([`process_one_ws`]). The queue
/// predicate ([`batch_affine`]) guarantees affinity, but this function is
/// public, so it re-screens defensively: any job whose A signature, shape,
/// or algorithm hint cannot join the fused unit is processed individually
/// instead of poisoning the batch.
pub fn process_batch_ws(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    batch: &[(&SpdmRequest, Instant)],
) -> Vec<SpdmResponse> {
    if batch.is_empty() {
        return Vec::new();
    }
    if batch.len() == 1 {
        let (req, enq) = batch[0];
        return vec![process_one_ws(engine, ws, registry, cfg, req, enq)];
    }
    let head = batch[0].0;
    let n = head.a.rows;
    let mut out: Vec<Option<SpdmResponse>> = batch.iter().map(|_| None).collect();
    let mut fused: Vec<usize> = Vec::new();
    for (i, (req, enq)) in batch.iter().enumerate() {
        // The signature is the cheap dequeue key; the re-screen compares the
        // actual element data (O(n²), dwarfed by the kernel) so fusion is
        // sound even against a constructed 64-bit hash collision — a
        // colliding request falls back to its own sequential execution.
        let fusable = req.a.rows == n
            && req.a.cols == n
            && req.b.rows == n
            && req.b.cols == n
            && req.a_sig == head.a_sig
            && req.algo_hint == head.algo_hint
            && req.a.data == head.a.data;
        if fusable {
            fused.push(i);
        } else {
            out[i] = Some(process_one_ws(engine, ws, registry, cfg, req, *enq));
        }
    }
    if fused.len() == 1 {
        let i = fused[0];
        out[i] = Some(process_one_ws(engine, ws, registry, cfg, batch[i].0, batch[i].1));
    } else if !fused.is_empty() {
        let jobs: Vec<(&SpdmRequest, Instant)> = fused.iter().map(|&i| batch[i]).collect();
        let resps = process_fused(engine, ws, registry, cfg, &jobs);
        for (&i, resp) in fused.iter().zip(resps) {
            out[i] = Some(resp);
        }
    }
    out.into_iter().map(|r| r.expect("every batch slot answered")).collect()
}

/// The fused execution core: all jobs share one square n×n A (equal
/// signatures) and one algorithm hint; `jobs.len() >= 2`.
fn process_fused(
    engine: &Engine,
    ws: &mut Workspace,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    jobs: &[(&SpdmRequest, Instant)],
) -> Vec<SpdmResponse> {
    let head = jobs[0].0;
    let n = head.a.rows;
    let k = jobs.len();
    let fail_all = |algo: Algo, msg: String| -> Vec<SpdmResponse> {
        jobs.iter().map(|(r, _)| SpdmResponse::failed(r.id, algo, msg.clone())).collect()
    };

    debug_assert!(jobs.iter().all(|(r, _)| r.a.data == head.a.data));

    // One fused stats scan and one plan for the whole batch.
    let t_stats = Instant::now();
    let stats = convert::scan_stats(&head.a, cfg.gcoo_p, cfg.convert_threads);
    let stats_s = t_stats.elapsed().as_secs_f64();
    let selector = Selector::new(cfg.policy);
    let mut plan = match selector.plan(
        registry,
        n,
        stats.sparsity(),
        stats.max_band_nnz(),
        stats.max_row_nnz,
        head.algo_hint,
    ) {
        Ok(p) => p,
        Err(e) => return fail_all(head.algo_hint.unwrap_or(Algo::DenseXla), e),
    };
    plan.width = k;
    let ne = plan.n_exec;

    // Stack the B operands column-wise: wide B = [B_0 | B_1 | … | B_{k−1}],
    // each block zero-padded from n to ne. Rows n..ne stay zero — A has no
    // entries in those columns, so they contribute nothing to any product.
    ws.b_stack.zero_into(ne, plan.width * ne);
    for (j, (req, _)) in jobs.iter().enumerate() {
        for i in 0..n {
            ws.b_stack.row_mut(i)[j * ne..j * ne + n].copy_from_slice(req.b.row(i));
        }
    }
    let b_bytes_each = (n * n * 4) as u64;

    // Same EO accounting as `process_one_ws`: the stats scan bills into
    // convert_s on the sparse paths only (dense converts nothing).
    let mut convert_s = 0.0;
    let mut head_bytes = 0u64; // once-per-batch copies (slab repad, dense A pad)
    let (kernel_s, artifact, copy) = match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            // The batch's one and only A conversion — the invariant the
            // differential suite asserts via convert_s/conversions_amortized.
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_slabs_into(
                &head.a,
                &stats,
                ne,
                plan.cap,
                cfg.convert_threads,
                &mut ws.gcoo_vals,
                &mut ws.gcoo_rows,
                &mut ws.gcoo_cols,
            ) {
                return fail_all(plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = GcooSlabs {
                g: ne.div_ceil(cfg.gcoo_p),
                cap: plan.cap,
                p: cfg.gcoo_p,
                n: ne,
                vals: &ws.gcoo_vals,
                rows: &ws.gcoo_rows,
                cols: &ws.gcoo_cols,
            };
            match engine.run_gcoo_slabs_into(
                registry,
                slabs,
                &ws.b_stack,
                plan.algo == Algo::Gcoo,
                &mut ws.c_stack,
            ) {
                Ok(s) => (s.kernel_s, s.artifact, s.copy),
                Err(e) => return fail_all(plan.algo, e.to_string()),
            }
        }
        Algo::Csr => {
            let t0 = Instant::now();
            if let Err(e) = convert::dense_to_ell_into(
                &head.a,
                ne,
                plan.cap,
                &mut ws.ell_vals,
                &mut ws.ell_cols,
            ) {
                return fail_all(plan.algo, e.to_string());
            }
            convert_s += stats_s + t0.elapsed().as_secs_f64();
            let slabs = EllSlabs {
                n: ne,
                rowcap: plan.cap,
                vals: &ws.ell_vals,
                cols: &ws.ell_cols,
            };
            match engine.run_ell_slabs_into(registry, slabs, &ws.b_stack, &mut ws.c_stack) {
                Ok(s) => (s.kernel_s, s.artifact, s.copy),
                Err(e) => return fail_all(plan.algo, e.to_string()),
            }
        }
        Algo::DenseXla | Algo::DensePallas => {
            let t0 = Instant::now();
            let a_exec: &Mat = if n == ne {
                &head.a
            } else {
                ws.a_pad.pad_from(&head.a, ne);
                head_bytes += (n * n * 4) as u64;
                &ws.a_pad
            };
            convert_s += t0.elapsed().as_secs_f64();
            match engine.run_dense(registry, plan.algo.as_str(), a_exec, &ws.b_stack) {
                Ok(o) => {
                    let (ks, art, cp) = (o.kernel_s, o.artifact, o.copy);
                    // Dense kernels return an owned wide C; stage it where
                    // the scatter reads (replaces the staging allocation).
                    ws.c_stack = o.c;
                    (ks, art, cp)
                }
                Err(e) => return fail_all(plan.algo, e.to_string()),
            }
        }
    };
    head_bytes += copy.bytes_copied;

    // Scatter: request j's C is the n×n top-left block of wide-C's j-th
    // ne-column slice. Each output column accumulated the same ordered f32
    // sum a width-1 run would have, so the scatter is bitwise-faithful to
    // sequential execution.
    let kernel_each = kernel_s / plan.width as f64;
    let mut resps = Vec::with_capacity(k);
    for (j, (req, enq)) in jobs.iter().enumerate() {
        let mut c = Mat::zeros(n, n);
        for i in 0..n {
            c.row_mut(i).copy_from_slice(&ws.c_stack.row(i)[j * ne..j * ne + n]);
        }
        let verified = if req.verify {
            let oracle = req.a.matmul(&req.b);
            Some(c.allclose(&oracle, 1e-3, 1e-2))
        } else {
            None
        };
        resps.push(SpdmResponse {
            id: req.id,
            algo: plan.algo,
            artifact: artifact.clone(),
            n_exec: ne,
            // The batch's one conversion (stats scan included) is billed to
            // its first job; the other k−1 ride it for free — they are the
            // conversions the amortized counter credits.
            convert_s: if j == 0 { convert_s } else { 0.0 },
            kernel_s: kernel_each,
            total_s: enq.elapsed().as_secs_f64(),
            verified,
            error: None,
            c: Some(c),
            // Stacking B in and scattering C out are inherent to fusion and
            // billed per job; once-per-batch copies go to the first job.
            bytes_copied: b_bytes_each
                + (n * n * 4) as u64
                + if j == 0 { head_bytes } else { 0 },
            copies_avoided: if j == 0 { copy.copies_avoided } else { 0 },
        });
    }
    resps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn padding_preserves_product() {
        // (pad A · pad B) trimmed == A · B — the identity the coordinator
        // relies on for odd request sizes.
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 6, &mut rng);
        let b = Mat::randn(6, 6, &mut rng);
        let c_direct = a.matmul(&b);
        let mut ws = Workspace::new();
        ws.a_pad.pad_from(&a, 8);
        ws.b_pad.pad_from(&b, 8);
        let c_padded = trim_mat(&ws.a_pad.matmul(&ws.b_pad), 6);
        assert!(c_direct.allclose(&c_padded, 1e-6, 1e-6));
    }

    #[test]
    fn submit_error_is_typed_and_displayable() {
        assert_eq!(SubmitError::ShutDown.to_string(), "coordinator is shut down");
    }

    /// Regression for the rows-only affinity bug: two different As with
    /// equal row counts must never share a fused batch. The old predicate
    /// (`h.req.a.rows == c.req.a.rows`) grouped them, which under fused
    /// execution would answer k−1 requests with the wrong A's product.
    #[test]
    fn different_a_same_rows_never_share_a_batch() {
        use super::super::job::ASig;
        let mut rng = Rng::new(31);
        let b = Mat::randn(16, 16, &mut rng);
        let a1 = Mat::randn(16, 16, &mut rng);
        let a2 = Mat::randn(16, 16, &mut rng);
        let mk = |id: u64, a: &Mat| SpdmRequest::new(id, a.clone(), b.clone());
        assert!(
            !batch_affine(&mk(0, &a1), &mk(1, &a2)),
            "equal row counts must not imply batch affinity"
        );
        assert!(batch_affine(&mk(0, &a1), &mk(1, &a1)));
        // A hint mismatch blocks fusion even with identical A.
        let mut hinted = mk(2, &a1);
        hinted.algo_hint = Some(Algo::Csr);
        assert!(!batch_affine(&mk(0, &a1), &hinted));
        // Through the queue: interleaved a1/a2 jobs dequeue as pure batches.
        let q = BoundedQueue::new(8);
        for (i, &a) in [&a1, &a2, &a1, &a2, &a1].iter().enumerate() {
            assert!(q.try_push(mk(i as u64, a)).is_ok());
        }
        q.close();
        let sig1 = ASig::of(&a1);
        let mut widths = Vec::new();
        while let Some(batch) = q.pop_batch(8, |h, c| batch_affine(h, c)) {
            let first = batch[0].a_sig;
            assert!(batch.iter().all(|r| r.a_sig == first), "mixed As fused into one batch");
            widths.push((first == sig1, batch.len()));
        }
        assert_eq!(widths, vec![(true, 3), (false, 2)]);
    }

    // Full coordinator round trips (needing PJRT + artifacts) are in
    // rust/tests/coordinator_integration.rs; zero-copy counter assertions
    // are in rust/tests/zero_copy.rs; batched-vs-sequential differential
    // coverage is in rust/tests/batch_differential.rs.
}
