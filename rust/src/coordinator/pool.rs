//! The coordinator proper: worker pool over the bounded queue, executing
//! requests on the shared PJRT engine according to the selector's plan.
//!
//! Request lifecycle:
//!   submit → queue (backpressure) → batch dequeue (shape affinity) →
//!   stats scan → [sparse path: timed GCOO/ELL conversion (EO)] →
//!   plan → pad to the artifact grid → PJRT execute (KC) →
//!   optional verification vs the CPU oracle → trim → reply + metrics.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use super::job::{Algo, SpdmRequest, SpdmResponse};
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::selector::{Selector, SelectorPolicy};
use crate::convert;
use crate::ndarray::Mat;
use crate::runtime::{Engine, Registry};
use crate::sparse::{Csr, Ell};

/// Coordinator tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub queue_cap: usize,
    /// Max jobs one worker claims per batch (shape-affine).
    pub batch_max: usize,
    pub policy: SelectorPolicy,
    /// Band height used for conversions (must match exported artifacts).
    pub gcoo_p: usize,
    /// Threads used inside one conversion.
    pub convert_threads: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_cap: 64,
            batch_max: 8,
            policy: SelectorPolicy::default(),
            gcoo_p: 8,
            convert_threads: 4,
        }
    }
}

struct Job {
    req: SpdmRequest,
    enqueued: Instant,
    reply: mpsc::Sender<SpdmResponse>,
}

/// The serving coordinator.
///
/// **Each worker owns a full engine and compile cache** — the per-worker
/// device-context pattern of GPU serving stacks (under PJRT the client
/// handles are `!Send`, so sharing one engine across threads is not an
/// option; the substrate engine keeps the same ownership shape). The batcher
/// keeps shape-affine jobs on one worker so per-worker compile caches stay
/// hot.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Job>>,
    metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new(registry: Arc<Registry>, cfg: CoordinatorConfig) -> Self {
        let queue = Arc::new(BoundedQueue::<Job>::new(cfg.queue_cap));
        let metrics = Arc::new(Metrics::new());
        let handles = (0..cfg.workers.max(1))
            .map(|w| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let registry = Arc::clone(&registry);
                std::thread::Builder::new()
                    .name(format!("coordinator-{w}"))
                    .spawn(move || {
                        // Per-worker PJRT engine (see struct docs).
                        let engine = match Engine::new() {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every job this worker would take.
                                while let Some(batch) = queue.pop_batch(1, |_, _| false) {
                                    for job in batch {
                                        metrics.record_error();
                                        let _ = job.reply.send(SpdmResponse::failed(
                                            job.req.id,
                                            Algo::DenseXla,
                                            format!("engine init failed: {e}"),
                                        ));
                                    }
                                }
                                return;
                            }
                        };
                        // Batch by matching request dimension: jobs padded to
                        // the same artifact stay on one warm executable.
                        while let Some(batch) = queue
                            .pop_batch(cfg.batch_max, |h, c| h.req.a.rows == c.req.a.rows)
                        {
                            for job in batch {
                                let resp =
                                    process_one(&engine, &registry, &cfg, &job.req, job.enqueued);
                                if resp.ok() {
                                    metrics.record_completion(
                                        resp.algo.as_str(),
                                        resp.total_s,
                                        resp.kernel_s,
                                        resp.convert_s,
                                    );
                                    if resp.verified == Some(false) {
                                        metrics
                                            .verify_failures
                                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                    }
                                } else {
                                    metrics.record_error();
                                }
                                let _ = job.reply.send(resp);
                            }
                        }
                    })
                    .expect("spawn coordinator worker")
            })
            .collect();
        Coordinator { queue, metrics, handles }
    }

    /// Enqueue a request; the receiver yields the response when done.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(&self, req: SpdmRequest) -> mpsc::Receiver<SpdmResponse> {
        let (tx, rx) = mpsc::channel();
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let accepted = self.queue.push(Job { req, enqueued: Instant::now(), reply: tx });
        assert!(accepted, "coordinator is shut down");
        rx
    }

    /// Submit and wait.
    pub fn run_sync(&self, req: SpdmRequest) -> SpdmResponse {
        self.submit(req).recv().expect("worker dropped reply channel")
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Zero-pad an n×n matrix to m×m (m ≥ n).
fn pad_mat(a: &Mat, m: usize) -> Mat {
    if a.rows == m && a.cols == m {
        return a.clone();
    }
    let mut out = Mat::zeros(m, m);
    for i in 0..a.rows {
        out.row_mut(i)[..a.cols].copy_from_slice(a.row(i));
    }
    out
}

/// Trim an m×m result back to n×n.
fn trim_mat(c: &Mat, n: usize) -> Mat {
    if c.rows == n && c.cols == n {
        return c.clone();
    }
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&c.row(i)[..n]);
    }
    out
}

/// Execute one request end to end (shared by workers and the CLI).
pub fn process_one(
    engine: &Engine,
    registry: &Registry,
    cfg: &CoordinatorConfig,
    req: &SpdmRequest,
    enqueued: Instant,
) -> SpdmResponse {
    let n = req.a.rows;
    if req.a.cols != n || req.b.rows != n || req.b.cols != n {
        return SpdmResponse::failed(
            req.id,
            Algo::DenseXla,
            format!("non-square or mismatched shapes: A {}x{}, B {}x{}", req.a.rows, req.a.cols, req.b.rows, req.b.cols),
        );
    }

    // --- stats scan: sparsity + max row nnz in one pass ---
    let mut nnz = 0usize;
    let mut max_row = 0usize;
    for i in 0..n {
        let rn = req.a.row(i).iter().filter(|v| **v != 0.0).count();
        nnz += rn;
        max_row = max_row.max(rn);
    }
    let sparsity = 1.0 - nnz as f64 / (n * n) as f64;

    // --- sparse-path conversion (timed: this is the paper's EO) ---
    let selector = Selector::new(cfg.policy);
    let want_sparse = req
        .algo_hint
        .map(|a| matches!(a, Algo::Gcoo | Algo::GcooNoreuse | Algo::Csr))
        .unwrap_or(sparsity >= cfg.policy.gcoo_crossover);

    let mut convert_s = 0.0;
    let (gcoo, max_band) = if want_sparse {
        let n_exec_guess = registry.fit_size("gcoo", n).unwrap_or(n);
        let a_pad = pad_mat(&req.a, n_exec_guess);
        let (g, timing) = convert::dense_to_gcoo_parallel(&a_pad, cfg.gcoo_p, cfg.convert_threads);
        convert_s += timing.eo();
        let mb = g.max_group_nnz();
        (Some(g), mb)
    } else {
        (None, 0)
    };

    let plan = match selector.plan(registry, n, sparsity, max_band, max_row, req.algo_hint) {
        Ok(p) => p,
        Err(e) => return SpdmResponse::failed(req.id, Algo::DenseXla, e),
    };

    let b_pad = pad_mat(&req.b, plan.n_exec);
    let exec = match plan.algo {
        Algo::Gcoo | Algo::GcooNoreuse => {
            let gcoo = match gcoo {
                Some(g) if g.n_rows == plan.n_exec => g,
                _ => {
                    let t0 = Instant::now();
                    let a_pad = pad_mat(&req.a, plan.n_exec);
                    let (g, _t) =
                        convert::dense_to_gcoo_parallel(&a_pad, cfg.gcoo_p, cfg.convert_threads);
                    convert_s += t0.elapsed().as_secs_f64();
                    g
                }
            };
            let t0 = Instant::now();
            let cap = match registry
                .select(plan.algo.as_str(), plan.n_exec, gcoo.max_group_nnz())
            {
                Ok(meta) => meta.param("cap").unwrap_or(gcoo.max_group_nnz()),
                Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
            };
            let padded = match gcoo.pad(cap) {
                Ok(p) => p,
                Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
            };
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_gcoo(registry, &padded, &b_pad, plan.algo == Algo::Gcoo)
        }
        Algo::Csr => {
            let t0 = Instant::now();
            let a_pad = pad_mat(&req.a, plan.n_exec);
            let csr = Csr::from_dense(&a_pad);
            let rowcap = match registry.select("csr", plan.n_exec, csr.max_row_nnz()) {
                Ok(meta) => meta.param("rowcap").unwrap_or(csr.max_row_nnz()),
                Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
            };
            let ell = match Ell::from_csr(&csr, rowcap) {
                Ok(e) => e,
                Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
            };
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_csr(registry, &ell, &b_pad)
        }
        Algo::DenseXla | Algo::DensePallas => {
            let t0 = Instant::now();
            let a_pad = pad_mat(&req.a, plan.n_exec);
            convert_s += t0.elapsed().as_secs_f64();
            engine.run_dense(registry, plan.algo.as_str(), &a_pad, &b_pad)
        }
    };

    let out = match exec {
        Ok(o) => o,
        Err(e) => return SpdmResponse::failed(req.id, plan.algo, e.to_string()),
    };
    let c = trim_mat(&out.c, n);
    let verified = if req.verify {
        let oracle = req.a.matmul(&req.b);
        Some(c.allclose(&oracle, 1e-3, 1e-2))
    } else {
        None
    };
    SpdmResponse {
        id: req.id,
        algo: plan.algo,
        artifact: out.artifact,
        n_exec: plan.n_exec,
        convert_s,
        kernel_s: out.kernel_s,
        total_s: enqueued.elapsed().as_secs_f64(),
        verified,
        error: None,
        c: Some(c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pad_and_trim_round_trip() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 5, &mut rng);
        let padded = pad_mat(&a, 8);
        assert_eq!(padded.rows, 8);
        assert_eq!(padded[(4, 4)], a[(4, 4)]);
        assert_eq!(padded[(7, 7)], 0.0);
        assert_eq!(trim_mat(&padded, 5), a);
    }

    #[test]
    fn pad_noop_when_sized() {
        let a = Mat::eye(4);
        assert_eq!(pad_mat(&a, 4), a);
    }

    #[test]
    fn padding_preserves_product() {
        // (pad A · pad B) trimmed == A · B — the identity the coordinator
        // relies on for odd request sizes.
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 6, &mut rng);
        let b = Mat::randn(6, 6, &mut rng);
        let c_direct = a.matmul(&b);
        let c_padded = trim_mat(&pad_mat(&a, 8).matmul(&pad_mat(&b, 8)), 6);
        assert!(c_direct.allclose(&c_padded, 1e-6, 1e-6));
    }

    // Full coordinator round trips (needing PJRT + artifacts) are in
    // rust/tests/coordinator_integration.rs.
}
