//! Property-testing substrate (the offline image has no proptest).
//!
//! A deliberately small core: a [`Gen`] wraps the repo RNG, properties are
//! closures over generated cases, and failures *shrink* by re-running the
//! case factory with progressively "smaller" size budgets. Shrinking here is
//! size-driven (halve the size knob and re-sample within the failing seed's
//! stream) rather than structural — simple, deterministic, and enough to
//! produce small counterexamples for the invariants we check (format
//! round-trips, scheduler properties, simulator monotonicity).

use crate::rng::Rng;

/// Test-case generator context: RNG + a size budget the case factory
/// should respect (bigger size ⇒ bigger structures).
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }
}

/// Failure report for a falsified property.
#[derive(Debug)]
pub struct Falsified {
    pub seed: u64,
    pub size: usize,
    pub case_debug: String,
    pub message: String,
}

impl std::fmt::Display for Falsified {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property falsified (seed={}, size={}): {}\ncase: {}",
            self.seed, self.size, self.message, self.case_debug
        )
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `property` over `cases` generated inputs; on failure, shrink by
/// halving the size budget while the property still fails, and panic with
/// the smallest found counterexample.
pub fn check<C: std::fmt::Debug>(
    cfg: Config,
    make_case: impl Fn(&mut Gen) -> C,
    property: impl Fn(&C) -> Result<(), String>,
) {
    for case_idx in 0..cfg.cases {
        let seed = cfg.base_seed ^ (case_idx as u64).wrapping_mul(0x9E37_79B9);
        // Size ramps up across cases so early failures are already small.
        let size = 1 + (cfg.max_size * (case_idx + 1)) / cfg.cases;
        if let Some(fail) = run_one(seed, size, &make_case, &property) {
            // Shrink: retry with smaller sizes on the same seed.
            let mut best = fail;
            let mut sz = size;
            while sz > 1 {
                sz /= 2;
                if let Some(smaller) = run_one(seed, sz, &make_case, &property) {
                    best = smaller;
                }
            }
            panic!("{best}");
        }
    }
}

fn run_one<C: std::fmt::Debug>(
    seed: u64,
    size: usize,
    make_case: &impl Fn(&mut Gen) -> C,
    property: &impl Fn(&C) -> Result<(), String>,
) -> Option<Falsified> {
    let mut g = Gen { rng: Rng::new(seed), size };
    let case = make_case(&mut g);
    match property(&case) {
        Ok(()) => None,
        Err(message) => Some(Falsified {
            seed,
            size,
            case_debug: format!("{case:?}"),
            message,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config { cases: 32, ..Default::default() },
            |g| g.usize_in(0, g.size),
            |&x| {
                if x <= 64 + 1 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics_with_report() {
        check(
            Config { cases: 16, ..Default::default() },
            |g| g.usize_in(0, g.size),
            |&x| if x < 2 { Ok(()) } else { Err("x >= 2".into()) },
        );
    }

    #[test]
    fn shrinking_reports_small_case() {
        // Capture the panic and verify the reported size shrank below max.
        let result = std::panic::catch_unwind(|| {
            check(
                Config { cases: 8, max_size: 64, base_seed: 7 },
                |g| g.usize_in(0, g.size),
                |&x| if x == 0 { Ok(()) } else { Err("nonzero".into()) },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrunk size should be small (<= 8) for a property this easy to fail.
        let size: usize = msg
            .split("size=")
            .nth(1)
            .and_then(|s| s.split(')').next())
            .unwrap()
            .parse()
            .unwrap();
        assert!(size <= 8, "expected shrunk size, got {size}: {msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen { rng: Rng::new(1), size: 10 };
        for _ in 0..1000 {
            let x = g.usize_in(3, 9);
            assert!((3..=9).contains(&x));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn pick_only_returns_members() {
        let mut g = Gen { rng: Rng::new(2), size: 10 };
        let xs = [1, 5, 9];
        for _ in 0..100 {
            assert!(xs.contains(g.pick(&xs)));
        }
    }
}
