//! Deterministic pseudo-random number generation (substrate).
//!
//! The offline image has no `rand` crate, so we carry our own: SplitMix64
//! for seeding and xoshiro256** as the workhorse generator. Everything in
//! this repo that needs randomness (matrix generators, workload traces,
//! property tests) goes through [`Rng`], so every experiment is exactly
//! reproducible from its seed.

/// SplitMix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically; two `Rng::new(seed)` produce identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. per matrix in a corpus).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generators are not on any hot path that cares).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Nonzero value for sparse matrices: normal, with tiny magnitudes
    /// pushed away from 0 so "nonzero" stays nonzero through round trips
    /// (mirrors python ref.random_sparse).
    pub fn nonzero_value(&mut self) -> f32 {
        let v = self.normal() as f32;
        if v.abs() < 1e-3 {
            1.0
        } else {
            v
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn coin(&mut self, prob: f64) -> bool {
        self.next_f64() < prob
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n: rejection; else shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.index(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out.sort_unstable();
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn range_inclusive_endpoints_reachable() {
        let mut r = Rng::new(5);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range_i64(-2, 2) {
                -2 => lo_seen = true,
                2 => hi_seen = true,
                x => assert!((-2..=2).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (1, 1), (50, 0)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            assert!(idx.windows(2).all(|w| w[0] < w[1]));
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(23);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn nonzero_value_never_tiny() {
        let mut r = Rng::new(29);
        for _ in 0..10_000 {
            assert!(r.nonzero_value().abs() >= 1e-3);
        }
    }
}
