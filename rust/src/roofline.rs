//! Roofline model (paper Fig 1 and §II-A).
//!
//! attainable(r) = min(peak_flops, r × dram_bandwidth): below the ridge
//! point the kernel is memory-bound and throughput grows linearly in the
//! operational intensity r; above it the kernel is compute-bound.

use crate::simgpu::{DeviceConfig, WalkConfig, simulate_dense};

/// Attainable GFLOPS at operational intensity `r` (FLOPs/byte).
pub fn attainable_gflops(dev: &DeviceConfig, r: f64) -> f64 {
    (dev.peak_flops().min(r * dev.dram_bw())) / 1e9
}

/// Ridge point: the intensity where the kernel turns compute-bound.
pub fn ridge_point(dev: &DeviceConfig) -> f64 {
    dev.peak_flops() / dev.dram_bw()
}

/// One point of the Fig-1 "cuBLAS measured" curve: simulate the dense GEMM
/// at size n and report (r, achieved GFLOPS).
pub fn gemm_point(dev: &DeviceConfig, n: usize) -> (f64, f64) {
    let rep = simulate_dense(n, dev, &WalkConfig::default());
    let r = crate::simgpu::estimate_r(&rep);
    let gflops = rep.flops as f64 / rep.time_s() / 1e9;
    (r, gflops)
}

/// The theoretical curve sampled log-uniformly over [r_lo, r_hi].
pub fn theoretical_curve(dev: &DeviceConfig, r_lo: f64, r_hi: f64, points: usize) -> Vec<(f64, f64)> {
    (0..points)
        .map(|i| {
            let t = i as f64 / (points - 1).max(1) as f64;
            let r = r_lo * (r_hi / r_lo).powf(t);
            (r, attainable_gflops(dev, r))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::{GTX980, TITANX};

    #[test]
    fn memory_bound_region_linear() {
        let r = ridge_point(&TITANX);
        let y1 = attainable_gflops(&TITANX, r / 8.0);
        let y2 = attainable_gflops(&TITANX, r / 4.0);
        assert!((y2 / y1 - 2.0).abs() < 1e-9, "linear below ridge");
    }

    #[test]
    fn compute_bound_region_flat() {
        let r = ridge_point(&GTX980);
        let y1 = attainable_gflops(&GTX980, r * 2.0);
        let y2 = attainable_gflops(&GTX980, r * 20.0);
        assert_eq!(y1, y2);
        assert!((y1 - GTX980.peak_tflops * 1e3).abs() < 1e-6);
    }

    #[test]
    fn ridge_points_match_table2() {
        // GTX980: 4981/224 ≈ 22.2 FLOPs/byte; TitanX: 10970/433 ≈ 25.3.
        assert!((ridge_point(&GTX980) - 4.981e12 / 224e9).abs() < 1e-9);
        assert!(ridge_point(&TITANX) > ridge_point(&GTX980));
    }

    #[test]
    fn gemm_sits_near_but_under_roof() {
        let (r, gflops) = gemm_point(&TITANX, 2048);
        let roof = attainable_gflops(&TITANX, r);
        assert!(gflops <= roof * 1.001, "measured {gflops} exceeds roof {roof}");
        assert!(gflops > 0.2 * roof, "GEMM should be within 5x of the roof");
    }

    #[test]
    fn theoretical_curve_monotone() {
        let pts = theoretical_curve(&TITANX, 0.1, 100.0, 32);
        assert_eq!(pts.len(), 32);
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9);
        }
    }
}
