//! Thread-pool execution substrate (the offline image has no tokio).
//!
//! Two primitives cover every concurrency need in the repo:
//! * [`ThreadPool`] — fixed worker pool with a shared injector queue; used by
//!   the coordinator's worker loop and the serving accept loop.
//! * [`scoped_for`] — data-parallel fork/join over an index range via
//!   `std::thread::scope`; used by the parallel dense→GCOO conversion
//!   (paper Algorithm 1) and the corpus generators.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    idle: Condvar,
}

/// Fixed-size thread pool with graceful shutdown and `wait_idle`.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gcoospdm-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (at least 2).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n.max(2))
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job. Panics if the pool is shut down.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(job));
        }
        self.shared.available.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0 {
            q = self.shared.idle.wait(q).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if *shared.shutdown.lock().unwrap() {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        job();
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Possibly idle now; wake waiters (they re-check under the lock).
            let _q = shared.queue.lock().unwrap();
            shared.idle.notify_all();
        }
    }
}

/// Fork/join data parallelism: split `0..n` into ~`chunks` contiguous ranges
/// and run `f(range)` on scoped threads. `f` sees disjoint ranges, so callers
/// can hand out `&mut` slices split beforehand.
pub fn scoped_for<F>(n: usize, chunks: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let chunks = chunks.clamp(1, n);
    let chunk = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for start in (0..n).step_by(chunk) {
            let end = (start + chunk).min(n);
            let f = &f;
            s.spawn(move || f(start..end));
        }
    });
}

/// Parallel map over indices with collected results (order preserved).
pub fn par_map<T, F>(n: usize, chunks: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    // Split `out` into disjoint chunks and fill each on its own thread.
    if n == 0 {
        return out;
    }
    let chunks = chunks.clamp(1, n);
    let chunk = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for (ci, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = ci * chunk;
                for (i, slot) in slice.iter_mut().enumerate() {
                    *slot = f(base + i);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn pool_shutdown_joins_workers() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scoped_for_covers_every_index_once() {
        let n = 1003;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        scoped_for(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scoped_for_zero_is_noop() {
        scoped_for(0, 4, |_r| panic!("must not be called"));
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * i));
    }

    #[test]
    fn par_map_single_chunk() {
        assert_eq!(par_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }
}
