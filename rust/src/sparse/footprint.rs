//! Table I — memory consumption of the storage formats.
//!
//! The paper counts *elements* (indices and values weigh one unit each):
//!   CSR  = 2·nnz + n
//!   COO  = 3·nnz
//!   GCOO = 3·nnz + 2·⌊(n+p−1)/p⌋     (gIdxes + nnzPerGroup per group)
//! `FootprintBytes` additionally reports real bytes for f32 values / u32
//! indices, which is what the simulator's DRAM traffic model consumes.

/// Element counts per Table I.
pub fn coo_elements(nnz: usize) -> usize {
    3 * nnz
}

pub fn csr_elements(nnz: usize, n: usize) -> usize {
    2 * nnz + n
}

pub fn gcoo_elements(nnz: usize, n: usize, p: usize) -> usize {
    3 * nnz + 2 * n.div_ceil(p)
}

/// Byte-level footprint (f32 values, u32 indices).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FootprintBytes {
    pub values: usize,
    pub indices: usize,
}

impl FootprintBytes {
    pub fn total(&self) -> usize {
        self.values + self.indices
    }
}

pub fn coo_bytes(nnz: usize) -> FootprintBytes {
    FootprintBytes { values: 4 * nnz, indices: 8 * nnz }
}

pub fn csr_bytes(nnz: usize, n: usize) -> FootprintBytes {
    FootprintBytes { values: 4 * nnz, indices: 4 * nnz + 4 * (n + 1) }
}

pub fn gcoo_bytes(nnz: usize, n: usize, p: usize) -> FootprintBytes {
    let groups = n.div_ceil(p);
    FootprintBytes { values: 4 * nnz, indices: 8 * nnz + 8 * groups }
}

pub fn dense_bytes(n: usize) -> FootprintBytes {
    FootprintBytes { values: 4 * n * n, indices: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_element_formulas() {
        let (nnz, n, p) = (1000, 100, 8);
        assert_eq!(coo_elements(nnz), 3000);
        assert_eq!(csr_elements(nnz, n), 2100);
        assert_eq!(gcoo_elements(nnz, n, p), 3000 + 2 * 13);
    }

    #[test]
    fn gcoo_overhead_vs_coo_is_per_group_only() {
        // GCOO = COO + 2 elements per group, exactly as Table I states.
        for &(n, p) in &[(64usize, 8usize), (100, 7), (1, 1)] {
            let d = gcoo_elements(500, n, p) - coo_elements(500);
            assert_eq!(d, 2 * n.div_ceil(p));
        }
    }

    #[test]
    fn csr_beats_coo_in_elements_when_nnz_exceeds_n() {
        let (nnz, n) = (5000, 1000);
        assert!(csr_elements(nnz, n) < coo_elements(nnz));
    }

    #[test]
    fn byte_footprints_positive_and_ordered() {
        let (nnz, n, p) = (10_000, 4000, 32);
        let coo = coo_bytes(nnz).total();
        let csr = csr_bytes(nnz, n).total();
        let gcoo = gcoo_bytes(nnz, n, p).total();
        assert!(csr < coo, "CSR should be smallest for nnz >> n");
        assert!(coo <= gcoo, "GCOO adds per-group overhead to COO");
        // sparse formats beat dense at this sparsity (nnz/n^2 ≈ 0.000625)
        assert!(gcoo < dense_bytes(n).total());
    }

    #[test]
    fn dense_crossover_in_bytes() {
        // At 1/3 density, COO (12 bytes/entry) equals dense (4 bytes/slot):
        // nnz = n^2/3 ⇒ 12·nnz = 4·n². Below that density sparse wins.
        let n = 300;
        let nnz_eq = n * n / 3;
        assert_eq!(coo_bytes(nnz_eq).total(), dense_bytes(n).total());
        assert!(coo_bytes(nnz_eq - 100).total() < dense_bytes(n).total());
    }
}
