//! COO — the coordinate storage format (paper §II-C).

use super::{FormatError, ToDense};
use crate::ndarray::Mat;

/// Coordinate format: parallel `rows/cols/vals` arrays, row-major ordered
/// (sorted by (row, col)) as in the paper's example.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub n_rows: usize,
    pub n_cols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn from_dense(a: &Mat) -> Self {
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    rows.push(i as u32);
                    cols.push(j as u32);
                    vals.push(v);
                }
            }
        }
        Coo { n_rows: a.rows, n_cols: a.cols, rows, cols, vals }
    }

    /// Build from triplets (any order); sorts to canonical (row, col) order.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(u32, u32, f32)],
    ) -> Result<Self, FormatError> {
        let mut sorted: Vec<&(u32, u32, f32)> = triplets.iter().collect();
        sorted.sort_by_key(|(r, c, _)| (*r, *c));
        let mut rows = Vec::with_capacity(triplets.len());
        let mut cols = Vec::with_capacity(triplets.len());
        let mut vals = Vec::with_capacity(triplets.len());
        let mut prev: Option<(u32, u32)> = None;
        for &&(r, c, v) in &sorted {
            if r as usize >= n_rows || c as usize >= n_cols {
                return Err(FormatError::Invalid(format!("({r},{c}) out of {n_rows}x{n_cols}")));
            }
            if prev == Some((r, c)) {
                return Err(FormatError::Invalid(format!("duplicate entry ({r},{c})")));
            }
            prev = Some((r, c));
            if v != 0.0 {
                rows.push(r);
                cols.push(c);
                vals.push(v);
            }
        }
        Ok(Coo { n_rows, n_cols, rows, cols, vals })
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.n_rows * self.n_cols) as f64
    }

    /// Structural validation: lengths agree, indices in range, canonical order.
    pub fn validate(&self) -> Result<(), FormatError> {
        if self.rows.len() != self.vals.len() || self.cols.len() != self.vals.len() {
            return Err(FormatError::Invalid("array length mismatch".into()));
        }
        let mut prev: Option<(u32, u32)> = None;
        for k in 0..self.nnz() {
            let (r, c) = (self.rows[k], self.cols[k]);
            if r as usize >= self.n_rows || c as usize >= self.n_cols {
                return Err(FormatError::Invalid(format!("entry {k} out of range")));
            }
            if let Some(p) = prev {
                if (r, c) <= p {
                    return Err(FormatError::Invalid(format!("entry {k} not (row,col)-sorted")));
                }
            }
            prev = Some((r, c));
        }
        Ok(())
    }

    /// Iterate (row, col, val).
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.nnz()).map(move |k| (self.rows[k], self.cols[k], self.vals[k]))
    }
}

impl ToDense for Coo {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for (r, c, v) in self.iter() {
            m[(r as usize, c as usize)] += v;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn paper_example() {
        // The 4x4 example from §II-C.
        #[rustfmt::skip]
        let a = Mat::from_vec(4, 4, vec![
            7.0, 0.0, 0.0, 8.0,
            0.0, 10.0, 0.0, 0.0,
            9.0, 0.0, 0.0, 0.0,
            0.0, 0.0, 6.0, 3.0,
        ]);
        let coo = Coo::from_dense(&a);
        assert_eq!(coo.vals, vec![7.0, 8.0, 10.0, 9.0, 6.0, 3.0]);
        assert_eq!(coo.rows, vec![0, 0, 1, 2, 3, 3]);
        assert_eq!(coo.cols, vec![0, 3, 1, 0, 2, 3]);
    }

    #[test]
    fn round_trip() {
        let mut rng = Rng::new(1);
        let a = gen::uniform(48, 0.85, &mut rng);
        let coo = Coo::from_dense(&a);
        assert_eq!(coo.to_dense(), a);
        coo.validate().unwrap();
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::from_dense(&Mat::zeros(8, 8));
        assert_eq!(coo.nnz(), 0);
        assert_eq!(coo.sparsity(), 1.0);
        coo.validate().unwrap();
    }

    #[test]
    fn from_triplets_sorts() {
        let coo = Coo::from_triplets(4, 4, &[(3, 1, 2.0), (0, 2, 1.0)]).unwrap();
        assert_eq!(coo.rows, vec![0, 3]);
        coo.validate().unwrap();
    }

    #[test]
    fn from_triplets_rejects_duplicates_and_oob() {
        assert!(Coo::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0)]).is_err());
        assert!(Coo::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn from_triplets_drops_explicit_zeros() {
        let coo = Coo::from_triplets(2, 2, &[(0, 0, 0.0), (1, 1, 5.0)]).unwrap();
        assert_eq!(coo.nnz(), 1);
    }

    #[test]
    fn validate_catches_unsorted() {
        let mut coo = Coo::from_dense(&Mat::eye(4));
        coo.rows.swap(0, 3);
        assert!(coo.validate().is_err());
    }
}
