//! BSR — block sparse row storage (format-library extension).
//!
//! Block-structured matrices (the `block_diagonal` family; FEM/structural
//! problems in Table III) waste GCOO index space: every nonzero carries
//! 8 bytes of coordinates. BSR stores dense `bs×bs` blocks with one
//! coordinate pair per *block*, cutting index overhead by bs² and making
//! block-level kernels (dense micro-GEMMs per block) possible. Included to
//! quantify the format trade-off against Table I (see `bsr_elements`).

use super::{FormatError, ToDense};
use crate::ndarray::Mat;

/// Block sparse row: like CSR over a (n/bs × n/bs) grid of blocks; each
/// stored block is a dense row-major `bs×bs` tile in `blocks`.
#[derive(Clone, Debug, PartialEq)]
pub struct Bsr {
    pub n: usize,
    pub bs: usize,
    /// block-row pointer, length n/bs + 1
    pub row_ptr: Vec<u32>,
    /// block-column index per stored block
    pub cols: Vec<u32>,
    /// concatenated bs×bs tiles, row-major within each tile
    pub blocks: Vec<f32>,
}

impl Bsr {
    /// Build from dense; a block is stored iff it has any nonzero.
    pub fn from_dense(a: &Mat, bs: usize) -> Result<Self, FormatError> {
        if bs == 0 || a.rows % bs != 0 || a.cols % bs != 0 || a.rows != a.cols {
            return Err(FormatError::Invalid(format!(
                "bs={bs} must divide square dims {}x{}",
                a.rows, a.cols
            )));
        }
        let nb = a.rows / bs;
        let mut row_ptr = vec![0u32; nb + 1];
        let mut cols = Vec::new();
        let mut blocks = Vec::new();
        for bi in 0..nb {
            for bj in 0..nb {
                let mut any = false;
                'scan: for i in 0..bs {
                    for j in 0..bs {
                        if a[(bi * bs + i, bj * bs + j)] != 0.0 {
                            any = true;
                            break 'scan;
                        }
                    }
                }
                if any {
                    cols.push(bj as u32);
                    for i in 0..bs {
                        for j in 0..bs {
                            blocks.push(a[(bi * bs + i, bj * bs + j)]);
                        }
                    }
                }
            }
            row_ptr[bi + 1] = cols.len() as u32;
        }
        Ok(Bsr { n: a.rows, bs, row_ptr, cols, blocks })
    }

    pub fn num_blocks(&self) -> usize {
        self.cols.len()
    }

    /// Stored nonzero *slots* (including explicit zeros inside blocks).
    pub fn stored_values(&self) -> usize {
        self.blocks.len()
    }

    /// Fill efficiency: true nonzeros / stored slots (1.0 = perfectly
    /// block-aligned structure; low values mean BSR wastes space).
    pub fn fill_efficiency(&self) -> f64 {
        let nnz = self.blocks.iter().filter(|v| **v != 0.0).count();
        if self.blocks.is_empty() {
            1.0
        } else {
            nnz as f64 / self.blocks.len() as f64
        }
    }

    /// Element count analogous to Table I:
    /// stored values + one col index per block + block-row pointer.
    pub fn elements(&self) -> usize {
        self.stored_values() + self.num_blocks() + self.row_ptr.len()
    }

    /// Block-level SpDM: C = A·B using dense bs×bs micro-GEMMs per block —
    /// the kernel structure BSR enables (CPU reference implementation).
    pub fn spdm(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.n);
        let bs = self.bs;
        let mut c = Mat::zeros(self.n, b.cols);
        let nb = self.n / bs;
        for bi in 0..nb {
            for k in self.row_ptr[bi] as usize..self.row_ptr[bi + 1] as usize {
                let bj = self.cols[k] as usize;
                let tile = &self.blocks[k * bs * bs..(k + 1) * bs * bs];
                // micro-GEMM: C[bi*bs.., :] += tile · B[bj*bs.., :]
                for i in 0..bs {
                    for l in 0..bs {
                        let a_il = tile[i * bs + l];
                        if a_il == 0.0 {
                            continue;
                        }
                        let brow = b.row(bj * bs + l);
                        let crow = c.row_mut(bi * bs + i);
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += a_il * bv;
                        }
                    }
                }
            }
        }
        c
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        let nb = self.n / self.bs;
        if self.row_ptr.len() != nb + 1 || self.row_ptr[0] != 0 {
            return Err(FormatError::Invalid("row_ptr shape".into()));
        }
        if *self.row_ptr.last().unwrap() as usize != self.num_blocks() {
            return Err(FormatError::Invalid("row_ptr end".into()));
        }
        if self.blocks.len() != self.num_blocks() * self.bs * self.bs {
            return Err(FormatError::Invalid("blocks length".into()));
        }
        for bi in 0..nb {
            let r = self.row_ptr[bi] as usize..self.row_ptr[bi + 1] as usize;
            let cols = &self.cols[r];
            if cols.windows(2).any(|w| w[0] >= w[1]) {
                return Err(FormatError::Invalid(format!("block row {bi} unsorted")));
            }
            if cols.iter().any(|&c| c as usize >= nb) {
                return Err(FormatError::Invalid(format!("block row {bi} col range")));
            }
        }
        Ok(())
    }
}

impl ToDense for Bsr {
    fn to_dense(&self) -> Mat {
        let bs = self.bs;
        let mut m = Mat::zeros(self.n, self.n);
        let nb = self.n / bs;
        for bi in 0..nb {
            for k in self.row_ptr[bi] as usize..self.row_ptr[bi + 1] as usize {
                let bj = self.cols[k] as usize;
                for i in 0..bs {
                    for j in 0..bs {
                        m[(bi * bs + i, bj * bs + j)] = self.blocks[k * bs * bs + i * bs + j];
                    }
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn round_trip_block_diagonal() {
        let mut rng = Rng::new(1);
        let a = gen::block_diagonal(64, 0.9, &mut rng);
        let bsr = Bsr::from_dense(&a, 4).unwrap();
        bsr.validate().unwrap();
        assert_eq!(bsr.to_dense(), a);
    }

    #[test]
    fn round_trip_uniform() {
        let mut rng = Rng::new(2);
        let a = gen::uniform(48, 0.9, &mut rng);
        let bsr = Bsr::from_dense(&a, 8).unwrap();
        bsr.validate().unwrap();
        assert_eq!(bsr.to_dense(), a);
    }

    #[test]
    fn spdm_matches_oracle() {
        let mut rng = Rng::new(3);
        let a = gen::block_diagonal(32, 0.8, &mut rng);
        let b = crate::ndarray::Mat::randn(32, 16, &mut rng);
        let bsr = Bsr::from_dense(&a, 4).unwrap();
        let c = bsr.spdm(&b);
        assert!(c.allclose(&a.matmul(&b), 1e-4, 1e-4));
    }

    #[test]
    fn fill_efficiency_discriminates_structure() {
        let mut rng = Rng::new(4);
        // block-aligned structure: high efficiency
        let blocky = Bsr::from_dense(&gen::block_diagonal(64, 0.9, &mut rng), 4).unwrap();
        // scattered structure: low efficiency at the same sparsity
        let scattered = Bsr::from_dense(&gen::uniform(64, 0.9, &mut rng), 4).unwrap();
        assert!(
            blocky.fill_efficiency() > scattered.fill_efficiency() + 0.2,
            "blocky {} vs scattered {}",
            blocky.fill_efficiency(),
            scattered.fill_efficiency()
        );
    }

    #[test]
    fn elements_beat_gcoo_for_block_structure() {
        // For block-aligned matrices, BSR stores fewer elements than GCOO.
        let mut rng = Rng::new(5);
        let a = gen::block_diagonal(64, 0.9, &mut rng);
        let bsr = Bsr::from_dense(&a, 4).unwrap();
        let gcoo_elems = crate::sparse::gcoo_elements(a.nnz(), 64, 8);
        assert!(
            bsr.elements() < gcoo_elems,
            "bsr {} vs gcoo {}",
            bsr.elements(),
            gcoo_elems
        );
    }

    #[test]
    fn rejects_bad_block_size() {
        let a = crate::ndarray::Mat::zeros(10, 10);
        assert!(Bsr::from_dense(&a, 3).is_err());
        assert!(Bsr::from_dense(&a, 0).is_err());
    }

    #[test]
    fn empty_matrix_valid() {
        let bsr = Bsr::from_dense(&crate::ndarray::Mat::zeros(16, 16), 4).unwrap();
        assert_eq!(bsr.num_blocks(), 0);
        bsr.validate().unwrap();
        assert_eq!(bsr.fill_efficiency(), 1.0);
    }
}
