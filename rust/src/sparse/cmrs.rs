//! CMRS — Compressed Multi-Row Storage (Koza et al., arXiv:1203.2946),
//! adapted to this engine's slab discipline for high-variance row
//! distributions where a single heavy row starves GCOO's (col,row) scan.
//!
//! Rows are grouped into *strips* of `p` consecutive rows — deliberately
//! the same height as the GCOO band, so `scan_stats`' per-band nnz counts
//! price strips exactly and no second stats pass is ever needed. Within a
//! strip, entries are interleaved **round-robin by occurrence index**:
//! first every row's 0th entry (ascending row), then every row's 1st, and
//! so on. A warp scanning the strip sequentially therefore touches `p`
//! different output rows in turn instead of draining one heavy row while
//! its neighbors idle — the load-balancing CMRS exists for.
//!
//! Bitwise discipline: each row's entries appear in ascending occurrence
//! index, and per-row entry lists are collected in ascending column order,
//! so every output element still accumulates over ascending k in f32 —
//! identical bit-for-bit to the dense/GCOO/ELL reference order.

use super::{FormatError, ToDense};
use crate::ndarray::Mat;

/// CMRS: concatenated per-strip entry arrays, round-robin interleaved
/// within each strip. Row indices are strip-local (`0..p`).
#[derive(Clone, Debug, PartialEq)]
pub struct Cmrs {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Strip height (equal to the GCOO band height p, so per-band stats
    /// price strips without a second scan).
    pub p: usize,
    pub vals: Vec<f32>,
    /// Strip-local row index of each entry (0..p).
    pub rows: Vec<u32>,
    /// Absolute column index of each entry.
    pub cols: Vec<u32>,
    /// Start offset of each strip in the concatenated arrays.
    pub s_idxes: Vec<u32>,
    /// Nonzeros per strip.
    pub nnz_per_strip: Vec<u32>,
}

impl Cmrs {
    /// Number of strips = ceil(n_rows / p).
    pub fn num_strips(&self) -> usize {
        self.n_rows.div_ceil(self.p)
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Build from dense: collect each strip row's entries in ascending
    /// column order, then emit round-robin by (occurrence index, row).
    pub fn from_dense(a: &Mat, p: usize) -> Self {
        assert!(p > 0);
        let g = a.rows.div_ceil(p);
        let mut vals = Vec::new();
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut s_idxes = vec![0u32; g];
        let mut nnz_per_strip = vec![0u32; g];
        for si in 0..g {
            let lo = si * p;
            let hi = ((si + 1) * p).min(a.rows);
            s_idxes[si] = vals.len() as u32;
            // Per-row (col, val) lists; a row-major walk gives ascending cols.
            let lists: Vec<Vec<(u32, f32)>> = (lo..hi)
                .map(|i| {
                    a.row(i)
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(j, &v)| (j as u32, v))
                        .collect()
                })
                .collect();
            let deepest = lists.iter().map(|l| l.len()).max().unwrap_or(0);
            for idx in 0..deepest {
                for (r, list) in lists.iter().enumerate() {
                    if let Some(&(c, v)) = list.get(idx) {
                        vals.push(v);
                        rows.push(r as u32);
                        cols.push(c);
                    }
                }
            }
            nnz_per_strip[si] = vals.len() as u32 - s_idxes[si];
        }
        Cmrs { n_rows: a.rows, n_cols: a.cols, p, vals, rows, cols, s_idxes, nnz_per_strip }
    }

    /// Strip `si`'s entries as (strip-local row, col, val), in stored
    /// (interleaved) order.
    pub fn strip(&self, si: usize) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        let lo = self.s_idxes[si] as usize;
        let hi = lo + self.nnz_per_strip[si] as usize;
        (lo..hi).map(move |k| (self.rows[k], self.cols[k], self.vals[k]))
    }

    /// Largest per-strip nnz — the capacity the padded device form needs.
    /// Equal to GCOO's `max_band_nnz` for the same matrix and p.
    pub fn max_strip_nnz(&self) -> usize {
        self.nnz_per_strip.iter().copied().max().unwrap_or(0) as usize
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        let g = self.num_strips();
        if self.s_idxes.len() != g || self.nnz_per_strip.len() != g {
            return Err(FormatError::Invalid("strip array lengths".into()));
        }
        let total: usize = self.nnz_per_strip.iter().map(|&x| x as usize).sum();
        if total != self.nnz() {
            return Err(FormatError::Invalid("nnz_per_strip sum != nnz".into()));
        }
        for si in 0..g {
            let expect = if si == 0 {
                0
            } else {
                self.s_idxes[si - 1] + self.nnz_per_strip[si - 1]
            };
            if self.s_idxes[si] != expect {
                return Err(FormatError::Invalid(format!("s_idxes[{si}] != prefix sum")));
            }
            let strip_rows = ((si + 1) * self.p).min(self.n_rows) - si * self.p;
            // Round-robin invariant: the (occurrence index, row) key of the
            // entry stream is strictly ascending, and each row's columns
            // ascend with occurrence index.
            let mut seen = vec![0u32; strip_rows];
            let mut last_col = vec![None::<u32>; strip_rows];
            let mut prev_key: Option<(u32, u32)> = None;
            for (r, c, _v) in self.strip(si) {
                if r as usize >= strip_rows || c as usize >= self.n_cols {
                    return Err(FormatError::Invalid(format!("strip {si}: entry out of range")));
                }
                let key = (seen[r as usize], r);
                if let Some(p) = prev_key {
                    if key <= p {
                        return Err(FormatError::Invalid(format!(
                            "strip {si}: not round-robin interleaved"
                        )));
                    }
                }
                if let Some(lc) = last_col[r as usize] {
                    if c <= lc {
                        return Err(FormatError::Invalid(format!(
                            "strip {si}: row {r} columns not ascending"
                        )));
                    }
                }
                last_col[r as usize] = Some(c);
                seen[r as usize] += 1;
                prev_key = Some(key);
            }
        }
        Ok(())
    }

    /// Pad to the device layout the `cmrs_*` artifacts expect.
    pub fn pad(&self, cap: usize) -> Result<CmrsPadded, FormatError> {
        let need = self.max_strip_nnz();
        if need > cap {
            return Err(FormatError::CapacityExceeded {
                which: "cmrs strip".into(),
                needed: need,
                cap,
            });
        }
        let g = self.num_strips();
        let mut vals = vec![0.0f32; g * cap];
        let mut rows = vec![0i32; g * cap];
        let mut cols = vec![0i32; g * cap];
        for si in 0..g {
            for (k, (r, c, v)) in self.strip(si).enumerate() {
                vals[si * cap + k] = v;
                rows[si * cap + k] = r as i32;
                cols[si * cap + k] = c as i32;
            }
        }
        Ok(CmrsPadded { g, cap, p: self.p, n: self.n_cols, vals, rows, cols })
    }
}

impl ToDense for Cmrs {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for si in 0..self.num_strips() {
            for (r, c, v) in self.strip(si) {
                m[(si * self.p + r as usize, c as usize)] += v;
            }
        }
        m
    }
}

/// Device-layout CMRS: `(g, cap)` row-major strip slabs, zero padded —
/// structurally a [`super::GcooPadded`] twin, but the entry order inside
/// each slab row is the round-robin interleave, never (col,row).
#[derive(Clone, Debug, PartialEq)]
pub struct CmrsPadded {
    pub g: usize,
    pub cap: usize,
    pub p: usize,
    pub n: usize,
    pub vals: Vec<f32>,
    pub rows: Vec<i32>,
    pub cols: Vec<i32>,
}

impl CmrsPadded {
    /// Borrow the slabs as the view the engine consumes (no copy).
    pub fn as_slabs(&self) -> CmrsSlabs<'_> {
        CmrsSlabs {
            g: self.g,
            cap: self.cap,
            p: self.p,
            n: self.n,
            vals: &self.vals,
            rows: &self.rows,
            cols: &self.cols,
        }
    }
}

/// Borrowed view of device-layout CMRS slabs.
#[derive(Clone, Copy, Debug)]
pub struct CmrsSlabs<'a> {
    pub g: usize,
    pub cap: usize,
    pub p: usize,
    pub n: usize,
    pub vals: &'a [f32],
    pub rows: &'a [i32],
    pub cols: &'a [i32],
}

impl CmrsSlabs<'_> {
    /// Re-pad to a different strip capacity, producing owned slabs. The
    /// interleave inside each strip's `cap`-prefix is untouched, so repad
    /// is order-preserving (and therefore bitwise-safe).
    pub fn repad(&self, cap: usize) -> CmrsPadded {
        let mut vals = vec![0.0f32; self.g * cap];
        let mut rows = vec![0i32; self.g * cap];
        let mut cols = vec![0i32; self.g * cap];
        let copy = self.cap.min(cap);
        for si in 0..self.g {
            vals[si * cap..si * cap + copy]
                .copy_from_slice(&self.vals[si * self.cap..si * self.cap + copy]);
            rows[si * cap..si * cap + copy]
                .copy_from_slice(&self.rows[si * self.cap..si * self.cap + copy]);
            cols[si * cap..si * cap + copy]
                .copy_from_slice(&self.cols[si * self.cap..si * self.cap + copy]);
        }
        CmrsPadded { g: self.g, cap, p: self.p, n: self.n, vals, rows, cols }
    }

    /// Total slab bytes at this geometry (f32 vals + i32 rows + i32 cols).
    pub fn bytes(&self) -> usize {
        self.g * self.cap * (4 + 4 + 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn small_example_interleaves_round_robin() {
        // Strip 0 = rows {0,1}: row 0 holds (0,7),(3,8); row 1 holds (1,10).
        // Round-robin: idx 0 of rows 0,1 then idx 1 of row 0.
        #[rustfmt::skip]
        let a = Mat::from_vec(4, 4, vec![
            7.0, 0.0, 0.0, 8.0,
            0.0, 10.0, 0.0, 0.0,
            9.0, 0.0, 0.0, 0.0,
            0.0, 0.0, 6.0, 3.0,
        ]);
        let cmrs = Cmrs::from_dense(&a, 2);
        assert_eq!(cmrs.num_strips(), 2);
        assert_eq!(cmrs.nnz_per_strip, vec![3, 3]);
        assert_eq!(cmrs.s_idxes, vec![0, 3]);
        let s0: Vec<_> = cmrs.strip(0).collect();
        assert_eq!(s0, vec![(0, 0, 7.0), (1, 1, 10.0), (0, 3, 8.0)]);
        // Strip 1: row 0 holds (0,9); row 1 holds (2,6),(3,3).
        let s1: Vec<_> = cmrs.strip(1).collect();
        assert_eq!(s1, vec![(0, 0, 9.0), (1, 2, 6.0), (1, 3, 3.0)]);
        cmrs.validate().unwrap();
        assert_eq!(cmrs.to_dense(), a);
    }

    #[test]
    fn heavy_row_interleaves_not_drains() {
        // Row 0 dense, rows 1-3 single-entry: the stream must alternate
        // across rows before returning to row 0's tail.
        let mut a = Mat::zeros(4, 8);
        for j in 0..8 {
            a[(0, j)] = (j + 1) as f32;
        }
        a[(1, 2)] = 20.0;
        a[(2, 5)] = 30.0;
        a[(3, 7)] = 40.0;
        let cmrs = Cmrs::from_dense(&a, 4);
        let rows: Vec<u32> = cmrs.strip(0).map(|e| e.0).collect();
        assert_eq!(&rows[..4], &[0, 1, 2, 3], "idx-0 pass covers every row");
        assert!(rows[4..].iter().all(|&r| r == 0), "tail is the heavy row");
        cmrs.validate().unwrap();
        assert_eq!(cmrs.to_dense(), a);
    }

    #[test]
    fn per_row_order_is_ascending_col() {
        // The bitwise guarantee: each row's entries appear in ascending
        // column order within the stream.
        let mut rng = Rng::new(31);
        let a = gen::power_law_rows(64, 0.9, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        cmrs.validate().unwrap();
        for si in 0..cmrs.num_strips() {
            let mut last = vec![None::<u32>; 8];
            for (r, c, _v) in cmrs.strip(si) {
                if let Some(lc) = last[r as usize] {
                    assert!(c > lc, "strip {si} row {r} out of column order");
                }
                last[r as usize] = Some(c);
            }
        }
    }

    #[test]
    fn round_trip_uniform_and_ragged() {
        let mut rng = Rng::new(32);
        let a = gen::uniform(64, 0.9, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        cmrs.validate().unwrap();
        assert_eq!(cmrs.to_dense(), a);
        // 30 rows, p=8: ragged last strip of 6 rows.
        let b = gen::uniform(30, 0.7, &mut rng);
        let cb = Cmrs::from_dense(&b, 8);
        assert_eq!(cb.num_strips(), 4);
        cb.validate().unwrap();
        assert_eq!(cb.to_dense(), b);
    }

    #[test]
    fn strip_counts_match_gcoo_band_counts() {
        // Strip == band: scan_stats' per-band counts price CMRS capacity.
        let mut rng = Rng::new(33);
        let a = gen::uniform(48, 0.85, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        let gcoo = super::super::Gcoo::from_dense(&a, 8);
        assert_eq!(cmrs.nnz_per_strip, gcoo.nnz_per_group);
        assert_eq!(cmrs.max_strip_nnz(), gcoo.max_group_nnz());
    }

    #[test]
    fn pad_round_trip_and_capacity() {
        let mut rng = Rng::new(34);
        let a = gen::uniform(32, 0.9, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        let padded = cmrs.pad(cmrs.max_strip_nnz()).unwrap();
        assert_eq!(padded.vals.len(), padded.g * padded.cap);
        assert!(cmrs.pad(cmrs.max_strip_nnz().saturating_sub(1)).is_err());
    }

    #[test]
    fn slab_repad_grows_and_shrinks_consistently() {
        let p = CmrsPadded {
            g: 2,
            cap: 2,
            p: 2,
            n: 4,
            vals: vec![1.0, 2.0, 3.0, 4.0],
            rows: vec![0, 1, 0, 1],
            cols: vec![0, 1, 2, 3],
        };
        let grown = p.as_slabs().repad(3);
        assert_eq!(grown.vals, vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0]);
        assert_eq!(grown.rows, vec![0, 1, 0, 0, 1, 0]);
        assert_eq!(grown.cols, vec![0, 1, 0, 2, 3, 0]);
        assert_eq!(grown.as_slabs().repad(2), p);
    }

    #[test]
    fn slab_views_borrow_without_copying() {
        let mut rng = Rng::new(35);
        let a = gen::uniform(32, 0.9, &mut rng);
        let cmrs = Cmrs::from_dense(&a, 8);
        let padded = cmrs.pad(cmrs.max_strip_nnz().max(1)).unwrap();
        let slabs = padded.as_slabs();
        assert!(std::ptr::eq(slabs.vals.as_ptr(), padded.vals.as_ptr()));
        assert_eq!(slabs.bytes(), padded.g * padded.cap * 12);
    }

    #[test]
    fn validate_catches_broken_interleave() {
        let mut rng = Rng::new(36);
        let a = gen::uniform(32, 0.8, &mut rng);
        let mut cmrs = Cmrs::from_dense(&a, 8);
        // Swapping two adjacent entries of different rows breaks the
        // (occurrence, row) ordering.
        let mut broke = false;
        for k in 1..cmrs.nnz() {
            if cmrs.rows[k] != cmrs.rows[k - 1] {
                cmrs.rows.swap(k, k - 1);
                cmrs.cols.swap(k, k - 1);
                cmrs.vals.swap(k, k - 1);
                broke = true;
                break;
            }
        }
        assert!(broke);
        assert!(cmrs.validate().is_err());
    }
}
