//! Sparse matrix storage formats: COO, CSR, GCOO (the paper's contribution)
//! and the padded device forms consumed by the AOT kernels.
//!
//! Layouts follow the paper §II-C/§III-A exactly (concatenated group arrays,
//! `gIdxes`, `nnzPerGroup`) with one documented divergence: groups are bands
//! of `p` consecutive *rows* (see DESIGN.md §3 "GCOO orientation note") —
//! the reading consistent with Algorithm 2's output indexing.

mod coo;
mod csr;
mod gcoo;
mod cmrs;
mod rowsplit;
mod bsr;
mod footprint;

pub use coo::Coo;
pub use csr::Csr;
pub use gcoo::{Ell, EllSlabs, Gcoo, GcooPadded, GcooSlabs};
pub use cmrs::{Cmrs, CmrsPadded, CmrsSlabs};
pub use rowsplit::{RowSplit, RowSplitPadded, RowSplitSlabs};
pub use bsr::Bsr;
pub use footprint::{
    FootprintBytes, coo_bytes, csr_bytes, gcoo_bytes, dense_bytes, coo_elements, csr_elements,
    gcoo_elements,
};

use crate::ndarray::Mat;

/// Errors shared across format code.
#[derive(Debug, Clone, PartialEq)]
pub enum FormatError {
    /// A band/row exceeded the padded device capacity.
    CapacityExceeded { which: String, needed: usize, cap: usize },
    /// Structural validation failed (index out of range, unsorted, …).
    Invalid(String),
}

impl std::fmt::Display for FormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FormatError::CapacityExceeded { which, needed, cap } => {
                write!(f, "{which}: nnz {needed} exceeds capacity {cap}")
            }
            FormatError::Invalid(msg) => write!(f, "invalid format: {msg}"),
        }
    }
}

impl std::error::Error for FormatError {}

/// Anything that can reconstruct the dense matrix it encodes.
pub trait ToDense {
    fn to_dense(&self) -> Mat;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    /// Cross-format agreement: every format must densify to the same matrix.
    #[test]
    fn all_formats_agree() {
        let mut rng = Rng::new(99);
        let a = gen::uniform(64, 0.9, &mut rng);
        let coo = Coo::from_dense(&a);
        let csr = Csr::from_dense(&a);
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(coo.to_dense(), a);
        assert_eq!(csr.to_dense(), a);
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn conversion_chains_agree() {
        let mut rng = Rng::new(100);
        let a = gen::uniform(32, 0.8, &mut rng);
        let via_coo = Csr::from_coo(&Coo::from_dense(&a));
        assert_eq!(via_coo.to_dense(), a);
        let back_coo = via_coo.to_coo();
        assert_eq!(back_coo.to_dense(), a);
    }
}
