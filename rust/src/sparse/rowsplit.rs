//! Row-split — the nnz-split SpMM discipline of Yang, Buluç & Owens
//! (arXiv:1803.08601), adapted to this engine's slab layout for power-law
//! matrices where banded GCOO degrades: a single dense row inflates its
//! whole band's capacity, while row-split simply cuts the row into
//! equal-work segments.
//!
//! Every row with nonzeros is split into `ceil(nnz_row / cap)` *segments*
//! of at most `cap` entries, emitted in row order; each segment carries
//! its owning row, so work per segment is bounded by `cap` regardless of
//! how skewed the row distribution is. Geometry is content-dependent
//! (`segs` varies with the matrix), so the padded form carries the
//! segment count explicitly.
//!
//! Bitwise discipline: segments of one row appear in order and entries
//! inside a segment keep ascending column order, so every output element
//! accumulates over ascending k in f32 — bit-identical to the
//! dense/GCOO/ELL/CMRS reference order.

use super::{FormatError, ToDense};
use crate::ndarray::Mat;

/// Row-split: concatenated unpadded segment arrays in row order.
#[derive(Clone, Debug, PartialEq)]
pub struct RowSplit {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Segment capacity (max entries per segment).
    pub cap: usize,
    pub vals: Vec<f32>,
    /// Absolute column index of each entry.
    pub cols: Vec<u32>,
    /// Owning row of each segment.
    pub seg_rows: Vec<u32>,
    /// Entries in each segment (≤ cap; every segment but a row's last is
    /// exactly cap).
    pub seg_len: Vec<u32>,
}

impl RowSplit {
    /// Split each row's entries (ascending column) into `cap`-sized
    /// segments. Any `cap ≥ 1` fits any matrix — there is no capacity
    /// failure mode, only more segments.
    pub fn from_dense(a: &Mat, cap: usize) -> Result<Self, FormatError> {
        if cap == 0 {
            return Err(FormatError::Invalid("rowsplit: segment capacity 0".into()));
        }
        let mut vals = Vec::new();
        let mut cols = Vec::new();
        let mut seg_rows = Vec::new();
        let mut seg_len = Vec::new();
        for i in 0..a.rows {
            let mut in_seg = 0u32;
            for (j, &v) in a.row(i).iter().enumerate() {
                if v == 0.0 {
                    continue;
                }
                if in_seg == 0 {
                    seg_rows.push(i as u32);
                    seg_len.push(0);
                }
                vals.push(v);
                cols.push(j as u32);
                in_seg += 1;
                *seg_len.last_mut().unwrap() = in_seg;
                if in_seg as usize == cap {
                    in_seg = 0;
                }
            }
        }
        Ok(RowSplit { n_rows: a.rows, n_cols: a.cols, cap, vals, cols, seg_rows, seg_len })
    }

    pub fn num_segments(&self) -> usize {
        self.seg_rows.len()
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Segment `s`'s entries as (col, val), in stored (ascending-column)
    /// order.
    pub fn segment(&self, s: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo: usize = self.seg_len[..s].iter().map(|&l| l as usize).sum();
        let hi = lo + self.seg_len[s] as usize;
        (lo..hi).map(move |k| (self.cols[k], self.vals[k]))
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        if self.cap == 0 {
            return Err(FormatError::Invalid("rowsplit: segment capacity 0".into()));
        }
        if self.seg_rows.len() != self.seg_len.len() {
            return Err(FormatError::Invalid("segment array lengths".into()));
        }
        let total: usize = self.seg_len.iter().map(|&l| l as usize).sum();
        if total != self.nnz() {
            return Err(FormatError::Invalid("seg_len sum != nnz".into()));
        }
        let mut k = 0usize;
        let mut prev_row: Option<u32> = None;
        let mut last_col: Option<u32> = None;
        for s in 0..self.num_segments() {
            let row = self.seg_rows[s];
            let len = self.seg_len[s] as usize;
            if row as usize >= self.n_rows {
                return Err(FormatError::Invalid(format!("segment {s}: row out of range")));
            }
            if len == 0 || len > self.cap {
                return Err(FormatError::Invalid(format!("segment {s}: bad length {len}")));
            }
            match prev_row {
                Some(pr) if pr == row => {
                    // A continuation segment: the previous one must be full.
                    if self.seg_len[s - 1] as usize != self.cap {
                        return Err(FormatError::Invalid(format!(
                            "segment {s}: follows a non-full segment of row {row}"
                        )));
                    }
                }
                Some(pr) if pr > row => {
                    return Err(FormatError::Invalid(format!(
                        "segment {s}: rows not ascending"
                    )));
                }
                _ => last_col = None,
            }
            for _ in 0..len {
                let c = self.cols[k];
                if c as usize >= self.n_cols {
                    return Err(FormatError::Invalid(format!("segment {s}: col out of range")));
                }
                if let Some(lc) = last_col {
                    if c <= lc {
                        return Err(FormatError::Invalid(format!(
                            "segment {s}: row {row} columns not ascending"
                        )));
                    }
                }
                last_col = Some(c);
                k += 1;
            }
            prev_row = Some(row);
        }
        Ok(())
    }

    /// Pad to the device layout the `rowsplit_*` artifacts expect: each
    /// segment zero-padded to `cap` entries.
    pub fn pad(&self) -> RowSplitPadded {
        let segs = self.num_segments();
        let mut vals = vec![0.0f32; segs * self.cap];
        let mut cols = vec![0i32; segs * self.cap];
        let seg_rows: Vec<i32> = self.seg_rows.iter().map(|&r| r as i32).collect();
        for s in 0..segs {
            for (k, (c, v)) in self.segment(s).enumerate() {
                vals[s * self.cap + k] = v;
                cols[s * self.cap + k] = c as i32;
            }
        }
        RowSplitPadded { segs, cap: self.cap, n: self.n_rows, vals, seg_rows, cols }
    }
}

impl ToDense for RowSplit {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for s in 0..self.num_segments() {
            for (c, v) in self.segment(s) {
                m[(self.seg_rows[s] as usize, c as usize)] += v;
            }
        }
        m
    }
}

/// Device-layout row-split: `(segs, cap)` row-major segment slabs, zero
/// padded, plus the per-segment owning-row array. `n` is the (square)
/// matrix dimension — needed because empty rows produce no segments.
#[derive(Clone, Debug, PartialEq)]
pub struct RowSplitPadded {
    pub segs: usize,
    pub cap: usize,
    pub n: usize,
    pub vals: Vec<f32>,
    pub seg_rows: Vec<i32>,
    pub cols: Vec<i32>,
}

impl RowSplitPadded {
    /// Borrow the slabs as the view the engine consumes (no copy).
    pub fn as_slabs(&self) -> RowSplitSlabs<'_> {
        RowSplitSlabs {
            segs: self.segs,
            cap: self.cap,
            n: self.n,
            vals: &self.vals,
            seg_rows: &self.seg_rows,
            cols: &self.cols,
        }
    }
}

/// Borrowed view of device-layout row-split slabs.
#[derive(Clone, Copy, Debug)]
pub struct RowSplitSlabs<'a> {
    pub segs: usize,
    pub cap: usize,
    pub n: usize,
    pub vals: &'a [f32],
    pub seg_rows: &'a [i32],
    pub cols: &'a [i32],
}

impl RowSplitSlabs<'_> {
    /// Re-pad to a different segment capacity. Unlike the banded formats
    /// this *re-segments*: per-row entry lists are reassembled in stored
    /// order (segments of a row are contiguous and ordered) and cut at the
    /// new capacity. Per-row entry order is preserved, so the result is
    /// bitwise-safe.
    pub fn repad(&self, cap: usize) -> RowSplitPadded {
        assert!(cap > 0, "rowsplit repad: capacity 0");
        let mut per_row: Vec<Vec<(i32, f32)>> = vec![Vec::new(); self.n];
        for s in 0..self.segs {
            let row = self.seg_rows[s] as usize;
            for k in 0..self.cap {
                let v = self.vals[s * self.cap + k];
                if v != 0.0 {
                    per_row[row].push((self.cols[s * self.cap + k], v));
                }
            }
        }
        let segs: usize = per_row.iter().map(|l| l.len().div_ceil(cap)).sum();
        let mut vals = vec![0.0f32; segs * cap];
        let mut cols = vec![0i32; segs * cap];
        let mut seg_rows = Vec::with_capacity(segs);
        let mut s = 0usize;
        for (row, list) in per_row.iter().enumerate() {
            for chunk in list.chunks(cap) {
                seg_rows.push(row as i32);
                for (k, &(c, v)) in chunk.iter().enumerate() {
                    vals[s * cap + k] = v;
                    cols[s * cap + k] = c;
                }
                s += 1;
            }
        }
        debug_assert_eq!(s, segs);
        RowSplitPadded { segs, cap, n: self.n, vals, seg_rows, cols }
    }

    /// Total slab bytes at this geometry (f32 vals + i32 cols per slot,
    /// plus one i32 row per segment).
    pub fn bytes(&self) -> usize {
        self.segs * self.cap * (4 + 4) + self.segs * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn small_example_splits_heavy_row() {
        // Row 0 holds 5 entries at cap 2 → segments of 2+2+1; row 2 holds 1.
        let mut a = Mat::zeros(3, 8);
        for j in 0..5 {
            a[(0, j)] = (j + 1) as f32;
        }
        a[(2, 6)] = 9.0;
        let rs = RowSplit::from_dense(&a, 2).unwrap();
        assert_eq!(rs.seg_rows, vec![0, 0, 0, 2]);
        assert_eq!(rs.seg_len, vec![2, 2, 1, 1]);
        let s1: Vec<_> = rs.segment(1).collect();
        assert_eq!(s1, vec![(2, 3.0), (3, 4.0)]);
        rs.validate().unwrap();
        assert_eq!(rs.to_dense(), a);
    }

    #[test]
    fn zero_capacity_is_invalid() {
        let a = Mat::eye(4);
        assert!(RowSplit::from_dense(&a, 0).is_err());
    }

    #[test]
    fn round_trip_power_law() {
        let mut rng = Rng::new(41);
        let a = gen::power_law_rows(64, 0.9, &mut rng);
        for cap in [1, 4, 64] {
            let rs = RowSplit::from_dense(&a, cap).unwrap();
            rs.validate().unwrap();
            assert_eq!(rs.to_dense(), a, "cap {cap}");
            // Work per segment is bounded no matter the skew.
            assert!(rs.seg_len.iter().all(|&l| l as usize <= cap));
        }
    }

    #[test]
    fn segment_count_is_sum_of_row_ceils() {
        let mut rng = Rng::new(42);
        let a = gen::power_law_rows(32, 0.9, &mut rng);
        let cap = 4;
        let rs = RowSplit::from_dense(&a, cap).unwrap();
        let expect: usize = (0..32)
            .map(|i| a.row(i).iter().filter(|v| **v != 0.0).count().div_ceil(cap))
            .sum();
        assert_eq!(rs.num_segments(), expect);
    }

    #[test]
    fn pad_and_slab_round_trip() {
        let mut rng = Rng::new(43);
        let a = gen::uniform(32, 0.9, &mut rng);
        let rs = RowSplit::from_dense(&a, 4).unwrap();
        let padded = rs.pad();
        assert_eq!(padded.vals.len(), padded.segs * padded.cap);
        assert_eq!(padded.seg_rows.len(), padded.segs);
        // Densify the padded form and compare.
        let mut m = Mat::zeros(32, 32);
        for s in 0..padded.segs {
            for k in 0..padded.cap {
                let v = padded.vals[s * padded.cap + k];
                if v != 0.0 {
                    m[(padded.seg_rows[s] as usize, padded.cols[s * padded.cap + k] as usize)] += v;
                }
            }
        }
        assert_eq!(m, a);
    }

    #[test]
    fn repad_resegments_bitwise() {
        let mut rng = Rng::new(44);
        let a = gen::power_law_rows(48, 0.92, &mut rng);
        let rs = RowSplit::from_dense(&a, 3).unwrap();
        let padded = rs.pad();
        // Repadding to another capacity matches building at that capacity
        // directly — per-row order survives re-segmentation.
        for cap in [1, 2, 5, 64] {
            let direct = RowSplit::from_dense(&a, cap).unwrap().pad();
            assert_eq!(padded.as_slabs().repad(cap), direct, "cap {cap}");
        }
        // And back to the original capacity is the identity.
        assert_eq!(padded.as_slabs().repad(3), padded);
    }

    #[test]
    fn slab_views_borrow_without_copying() {
        let mut rng = Rng::new(45);
        let a = gen::uniform(32, 0.9, &mut rng);
        let padded = RowSplit::from_dense(&a, 8).unwrap().pad();
        let slabs = padded.as_slabs();
        assert!(std::ptr::eq(slabs.vals.as_ptr(), padded.vals.as_ptr()));
        assert_eq!(slabs.bytes(), padded.segs * padded.cap * 8 + padded.segs * 4);
    }

    #[test]
    fn validate_catches_unsorted_and_nonfull_continuation() {
        let mut rng = Rng::new(46);
        let a = gen::uniform(16, 0.5, &mut rng);
        let mut rs = RowSplit::from_dense(&a, 4).unwrap();
        // Swap two entries inside the first multi-entry segment.
        let s = rs.seg_len.iter().position(|&l| l >= 2).unwrap();
        let lo: usize = rs.seg_len[..s].iter().map(|&l| l as usize).sum();
        rs.cols.swap(lo, lo + 1);
        rs.vals.swap(lo, lo + 1);
        assert!(rs.validate().is_err());
    }
}
