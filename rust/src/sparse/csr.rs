//! CSR — compressed sparse row (the format cuSPARSE's csrmm consumes).

use super::{Coo, FormatError, ToDense};
use crate::ndarray::Mat;

/// Compressed sparse row: `row_ptr` has `n_rows + 1` entries;
/// row `i`'s entries live at `row_ptr[i]..row_ptr[i+1]`, column-sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn from_dense(a: &Mat) -> Self {
        let mut row_ptr = Vec::with_capacity(a.rows + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..a.rows {
            for (j, &v) in a.row(i).iter().enumerate() {
                if v != 0.0 {
                    cols.push(j as u32);
                    vals.push(v);
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        Csr { n_rows: a.rows, n_cols: a.cols, row_ptr, cols, vals }
    }

    /// COO (canonical order) → CSR in one pass.
    pub fn from_coo(coo: &Coo) -> Self {
        let mut row_ptr = vec![0u32; coo.n_rows + 1];
        for &r in &coo.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..coo.n_rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr {
            n_rows: coo.n_rows,
            n_cols: coo.n_cols,
            row_ptr,
            cols: coo.cols.clone(),
            vals: coo.vals.clone(),
        }
    }

    pub fn to_coo(&self) -> Coo {
        let mut rows = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            for _ in self.row_range(i) {
                rows.push(i as u32);
            }
        }
        Coo {
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            rows,
            cols: self.cols.clone(),
            vals: self.vals.clone(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize
    }

    /// Entries of row `i` as (col, val) pairs.
    pub fn row_entries(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.row_range(i).map(move |k| (self.cols[k], self.vals[k]))
    }

    /// Max nonzeros in any row — determines the ELL rowcap the artifact needs.
    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_range(i).len()).max().unwrap_or(0)
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        if self.row_ptr.len() != self.n_rows + 1 {
            return Err(FormatError::Invalid("row_ptr length".into()));
        }
        if self.row_ptr[0] != 0 || *self.row_ptr.last().unwrap() as usize != self.nnz() {
            return Err(FormatError::Invalid("row_ptr endpoints".into()));
        }
        if self.row_ptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(FormatError::Invalid("row_ptr not monotone".into()));
        }
        for i in 0..self.n_rows {
            let r = self.row_range(i);
            let cols = &self.cols[r];
            if cols.iter().any(|&c| c as usize >= self.n_cols) {
                return Err(FormatError::Invalid(format!("row {i}: col out of range")));
            }
            if cols.windows(2).any(|w| w[0] >= w[1]) {
                return Err(FormatError::Invalid(format!("row {i}: cols not sorted")));
            }
        }
        Ok(())
    }
}

impl ToDense for Csr {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for i in 0..self.n_rows {
            for (c, v) in self.row_entries(i) {
                m[(i, c as usize)] += v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn round_trip_dense() {
        let mut rng = Rng::new(2);
        let a = gen::uniform(40, 0.9, &mut rng);
        let csr = Csr::from_dense(&a);
        assert_eq!(csr.to_dense(), a);
        csr.validate().unwrap();
    }

    #[test]
    fn coo_csr_coo_identity() {
        let mut rng = Rng::new(3);
        let a = gen::uniform(32, 0.7, &mut rng);
        let coo = Coo::from_dense(&a);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.to_coo(), coo);
    }

    #[test]
    fn row_entries_and_max_row_nnz() {
        #[rustfmt::skip]
        let a = Mat::from_vec(3, 4, vec![
            1.0, 0.0, 2.0, 0.0,
            0.0, 0.0, 0.0, 0.0,
            3.0, 4.0, 5.0, 0.0,
        ]);
        let csr = Csr::from_dense(&a);
        assert_eq!(csr.row_entries(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(csr.row_entries(1).count(), 0);
        assert_eq!(csr.max_row_nnz(), 3);
    }

    #[test]
    fn empty_rows_handled() {
        let csr = Csr::from_dense(&Mat::zeros(5, 5));
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.max_row_nnz(), 0);
        csr.validate().unwrap();
    }

    #[test]
    fn validate_catches_bad_row_ptr() {
        let mut csr = Csr::from_dense(&Mat::eye(4));
        csr.row_ptr[2] = 5;
        assert!(csr.validate().is_err());
    }
}
