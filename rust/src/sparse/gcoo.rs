//! GCOO — the paper's grouped COO format (§III-A) plus the padded device
//! forms (`GcooPadded`, `Ell`) whose layouts match the AOT kernel inputs.

use super::{Csr, FormatError, ToDense};
use crate::ndarray::Mat;

/// Grouped COO. Groups are bands of `p` consecutive rows (DESIGN.md §3);
/// per-group COO entries are stored *concatenated* exactly as the paper
/// lays them out: `vals/rows/cols` plus `g_idxes` (start offset of each
/// group) and `nnz_per_group`. Row indices are band-local (`0..p`); within
/// a band entries are sorted by `(col, row)` — the order the bv-reuse scan
/// of Algorithm 2 depends on.
#[derive(Clone, Debug, PartialEq)]
pub struct Gcoo {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Band height (the paper's p).
    pub p: usize,
    pub vals: Vec<f32>,
    /// Band-local row index of each entry (0..p).
    pub rows: Vec<u32>,
    /// Absolute column index of each entry.
    pub cols: Vec<u32>,
    /// Start offset of each group in the concatenated arrays (paper gIdxes).
    pub g_idxes: Vec<u32>,
    /// Nonzeros per group (paper nnzPerGroup).
    pub nnz_per_group: Vec<u32>,
}

impl Gcoo {
    /// Number of groups g = ceil(n_rows / p) (paper uses floor((n+p-1)/p)).
    pub fn num_groups(&self) -> usize {
        self.n_rows.div_ceil(self.p)
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Paper Algorithm 1 (single-threaded reference; the parallel version
    /// lives in crate::convert). Step 1 counts per-group nonzeros and fills
    /// `g_idxes`/`nnz_per_group`; step 2 scatters entries into place.
    pub fn from_dense(a: &Mat, p: usize) -> Self {
        assert!(p > 0);
        let g = a.rows.div_ceil(p);
        // Step 1: count nnz per group.
        let mut nnz_per_group = vec![0u32; g];
        for i in 0..a.rows {
            let band = i / p;
            nnz_per_group[band] += a.row(i).iter().filter(|v| **v != 0.0).count() as u32;
        }
        let mut g_idxes = vec![0u32; g];
        for gi in 1..g {
            g_idxes[gi] = g_idxes[gi - 1] + nnz_per_group[gi - 1];
        }
        let total: usize = nnz_per_group.iter().map(|&x| x as usize).sum();
        // Step 2: allocate and fill, sorted by (col, row) within each band.
        let mut vals = vec![0.0f32; total];
        let mut rows = vec![0u32; total];
        let mut cols = vec![0u32; total];
        for gi in 0..g {
            let lo = gi * p;
            let hi = ((gi + 1) * p).min(a.rows);
            // Column-major walk over the band gives (col, row) order directly.
            let mut k = g_idxes[gi] as usize;
            for j in 0..a.cols {
                for i in lo..hi {
                    let v = a[(i, j)];
                    if v != 0.0 {
                        vals[k] = v;
                        rows[k] = (i - lo) as u32;
                        cols[k] = j as u32;
                        k += 1;
                    }
                }
            }
            debug_assert_eq!(k, (g_idxes[gi] + nnz_per_group[gi]) as usize);
        }
        Gcoo { n_rows: a.rows, n_cols: a.cols, p, vals, rows, cols, g_idxes, nnz_per_group }
    }

    /// CSR → GCOO without densifying (bucket rows into bands, sort each band).
    pub fn from_csr(csr: &Csr, p: usize) -> Self {
        assert!(p > 0);
        let g = csr.n_rows.div_ceil(p);
        let mut nnz_per_group = vec![0u32; g];
        for i in 0..csr.n_rows {
            nnz_per_group[i / p] += csr.row_range(i).len() as u32;
        }
        let mut g_idxes = vec![0u32; g];
        for gi in 1..g {
            g_idxes[gi] = g_idxes[gi - 1] + nnz_per_group[gi - 1];
        }
        let total = csr.nnz();
        let mut entries: Vec<(u32, u32, f32)> = Vec::with_capacity(total);
        let mut vals = Vec::with_capacity(total);
        let mut rows = Vec::with_capacity(total);
        let mut cols = Vec::with_capacity(total);
        for gi in 0..g {
            entries.clear();
            let lo = gi * p;
            let hi = ((gi + 1) * p).min(csr.n_rows);
            for i in lo..hi {
                for (c, v) in csr.row_entries(i) {
                    entries.push((c, (i - lo) as u32, v));
                }
            }
            entries.sort_by_key(|&(c, r, _)| (c, r));
            for &(c, r, v) in &entries {
                vals.push(v);
                rows.push(r);
                cols.push(c);
            }
        }
        Gcoo {
            n_rows: csr.n_rows,
            n_cols: csr.n_cols,
            p,
            vals,
            rows,
            cols,
            g_idxes,
            nnz_per_group,
        }
    }

    /// Group `gi`'s entries as (band-local row, col, val).
    pub fn group(&self, gi: usize) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        let lo = self.g_idxes[gi] as usize;
        let hi = lo + self.nnz_per_group[gi] as usize;
        (lo..hi).map(move |k| (self.rows[k], self.cols[k], self.vals[k]))
    }

    /// Largest per-group nnz — the capacity the padded device form needs.
    pub fn max_group_nnz(&self) -> usize {
        self.nnz_per_group.iter().copied().max().unwrap_or(0) as usize
    }

    /// Count of same-column adjacent pairs — the paper's reuse opportunity
    /// metric ("(1−s)·n nonzeros share a column"); drives the autotuner and
    /// explains Fig 5's diagonal-matrix losses.
    pub fn reuse_pairs(&self) -> usize {
        let mut pairs = 0;
        for gi in 0..self.num_groups() {
            let lo = self.g_idxes[gi] as usize;
            let hi = lo + self.nnz_per_group[gi] as usize;
            for k in lo + 1..hi {
                if self.cols[k] == self.cols[k - 1] {
                    pairs += 1;
                }
            }
        }
        pairs
    }

    pub fn validate(&self) -> Result<(), FormatError> {
        let g = self.num_groups();
        if self.g_idxes.len() != g || self.nnz_per_group.len() != g {
            return Err(FormatError::Invalid("group array lengths".into()));
        }
        let total: usize = self.nnz_per_group.iter().map(|&x| x as usize).sum();
        if total != self.nnz() {
            return Err(FormatError::Invalid("nnz_per_group sum != nnz".into()));
        }
        for gi in 0..g {
            let expect = if gi == 0 {
                0
            } else {
                self.g_idxes[gi - 1] + self.nnz_per_group[gi - 1]
            };
            if self.g_idxes[gi] != expect {
                return Err(FormatError::Invalid(format!("g_idxes[{gi}] != prefix sum")));
            }
            let band_rows = ((gi + 1) * self.p).min(self.n_rows) - gi * self.p;
            let mut prev: Option<(u32, u32)> = None;
            for (r, c, _v) in self.group(gi) {
                if r as usize >= band_rows || c as usize >= self.n_cols {
                    return Err(FormatError::Invalid(format!("group {gi}: entry out of range")));
                }
                if let Some(p) = prev {
                    if (c, r) <= p {
                        return Err(FormatError::Invalid(format!(
                            "group {gi}: not (col,row)-sorted"
                        )));
                    }
                }
                prev = Some((c, r));
            }
        }
        Ok(())
    }

    /// Pad to the device layout the `gcoo_*` artifacts expect.
    pub fn pad(&self, cap: usize) -> Result<GcooPadded, FormatError> {
        let need = self.max_group_nnz();
        if need > cap {
            return Err(FormatError::CapacityExceeded {
                which: "gcoo band".into(),
                needed: need,
                cap,
            });
        }
        let g = self.num_groups();
        let mut vals = vec![0.0f32; g * cap];
        let mut rows = vec![0i32; g * cap];
        let mut cols = vec![0i32; g * cap];
        for gi in 0..g {
            for (k, (r, c, v)) in self.group(gi).enumerate() {
                vals[gi * cap + k] = v;
                rows[gi * cap + k] = r as i32;
                cols[gi * cap + k] = c as i32;
            }
        }
        Ok(GcooPadded { g, cap, p: self.p, n: self.n_cols, vals, rows, cols })
    }
}

impl ToDense for Gcoo {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n_rows, self.n_cols);
        for gi in 0..self.num_groups() {
            for (r, c, v) in self.group(gi) {
                m[(gi * self.p + r as usize, c as usize)] += v;
            }
        }
        m
    }
}

/// Device-layout GCOO: `(g, cap)` row-major slabs, zero padded — byte-for-
/// byte the arrays fed to the `gcoo_*` PJRT executables.
#[derive(Clone, Debug, PartialEq)]
pub struct GcooPadded {
    pub g: usize,
    pub cap: usize,
    pub p: usize,
    pub n: usize,
    pub vals: Vec<f32>,
    pub rows: Vec<i32>,
    pub cols: Vec<i32>,
}

impl GcooPadded {
    /// Borrow the slabs as the view the engine consumes (no copy).
    pub fn as_slabs(&self) -> GcooSlabs<'_> {
        GcooSlabs {
            g: self.g,
            cap: self.cap,
            p: self.p,
            n: self.n,
            vals: &self.vals,
            rows: &self.rows,
            cols: &self.cols,
        }
    }
}

/// Borrowed view of device-layout GCOO slabs — what the engine kernels
/// actually consume. Obtained from [`GcooPadded::as_slabs`], or built
/// directly over per-worker workspace buffers so the matching-capacity
/// serving path executes with zero slab copies.
#[derive(Clone, Copy, Debug)]
pub struct GcooSlabs<'a> {
    pub g: usize,
    pub cap: usize,
    pub p: usize,
    pub n: usize,
    pub vals: &'a [f32],
    pub rows: &'a [i32],
    pub cols: &'a [i32],
}

impl GcooSlabs<'_> {
    /// Re-pad to a different band capacity, producing owned slabs. Growing
    /// zero-fills the new tail of every band; shrinking keeps each band's
    /// `cap`-prefix (lossless whenever the band's nnz fit the new capacity,
    /// which the engine guarantees by selecting `cap ≥` the provided one).
    pub fn repad(&self, cap: usize) -> GcooPadded {
        let mut vals = vec![0.0f32; self.g * cap];
        let mut rows = vec![0i32; self.g * cap];
        let mut cols = vec![0i32; self.g * cap];
        let copy = self.cap.min(cap);
        for gi in 0..self.g {
            vals[gi * cap..gi * cap + copy]
                .copy_from_slice(&self.vals[gi * self.cap..gi * self.cap + copy]);
            rows[gi * cap..gi * cap + copy]
                .copy_from_slice(&self.rows[gi * self.cap..gi * self.cap + copy]);
            cols[gi * cap..gi * cap + copy]
                .copy_from_slice(&self.cols[gi * self.cap..gi * self.cap + copy]);
        }
        GcooPadded { g: self.g, cap, p: self.p, n: self.n, vals, rows, cols }
    }

    /// Total slab bytes at this geometry (f32 vals + i32 rows + i32 cols).
    pub fn bytes(&self) -> usize {
        self.g * self.cap * (4 + 4 + 4)
    }
}

/// Device-layout padded CSR (ELL): `(n, rowcap)` slabs for the `csr_*`
/// artifacts.
#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    pub n: usize,
    pub rowcap: usize,
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
}

/// Borrowed view of ELL slabs (CSR-path analog of [`GcooSlabs`]).
#[derive(Clone, Copy, Debug)]
pub struct EllSlabs<'a> {
    pub n: usize,
    pub rowcap: usize,
    pub vals: &'a [f32],
    pub cols: &'a [i32],
}

impl EllSlabs<'_> {
    /// Re-pad to a different row capacity, producing an owned `Ell`.
    pub fn repad(&self, rowcap: usize) -> Ell {
        let mut vals = vec![0.0f32; self.n * rowcap];
        let mut cols = vec![0i32; self.n * rowcap];
        let copy = self.rowcap.min(rowcap);
        for i in 0..self.n {
            vals[i * rowcap..i * rowcap + copy]
                .copy_from_slice(&self.vals[i * self.rowcap..i * self.rowcap + copy]);
            cols[i * rowcap..i * rowcap + copy]
                .copy_from_slice(&self.cols[i * self.rowcap..i * self.rowcap + copy]);
        }
        Ell { n: self.n, rowcap, vals, cols }
    }

    /// Total slab bytes at this geometry (f32 vals + i32 cols).
    pub fn bytes(&self) -> usize {
        self.n * self.rowcap * (4 + 4)
    }
}

impl Ell {
    /// Borrow the slabs as the view the engine consumes (no copy).
    pub fn as_slabs(&self) -> EllSlabs<'_> {
        EllSlabs { n: self.n, rowcap: self.rowcap, vals: &self.vals, cols: &self.cols }
    }

    pub fn from_csr(csr: &Csr, rowcap: usize) -> Result<Self, FormatError> {
        let need = csr.max_row_nnz();
        if need > rowcap {
            return Err(FormatError::CapacityExceeded {
                which: "ell row".into(),
                needed: need,
                cap: rowcap,
            });
        }
        let n = csr.n_rows;
        let mut vals = vec![0.0f32; n * rowcap];
        let mut cols = vec![0i32; n * rowcap];
        for i in 0..n {
            for (k, (c, v)) in csr.row_entries(i).enumerate() {
                vals[i * rowcap + k] = v;
                cols[i * rowcap + k] = c as i32;
            }
        }
        Ok(Ell { n, rowcap, vals, cols })
    }
}

impl ToDense for Ell {
    fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.n, self.n);
        for i in 0..self.n {
            for k in 0..self.rowcap {
                let v = self.vals[i * self.rowcap + k];
                if v != 0.0 {
                    m[(i, self.cols[i * self.rowcap + k] as usize)] += v;
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Rng;

    #[test]
    fn paper_fig2_example_rowband_reading() {
        // The paper's 4x4 example, grouped with p=2 under the row-band
        // reading (DESIGN.md §3): band 0 = rows {0,1}, band 1 = rows {2,3}.
        #[rustfmt::skip]
        let a = Mat::from_vec(4, 4, vec![
            7.0, 0.0, 0.0, 8.0,
            0.0, 10.0, 0.0, 0.0,
            9.0, 0.0, 0.0, 0.0,
            0.0, 0.0, 6.0, 3.0,
        ]);
        let gcoo = Gcoo::from_dense(&a, 2);
        assert_eq!(gcoo.num_groups(), 2);
        assert_eq!(gcoo.nnz_per_group, vec![3, 3]);
        assert_eq!(gcoo.g_idxes, vec![0, 3]);
        // band 0 sorted by (col,row): (0,r0,7), (1,r1,10), (3,r0,8)
        let g0: Vec<_> = gcoo.group(0).collect();
        assert_eq!(g0, vec![(0, 0, 7.0), (1, 1, 10.0), (0, 3, 8.0)]);
        // band 1: (0,r0,9), (2,r1,6), (3,r1,3)
        let g1: Vec<_> = gcoo.group(1).collect();
        assert_eq!(g1, vec![(0, 0, 9.0), (1, 2, 6.0), (1, 3, 3.0)]);
        gcoo.validate().unwrap();
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn round_trip_uniform() {
        let mut rng = Rng::new(4);
        let a = gen::uniform(64, 0.9, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        gcoo.validate().unwrap();
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn from_csr_matches_from_dense() {
        let mut rng = Rng::new(5);
        let a = gen::uniform(48, 0.8, &mut rng);
        let via_dense = Gcoo::from_dense(&a, 8);
        let via_csr = Gcoo::from_csr(&Csr::from_dense(&a), 8);
        assert_eq!(via_dense, via_csr);
    }

    #[test]
    fn p_not_dividing_n_rows() {
        let mut rng = Rng::new(6);
        let a = gen::uniform(30, 0.7, &mut rng); // 30 rows, p=8 -> last band 6 rows
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(gcoo.num_groups(), 4);
        gcoo.validate().unwrap();
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn pad_round_trip_and_capacity() {
        let mut rng = Rng::new(7);
        let a = gen::uniform(32, 0.9, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz()).unwrap();
        assert_eq!(padded.vals.len(), padded.g * padded.cap);
        assert!(gcoo.pad(gcoo.max_group_nnz().saturating_sub(1)).is_err());
    }

    #[test]
    fn reuse_pairs_dense_column() {
        // A single dense column inside one band: p nonzeros, p-1 reuse pairs.
        let mut a = Mat::zeros(8, 8);
        for i in 0..8 {
            a[(i, 3)] = 1.0;
        }
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(gcoo.reuse_pairs(), 7);
        // Diagonal: no two entries share a column at all.
        let diag = Gcoo::from_dense(&Mat::eye(8), 8);
        assert_eq!(diag.reuse_pairs(), 0);
    }

    #[test]
    fn ell_round_trip_and_capacity() {
        let mut rng = Rng::new(8);
        let a = gen::uniform(32, 0.85, &mut rng);
        let csr = Csr::from_dense(&a);
        let ell = Ell::from_csr(&csr, csr.max_row_nnz()).unwrap();
        assert_eq!(ell.to_dense(), a);
        assert!(Ell::from_csr(&csr, csr.max_row_nnz().saturating_sub(1)).is_err());
    }

    #[test]
    fn ragged_last_band_prefix_and_intra_band_order() {
        let mut rng = Rng::new(21);
        let a = gen::uniform(30, 0.7, &mut rng); // 30 rows, p=8 -> last band 6 rows
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(gcoo.num_groups(), 4);
        // g_idxes is exactly the exclusive prefix sum of nnz_per_group.
        let mut expect = 0u32;
        for gi in 0..4 {
            assert_eq!(gcoo.g_idxes[gi], expect, "g_idxes[{gi}]");
            expect += gcoo.nnz_per_group[gi];
        }
        assert_eq!(expect as usize, gcoo.nnz());
        // Entries stay inside their band and are strictly (col, row)-sorted.
        for gi in 0..4 {
            let band_rows = if gi == 3 { 6 } else { 8 };
            let entries: Vec<_> = gcoo.group(gi).collect();
            assert!(entries.iter().all(|e| (e.0 as usize) < band_rows), "band {gi} row range");
            for w in entries.windows(2) {
                assert!((w[0].1, w[0].0) < (w[1].1, w[1].0), "band {gi} not (col,row)-sorted");
            }
        }
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn all_zero_band_yields_empty_group() {
        // Rows 8..16 stay zero: the middle band must become an empty group
        // that the prefix structure simply skips over.
        let mut a = Mat::zeros(24, 24);
        let mut rng = Rng::new(22);
        for i in (0..8).chain(16..24) {
            for j in 0..24 {
                if rng.coin(0.3) {
                    a[(i, j)] = rng.nonzero_value();
                }
            }
        }
        assert!(a.nnz() > 0);
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(gcoo.num_groups(), 3);
        assert_eq!(gcoo.nnz_per_group[1], 0, "middle band must be empty");
        assert_eq!(gcoo.g_idxes[1], gcoo.g_idxes[2], "empty group spans no entries");
        assert_eq!(gcoo.group(1).count(), 0);
        gcoo.validate().unwrap();
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn single_column_matrix() {
        // One column: every entry has col 0, so (col,row) order reduces to
        // ascending band-local rows.
        let mut a = Mat::zeros(20, 1);
        for i in [0usize, 3, 7, 8, 12, 19] {
            a[(i, 0)] = (i + 1) as f32;
        }
        let gcoo = Gcoo::from_dense(&a, 8);
        assert_eq!(gcoo.num_groups(), 3);
        assert_eq!(gcoo.nnz_per_group, vec![3, 2, 1]);
        assert_eq!(gcoo.g_idxes, vec![0, 3, 5]);
        assert!(gcoo.cols.iter().all(|&c| c == 0));
        for gi in 0..3 {
            let rows: Vec<u32> = gcoo.group(gi).map(|e| e.0).collect();
            assert!(rows.windows(2).all(|w| w[0] < w[1]), "band {gi} rows not ascending");
        }
        gcoo.validate().unwrap();
        assert_eq!(gcoo.to_dense(), a);
    }

    #[test]
    fn slab_repad_grows_and_shrinks_consistently() {
        let p = GcooPadded {
            g: 2,
            cap: 2,
            p: 2,
            n: 4,
            vals: vec![1.0, 2.0, 3.0, 4.0],
            rows: vec![0, 1, 0, 1],
            cols: vec![0, 1, 2, 3],
        };
        let grown = p.as_slabs().repad(3);
        assert_eq!(grown.vals, vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0]);
        assert_eq!(grown.rows, vec![0, 1, 0, 0, 1, 0]);
        assert_eq!(grown.cols, vec![0, 1, 0, 2, 3, 0]);
        // Shrinking back to the original capacity restores the original.
        assert_eq!(grown.as_slabs().repad(2), p);
    }

    #[test]
    fn ell_slab_repad_grows() {
        let e = Ell { n: 2, rowcap: 1, vals: vec![5.0, 6.0], cols: vec![1, 0] };
        let grown = e.as_slabs().repad(2);
        assert_eq!(grown.vals, vec![5.0, 0.0, 6.0, 0.0]);
        assert_eq!(grown.cols, vec![1, 0, 0, 0]);
        assert_eq!(grown.as_slabs().repad(1), e);
    }

    #[test]
    fn slab_views_borrow_without_copying() {
        let mut rng = Rng::new(11);
        let a = gen::uniform(32, 0.9, &mut rng);
        let gcoo = Gcoo::from_dense(&a, 8);
        let padded = gcoo.pad(gcoo.max_group_nnz().max(1)).unwrap();
        let slabs = padded.as_slabs();
        assert!(std::ptr::eq(slabs.vals.as_ptr(), padded.vals.as_ptr()));
        assert_eq!(slabs.bytes(), padded.g * padded.cap * 12);
    }

    #[test]
    fn validate_catches_broken_prefix() {
        let mut rng = Rng::new(9);
        let a = gen::uniform(32, 0.8, &mut rng);
        let mut gcoo = Gcoo::from_dense(&a, 8);
        gcoo.g_idxes[1] += 1;
        assert!(gcoo.validate().is_err());
    }
}
