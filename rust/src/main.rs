//! gcoospdm CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info      — artifact registry + device table
//!   run       — one SpDM through the full stack (convert→select→PJRT)
//!   serve     — start the TCP serving loop
//!   client    — drive a running server with synthetic requests
//!   simulate  — simgpu report for one (n, sparsity, pattern, device)
//!   autotune  — tune (p, b) for a matrix spec
//!   figures   — regenerate paper tables/figures (--fig 1|4|5|6|7|10|13|14|15|table1|all)

use std::sync::Arc;

use gcoospdm::cli::{self, FlagSpec};
use gcoospdm::coordinator::{Algo, Coordinator, CoordinatorConfig, SpdmRequest};
use gcoospdm::gen;
use gcoospdm::ndarray::Mat;
use gcoospdm::rng::Rng;
use gcoospdm::runtime::Registry;
use gcoospdm::serve::{Client, Server, ServerConfig};
use gcoospdm::simgpu::{self, WalkConfig};
use gcoospdm::sparse::Gcoo;
use gcoospdm::{autotune, figures};

const SUBCOMMANDS: [(&str, &str); 7] = [
    ("info", "artifact registry + simulated device table"),
    ("run", "run one SpDM end to end through PJRT"),
    ("serve", "start the TCP serving loop"),
    ("client", "send synthetic requests to a server"),
    ("simulate", "simgpu kernel report"),
    ("autotune", "tune (p, b) for a matrix spec"),
    ("figures", "regenerate paper tables/figures"),
];

fn flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "artifacts", takes_value: true, help: "artifacts dir (default artifacts)" },
        FlagSpec { name: "n", takes_value: true, help: "matrix dimension" },
        FlagSpec { name: "sparsity", takes_value: true, help: "sparsity in [0,1)" },
        FlagSpec { name: "pattern", takes_value: true, help: "uniform|diagonal|banded|block_diagonal|power_law_rows|dense_columns" },
        FlagSpec { name: "seed", takes_value: true, help: "rng seed" },
        FlagSpec { name: "algo", takes_value: true, help: "auto|gcoo|gcoo_noreuse|csr|dense_xla|dense_pallas" },
        FlagSpec { name: "verify", takes_value: false, help: "check against CPU oracle" },
        FlagSpec { name: "addr", takes_value: true, help: "server address (default 127.0.0.1:7077)" },
        FlagSpec { name: "workers", takes_value: true, help: "coordinator workers" },
        FlagSpec { name: "count", takes_value: true, help: "request / corpus count" },
        FlagSpec { name: "device", takes_value: true, help: "GTX980|TitanX|P100" },
        FlagSpec { name: "fig", takes_value: true, help: "figure id or 'all'" },
        FlagSpec { name: "max-n", takes_value: true, help: "scale cap for corpus figures" },
        FlagSpec { name: "full", takes_value: false, help: "paper-scale corpus sizes" },
        FlagSpec { name: "config", takes_value: true, help: "TOML config file (serve)" },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(&argv, &flags()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", cli::usage("gcoospdm", &SUBCOMMANDS, &flags()));
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "simulate" => cmd_simulate(&args),
        "autotune" => cmd_autotune(&args),
        "figures" => cmd_figures(&args),
        "" => {
            println!("{}", cli::usage("gcoospdm", &SUBCOMMANDS, &flags()));
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_registry(args: &cli::Args) -> Result<Registry, String> {
    Registry::load(args.get_str("artifacts", "artifacts")).map_err(|e| e.to_string())
}

fn device(args: &cli::Args) -> Result<&'static simgpu::DeviceConfig, String> {
    match args.get_str("device", "TitanX").as_str() {
        "GTX980" => Ok(&simgpu::GTX980),
        "TitanX" => Ok(&simgpu::TITANX),
        "P100" => Ok(&simgpu::P100),
        other => Err(format!("unknown device {other}")),
    }
}

fn gen_matrix(args: &cli::Args) -> Result<(Mat, Mat, usize, f64), String> {
    let n = args.get_usize("n", 512)?;
    let sparsity = args.get_f64("sparsity", 0.99)?;
    let seed = args.get_u64("seed", 42)?;
    let pattern = gen::Pattern::from_name(&args.get_str("pattern", "uniform"))
        .ok_or("unknown pattern")?;
    let mut rng = Rng::new(seed);
    let a = gen::generate(pattern, n, sparsity, &mut rng);
    let b = Mat::randn(n, n, &mut rng);
    Ok((a, b, n, sparsity))
}

fn cmd_info(args: &cli::Args) -> Result<(), String> {
    let reg = load_registry(args)?;
    println!("artifacts dir: {}", reg.dir.display());
    println!("{:<40} {:>6} {:>10}", "name", "n", "capacity");
    for a in &reg.artifacts {
        println!(
            "{:<40} {:>6} {:>10}",
            a.name,
            a.n,
            a.capacity().map(|c| c.to_string()).unwrap_or_else(|| "-".into())
        );
    }
    println!("\nsimulated devices (paper Table II):");
    println!("{:<8} {:>4}x{:<4} {:>8} {:>10}", "name", "SMs", "cores", "TFLOPS", "GB/s");
    for d in simgpu::ALL_DEVICES {
        println!(
            "{:<8} {:>4}x{:<4} {:>8.2} {:>10.0}",
            d.name, d.sms, d.cores_per_sm, d.peak_tflops, d.mem_bw_gbps
        );
    }
    Ok(())
}

fn cmd_run(args: &cli::Args) -> Result<(), String> {
    let reg = Arc::new(load_registry(args)?);
    let (a, b, n, sparsity) = gen_matrix(args)?;
    let algo = match args.get_str("algo", "auto").as_str() {
        "auto" => None,
        s => Some(Algo::from_str(s).ok_or_else(|| format!("unknown algo {s}"))?),
    };
    let coord = Coordinator::new(reg, CoordinatorConfig::default());
    let mut req = SpdmRequest::new(1, a, b);
    req.algo_hint = algo;
    req.verify = args.has("verify");
    let resp = coord.run_sync(req);
    match &resp.error {
        Some(e) => return Err(e.clone()),
        None => {
            println!(
                "n={n} sparsity={sparsity:.4} → algo={} artifact={} n_exec={}",
                resp.algo.as_str(),
                resp.artifact,
                resp.n_exec
            );
            println!(
                "convert {:.3} ms | kernel {:.3} ms | total {:.3} ms | verified: {:?}",
                resp.convert_s * 1e3,
                resp.kernel_s * 1e3,
                resp.total_s * 1e3,
                resp.verified
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    // Precedence: --config file < explicit flags < built-in defaults.
    let mut sys = match args.get("config") {
        Some(path) => gcoospdm::config::SystemConfig::from_file(path)?,
        None => gcoospdm::config::SystemConfig::default(),
    };
    if let Some(addr) = args.get("addr") {
        sys.server_addr = addr.to_string();
    }
    if let Some(w) = args.get("workers") {
        sys.coordinator.workers = w.parse().map_err(|_| "--workers: bad integer")?;
    }
    if let Some(dir) = args.get("artifacts") {
        sys.artifacts_dir = dir.to_string();
    }
    let reg = Arc::new(Registry::load(&sys.artifacts_dir).map_err(|e| e.to_string())?);
    let coord = Arc::new(Coordinator::new(reg, sys.coordinator));
    let scfg = ServerConfig { addr: sys.server_addr.clone() };
    let server = Server::bind(&scfg, coord).map_err(|e| e.to_string())?;
    println!("serving on {}", server.local_addr().map_err(|e| e.to_string())?);
    server.run().map_err(|e| e.to_string())
}

fn cmd_client(args: &cli::Args) -> Result<(), String> {
    let addr = args.get_str("addr", "127.0.0.1:7077");
    let count = args.get_usize("count", 8)?;
    let n = args.get_usize("n", 256)?;
    let sparsity = args.get_f64("sparsity", 0.99)?;
    let mut client = Client::connect(&addr).map_err(|e| e.to_string())?;
    for i in 0..count {
        let r = client.spdm_synthetic(
            i as u64,
            n,
            sparsity,
            &args.get_str("pattern", "uniform"),
            args.get_u64("seed", 1)? + i as u64,
            &args.get_str("algo", "auto"),
            args.has("verify"),
        )?;
        println!(
            "req {}: ok={} algo={:?} kernel {:?} ms total {:?} ms verified={:?}",
            i, r.ok, r.algo, r.kernel_ms, r.total_ms, r.verified
        );
    }
    let m = client.metrics(9999)?;
    println!("\nserver metrics:\n{}", m.metrics.unwrap_or_default());
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<(), String> {
    let dev = device(args)?;
    let (a, _b, n, sparsity) = gen_matrix(args)?;
    let gcoo = Gcoo::from_dense(&a, 8);
    let reports = simgpu::simulate_all(&gcoo, dev, &WalkConfig::default());
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "algo", "dram", "l2", "shm", "l1_tex", "time_ms", "eff_gflops"
    );
    for r in reports {
        println!(
            "{:<8} {:>12} {:>12} {:>12} {:>12} {:>12.4} {:>10.2}",
            r.algo,
            r.counters.dram,
            r.counters.l2,
            r.counters.shm,
            r.counters.l1_tex,
            r.time_s() * 1e3,
            r.effective_gflops(n, sparsity)
        );
    }
    Ok(())
}

fn cmd_autotune(args: &cli::Args) -> Result<(), String> {
    let dev = device(args)?;
    let (a, _b, _n, _s) = gen_matrix(args)?;
    let gcoo = Gcoo::from_dense(&a, 8);
    let mut tuner = autotune::Autotuner::new(dev);
    let stats = autotune::MatrixStats::measure(&gcoo);
    println!(
        "stats: nnz={} sparsity={:.4} reuse_fraction={:.3} band_skew={:.2}",
        stats.nnz,
        stats.sparsity(),
        stats.reuse_fraction,
        stats.band_skew
    );
    println!("\nanalytic ranking:");
    for c in tuner.rank(&stats).iter().take(6) {
        println!("  p={:<3} b={:<4} predicted={:.0}", c.p, c.b, c.predicted_cost);
    }
    let choice = tuner.tune(&gcoo);
    println!(
        "\nchosen: p={} b={} (simulated {:.4} ms on {})",
        choice.p,
        choice.b,
        choice.measured_s.unwrap_or(0.0) * 1e3,
        dev.name
    );
    Ok(())
}

fn cmd_figures(args: &cli::Args) -> Result<(), String> {
    let fig = args.get_str("fig", "all");
    let full = args.has("full");
    let count = args.get_usize("count", if full { 2694 } else { 200 })?;
    let max_n = args.get_usize("max-n", if full { 4096 } else { 1024 })?;
    let run = |name: &str| -> bool { fig == "all" || fig == name };
    if run("1") {
        figures::fig1_roofline().print();
    }
    if run("table1") {
        figures::table1_memory().print();
    }
    if run("4") {
        figures::fig4_public_hist(count, max_n).print();
    }
    if run("5") {
        figures::fig5_selected(if full { 4096 } else { 1024 }).print();
    }
    if run("6") {
        figures::fig6_random_hist(count, max_n.max(2048)).print();
    }
    if run("7") || run("8") || run("9") {
        figures::fig7_9_time_vs_sparsity().print();
    }
    if run("10") || run("11") || run("12") {
        figures::fig10_12_perf_vs_size().print();
    }
    if run("13") {
        figures::fig13_breakdown().print();
    }
    if run("14") {
        figures::fig14_instructions().print();
    }
    if run("15") {
        figures::fig15_scaling().print();
    }
    println!("CSV series written under results/");
    Ok(())
}
