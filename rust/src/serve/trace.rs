//! Workload traces: synthetic request schedules for open-loop load testing
//! of the serving stack (Poisson arrivals, mixed shapes/sparsities), plus a
//! replayer that measures per-request latency against the schedule.
//!
//! This is the serving-framework side of the evaluation: the paper measures
//! kernels in isolation; a deployable system also needs load behavior under
//! arrival pressure (queueing delay vs service time).

use crate::rng::Rng;

/// Specification of a synthetic workload trace.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub requests: usize,
    /// Mean arrival rate (requests/second) for the Poisson process.
    pub rate_rps: f64,
    /// Candidate matrix sizes (sampled uniformly).
    pub sizes: Vec<usize>,
    /// Candidate sparsities (sampled uniformly).
    pub sparsities: Vec<f64>,
    /// Candidate structural patterns (names from gen::Pattern).
    pub patterns: Vec<String>,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            requests: 64,
            rate_rps: 20.0,
            sizes: vec![128, 256, 512],
            sparsities: vec![0.95, 0.98, 0.99, 0.995],
            patterns: vec!["uniform".into(), "banded".into(), "power_law_rows".into()],
            seed: 0x712ACE,
        }
    }
}

/// One scheduled request.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceItem {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub n: usize,
    pub sparsity: f64,
    pub pattern: String,
    pub seed: u64,
}

/// Generate the schedule: exponential inter-arrivals at `rate_rps`,
/// independent uniform draws for the shape mix. Deterministic per seed.
pub fn generate(spec: &TraceSpec) -> Vec<TraceItem> {
    assert!(spec.rate_rps > 0.0, "rate must be positive");
    assert!(!spec.sizes.is_empty() && !spec.sparsities.is_empty() && !spec.patterns.is_empty());
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0;
    (0..spec.requests)
        .map(|id| {
            // exponential inter-arrival: -ln(U)/λ
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / spec.rate_rps;
            TraceItem {
                id: id as u64,
                arrival_s: t,
                n: spec.sizes[rng.index(spec.sizes.len())],
                sparsity: spec.sparsities[rng.index(spec.sparsities.len())],
                pattern: spec.patterns[rng.index(spec.patterns.len())].clone(),
                seed: rng.next_u64(),
            }
        })
        .collect()
}

/// Replay statistics.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub completed: usize,
    pub failed: usize,
    pub wall_s: f64,
    /// End-to-end latency per request (arrival → completion), seconds.
    pub latency_s: Vec<f64>,
    /// Time each request waited past its scheduled arrival before issue.
    pub lateness_s: Vec<f64>,
}

impl ReplayReport {
    pub fn p(&self, pct: f64) -> f64 {
        if self.latency_s.is_empty() {
            0.0
        } else {
            crate::ndarray::percentile(&self.latency_s, pct)
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }
}

/// Open-loop replay: issue each item at its scheduled arrival (sleeping as
/// needed; if the executor falls behind, lateness accumulates — that *is*
/// the signal), calling `run` synchronously per item from this thread's
/// pacing loop with results collected via worker threads.
pub fn replay<F>(items: &[TraceItem], concurrency: usize, run: F) -> ReplayReport
where
    F: Fn(&TraceItem) -> Result<(), String> + Send + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    let started = Instant::now();
    let failed = AtomicUsize::new(0);
    let latencies = Mutex::new(Vec::with_capacity(items.len()));
    let lateness = Mutex::new(Vec::with_capacity(items.len()));
    let next = AtomicUsize::new(0);

    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::SeqCst);
                if idx >= items.len() {
                    break;
                }
                let item = &items[idx];
                // pace to the schedule
                let target = Duration::from_secs_f64(item.arrival_s);
                let now = started.elapsed();
                if now < target {
                    std::thread::sleep(target - now);
                }
                let late = (started.elapsed().as_secs_f64() - item.arrival_s).max(0.0);
                let issue = Instant::now();
                match run(item) {
                    Ok(()) => {
                        let total = late + issue.elapsed().as_secs_f64();
                        latencies.lock().unwrap().push(total);
                        lateness.lock().unwrap().push(late);
                    }
                    Err(_) => {
                        failed.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }
    });

    let latency_s = latencies.into_inner().unwrap();
    ReplayReport {
        completed: latency_s.len(),
        failed: failed.into_inner(),
        wall_s: started.elapsed().as_secs_f64(),
        latency_s,
        lateness_s: lateness.into_inner().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_monotone() {
        let spec = TraceSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a.len(), spec.requests);
    }

    #[test]
    fn arrival_rate_approximately_honored() {
        let spec = TraceSpec { requests: 2000, rate_rps: 100.0, ..Default::default() };
        let items = generate(&spec);
        let span = items.last().unwrap().arrival_s;
        let measured = items.len() as f64 / span;
        assert!((measured - 100.0).abs() < 15.0, "rate {measured}");
    }

    #[test]
    fn mix_draws_from_spec() {
        let spec = TraceSpec::default();
        for item in generate(&spec) {
            assert!(spec.sizes.contains(&item.n));
            assert!(spec.sparsities.contains(&item.sparsity));
            assert!(spec.patterns.contains(&item.pattern));
        }
    }

    #[test]
    fn replay_runs_everything() {
        let spec = TraceSpec { requests: 20, rate_rps: 2000.0, ..Default::default() };
        let items = generate(&spec);
        let count = std::sync::atomic::AtomicUsize::new(0);
        let report = replay(&items, 4, |_item| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            Ok(())
        });
        assert_eq!(report.completed, 20);
        assert_eq!(report.failed, 0);
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 20);
        assert!(report.p(50.0) >= 0.0);
        assert!(report.throughput_rps() > 0.0);
    }

    #[test]
    fn replay_counts_failures() {
        let spec = TraceSpec { requests: 10, rate_rps: 5000.0, ..Default::default() };
        let items = generate(&spec);
        let report = replay(&items, 2, |item| {
            if item.id % 2 == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
        assert_eq!(report.completed, 5);
        assert_eq!(report.failed, 5);
    }

    #[test]
    fn lateness_accumulates_when_saturated() {
        // 1 worker, instantaneous schedule, slow service ⇒ lateness grows.
        let spec = TraceSpec { requests: 6, rate_rps: 1e6, ..Default::default() };
        let items = generate(&spec);
        let report = replay(&items, 1, |_| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            Ok(())
        });
        let max_late = report.lateness_s.iter().copied().fold(0.0, f64::max);
        assert!(max_late > 0.015, "expected queueing lateness, got {max_late}");
    }
}
