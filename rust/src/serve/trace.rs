//! Workload traces: synthetic request schedules for open-loop load testing
//! of the serving stack (Poisson arrivals, mixed shapes/sparsities, and a
//! shared-A dimension: a zipfian choice over a small pool of registered As
//! so load tests exercise operand-handle reuse under realistic skew), plus
//! a replayer that measures per-request latency against the schedule and
//! reports the operand-store hit rate the driver achieved, plus the
//! per-item resolved algorithm and route-flip schedule (so two same-seed
//! replays through a live coordinator can be compared flip for flip).
//!
//! This is the serving-framework side of the evaluation: the paper measures
//! kernels in isolation; a deployable system also needs load behavior under
//! arrival pressure (queueing delay vs service time) and under operand
//! reuse (conversions amortized across handle traffic).

use crate::rng::Rng;

/// Specification of a synthetic workload trace.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub requests: usize,
    /// Mean arrival rate (requests/second) for the Poisson process.
    pub rate_rps: f64,
    /// Candidate matrix sizes (sampled uniformly).
    pub sizes: Vec<usize>,
    /// Candidate sparsities (sampled uniformly).
    pub sparsities: Vec<f64>,
    /// Candidate structural patterns (names from gen::Pattern).
    pub patterns: Vec<String>,
    pub seed: u64,
    /// Size of the shared-A pool: 0 (default) keeps the v1 behavior where
    /// every request ships its own synthetic A; k > 0 makes every request
    /// draw one of k fixed A operands (each with its own size/sparsity/
    /// pattern/seed, drawn once from the candidate lists), the fraction of
    /// traffic per operand following the zipf skew below — the shape of
    /// real serving traffic, where a few hot models dominate.
    pub shared_a_pool: usize,
    /// Zipf exponent over the pool: weight(slot k) ∝ 1/(k+1)^s. 0.0 is
    /// uniform; ~1.0 is classic web-traffic skew.
    pub shared_a_zipf: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            requests: 64,
            rate_rps: 20.0,
            sizes: vec![128, 256, 512],
            sparsities: vec![0.95, 0.98, 0.99, 0.995],
            patterns: vec!["uniform".into(), "banded".into(), "power_law_rows".into()],
            seed: 0x712ACE,
            shared_a_pool: 0,
            shared_a_zipf: 1.0,
        }
    }
}

/// One A operand of the shared pool: the parameters a driver passes to
/// `put_a` (synthetic payload) for that slot.
#[derive(Clone, Debug, PartialEq)]
pub struct SharedA {
    pub slot: usize,
    pub n: usize,
    pub sparsity: f64,
    pub pattern: String,
    pub seed: u64,
}

/// One scheduled request.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceItem {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub n: usize,
    pub sparsity: f64,
    pub pattern: String,
    /// Per-request seed: the full synthetic workload for one-off items, the
    /// B operand for shared-A items (whose A is fixed by the slot).
    pub seed: u64,
    /// Which shared-A slot this request multiplies against (`None` = the
    /// v1 one-off synthetic request). Shape fields mirror the slot's.
    pub a_slot: Option<usize>,
}

/// The shared-A pool a spec implies: slot parameters are drawn once from
/// the candidate lists, deterministically per spec seed — `generate` uses
/// exactly these, so a driver can `put_a` each slot up front (or lazily on
/// first use) and know the trace items match.
pub fn shared_pool(spec: &TraceSpec) -> Vec<SharedA> {
    let mut rng = Rng::new(spec.seed ^ 0xA_900D_5EED);
    (0..spec.shared_a_pool)
        .map(|slot| SharedA {
            slot,
            n: spec.sizes[rng.index(spec.sizes.len())],
            sparsity: spec.sparsities[rng.index(spec.sparsities.len())],
            pattern: spec.patterns[rng.index(spec.patterns.len())].clone(),
            seed: rng.next_u64(),
        })
        .collect()
}

/// Cumulative zipf weights over `n` slots (weight(k) ∝ 1/(k+1)^s),
/// computed once per schedule so the per-item draw does no allocation.
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut acc = 0.0;
    (0..n)
        .map(|k| {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            acc
        })
        .collect()
}

/// Draw a zipf-distributed index from a precomputed [`zipf_cdf`] table.
fn zipf_index(rng: &mut Rng, cdf: &[f64]) -> usize {
    let total = *cdf.last().expect("non-empty pool");
    let u = rng.next_f64() * total;
    cdf.iter().position(|&c| u <= c).unwrap_or(cdf.len() - 1)
}

/// Generate the schedule: exponential inter-arrivals at `rate_rps`,
/// independent uniform draws for the shape mix (one-off items) or a
/// zipfian slot choice from [`shared_pool`] (shared-A items).
/// Deterministic per seed.
pub fn generate(spec: &TraceSpec) -> Vec<TraceItem> {
    assert!(spec.rate_rps > 0.0, "rate must be positive");
    assert!(!spec.sizes.is_empty() && !spec.sparsities.is_empty() && !spec.patterns.is_empty());
    let pool = shared_pool(spec);
    let cdf = zipf_cdf(pool.len(), spec.shared_a_zipf);
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0;
    (0..spec.requests)
        .map(|id| {
            // exponential inter-arrival: -ln(U)/λ
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / spec.rate_rps;
            if pool.is_empty() {
                TraceItem {
                    id: id as u64,
                    arrival_s: t,
                    n: spec.sizes[rng.index(spec.sizes.len())],
                    sparsity: spec.sparsities[rng.index(spec.sparsities.len())],
                    pattern: spec.patterns[rng.index(spec.patterns.len())].clone(),
                    seed: rng.next_u64(),
                    a_slot: None,
                }
            } else {
                let a = &pool[zipf_index(&mut rng, &cdf)];
                TraceItem {
                    id: id as u64,
                    arrival_s: t,
                    n: a.n,
                    sparsity: a.sparsity,
                    pattern: a.pattern.clone(),
                    seed: rng.next_u64(), // the B seed; A is the slot's
                    a_slot: Some(a.slot),
                }
            }
        })
        .collect()
}

/// How one replayed request reached its operand: a plain
/// (inline/synthetic) request, or a handle request that hit or missed the
/// operand store (miss = the driver had to `put_a` first).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplayKind {
    Plain,
    StoreHit,
    StoreMiss,
}

/// What one replayed request did, as reported by the driver closure: the
/// operand path ([`ReplayKind`]), the algorithm the server resolved for
/// it, and whether it triggered an adaptive route flip — so a replayed
/// trace carries the full routing schedule, and two replays at one seed
/// can be compared flip for flip.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayOutcome {
    pub kind: ReplayKind,
    /// Resolved algorithm name from the server's reply (None when the
    /// driver does not track it).
    pub algo: Option<String>,
    /// Whether this request triggered a route flip (entry republish).
    pub flip: bool,
}

impl ReplayOutcome {
    pub fn plain() -> Self {
        ReplayOutcome { kind: ReplayKind::Plain, algo: None, flip: false }
    }

    pub fn store_hit() -> Self {
        ReplayOutcome { kind: ReplayKind::StoreHit, algo: None, flip: false }
    }

    pub fn store_miss() -> Self {
        ReplayOutcome { kind: ReplayKind::StoreMiss, algo: None, flip: false }
    }

    pub fn with_algo(mut self, algo: impl Into<String>) -> Self {
        self.algo = Some(algo.into());
        self
    }

    pub fn with_flip(mut self, flip: bool) -> Self {
        self.flip = flip;
        self
    }
}

/// Replay statistics.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub completed: usize,
    pub failed: usize,
    pub wall_s: f64,
    /// End-to-end latency per request (arrival → completion), seconds.
    pub latency_s: Vec<f64>,
    /// Time each request waited past its scheduled arrival before issue.
    pub lateness_s: Vec<f64>,
    /// Handle requests served from an already-registered operand.
    pub store_hits: usize,
    /// Handle requests that had to register their operand first.
    pub store_misses: usize,
    /// Per-item outcomes (item id, what the driver reported), ordered by
    /// item id — the replayed routing schedule.
    pub outcomes: Vec<(u64, ReplayOutcome)>,
}

impl ReplayReport {
    pub fn p(&self, pct: f64) -> f64 {
        if self.latency_s.is_empty() {
            0.0
        } else {
            crate::ndarray::percentile(&self.latency_s, pct)
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }

    /// Item ids that triggered a route flip, in schedule order — the
    /// flip schedule two same-seed replays must agree on exactly.
    pub fn flip_schedule(&self) -> Vec<u64> {
        self.outcomes.iter().filter(|(_, o)| o.flip).map(|(id, _)| *id).collect()
    }

    /// Fraction of handle traffic that reused an already-registered
    /// operand (0.0 when the trace had no handle traffic).
    pub fn store_hit_rate(&self) -> f64 {
        let handle = self.store_hits + self.store_misses;
        if handle == 0 {
            0.0
        } else {
            self.store_hits as f64 / handle as f64
        }
    }
}

/// Open-loop replay: issue each item at its scheduled arrival (sleeping as
/// needed; if the executor falls behind, lateness accumulates — that *is*
/// the signal), calling `run` synchronously per item from this thread's
/// pacing loop with results collected via worker threads. The closure
/// reports each request's [`ReplayOutcome`] so shared-A traces surface
/// their operand-store hit rate in the report.
pub fn replay<F>(items: &[TraceItem], concurrency: usize, run: F) -> ReplayReport
where
    F: Fn(&TraceItem) -> Result<ReplayOutcome, String> + Send + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    let started = Instant::now();
    let failed = AtomicUsize::new(0);
    let hits = AtomicUsize::new(0);
    let misses = AtomicUsize::new(0);
    let latencies = Mutex::new(Vec::with_capacity(items.len()));
    let lateness = Mutex::new(Vec::with_capacity(items.len()));
    let outcomes = Mutex::new(Vec::with_capacity(items.len()));
    let next = AtomicUsize::new(0);

    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::SeqCst);
                if idx >= items.len() {
                    break;
                }
                let item = &items[idx];
                // pace to the schedule
                let target = Duration::from_secs_f64(item.arrival_s);
                let now = started.elapsed();
                if now < target {
                    std::thread::sleep(target - now);
                }
                let late = (started.elapsed().as_secs_f64() - item.arrival_s).max(0.0);
                let issue = Instant::now();
                match run(item) {
                    Ok(outcome) => {
                        let total = late + issue.elapsed().as_secs_f64();
                        latencies.lock().unwrap().push(total);
                        lateness.lock().unwrap().push(late);
                        match outcome.kind {
                            ReplayKind::Plain => {}
                            ReplayKind::StoreHit => {
                                hits.fetch_add(1, Ordering::SeqCst);
                            }
                            ReplayKind::StoreMiss => {
                                misses.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        outcomes.lock().unwrap().push((item.id, outcome));
                    }
                    Err(_) => {
                        failed.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }
    });

    let latency_s = latencies.into_inner().unwrap();
    let mut outcomes = outcomes.into_inner().unwrap();
    outcomes.sort_by_key(|(id, _)| *id);
    ReplayReport {
        completed: latency_s.len(),
        failed: failed.into_inner(),
        wall_s: started.elapsed().as_secs_f64(),
        latency_s,
        lateness_s: lateness.into_inner().unwrap(),
        store_hits: hits.into_inner(),
        store_misses: misses.into_inner(),
        outcomes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_monotone() {
        let spec = TraceSpec::default();
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a.len(), spec.requests);
    }

    #[test]
    fn arrival_rate_approximately_honored() {
        let spec = TraceSpec { requests: 2000, rate_rps: 100.0, ..Default::default() };
        let items = generate(&spec);
        let span = items.last().unwrap().arrival_s;
        let measured = items.len() as f64 / span;
        assert!((measured - 100.0).abs() < 15.0, "rate {measured}");
    }

    #[test]
    fn mix_draws_from_spec() {
        let spec = TraceSpec::default();
        for item in generate(&spec) {
            assert!(spec.sizes.contains(&item.n));
            assert!(spec.sparsities.contains(&item.sparsity));
            assert!(spec.patterns.contains(&item.pattern));
            assert_eq!(item.a_slot, None, "pool 0 keeps the v1 one-off behavior");
        }
    }

    #[test]
    fn shared_pool_items_match_their_slots() {
        let spec = TraceSpec {
            requests: 200,
            shared_a_pool: 4,
            shared_a_zipf: 1.0,
            ..Default::default()
        };
        let pool = shared_pool(&spec);
        assert_eq!(pool.len(), 4);
        assert_eq!(pool, shared_pool(&spec), "pool is deterministic per seed");
        let items = generate(&spec);
        assert_eq!(items, generate(&spec), "schedule is deterministic per seed");
        let mut counts = vec![0usize; 4];
        for item in &items {
            let slot = item.a_slot.expect("every pooled item carries a slot");
            counts[slot] += 1;
            // Shape fields mirror the slot's, so a driver that `put_a`s the
            // slot's parameters serves exactly this item's A.
            let a = &pool[slot];
            assert_eq!((item.n, item.sparsity, &item.pattern), (a.n, a.sparsity, &a.pattern));
            assert_ne!(item.seed, a.seed, "per-request B seed differs from the slot's A seed");
        }
        // Zipf skew at s=1: slot 0 must dominate the tail slot.
        assert!(
            counts[0] > counts[3],
            "zipf(1.0) should skew toward slot 0: {counts:?}"
        );
        assert!(counts.iter().all(|&c| c > 0), "200 draws should touch all 4 slots: {counts:?}");
    }

    #[test]
    fn replay_runs_everything() {
        let spec = TraceSpec { requests: 20, rate_rps: 2000.0, ..Default::default() };
        let items = generate(&spec);
        let count = std::sync::atomic::AtomicUsize::new(0);
        let report = replay(&items, 4, |item| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            Ok(ReplayOutcome::plain().with_algo(if item.id % 2 == 0 { "gcoo" } else { "dense_xla" }))
        });
        assert_eq!(report.completed, 20);
        assert_eq!(report.failed, 0);
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 20);
        assert!(report.p(50.0) >= 0.0);
        assert!(report.throughput_rps() > 0.0);
        assert_eq!((report.store_hits, report.store_misses), (0, 0));
        assert_eq!(report.store_hit_rate(), 0.0, "no handle traffic → rate 0");
        // Per-item outcomes come back ordered by id with the resolved algo.
        assert_eq!(report.outcomes.len(), 20);
        assert!(report.outcomes.windows(2).all(|w| w[0].0 < w[1].0));
        assert_eq!(report.outcomes[0].1.algo.as_deref(), Some("gcoo"));
        assert_eq!(report.outcomes[1].1.algo.as_deref(), Some("dense_xla"));
        assert!(report.flip_schedule().is_empty(), "no flips reported → empty schedule");
    }

    #[test]
    fn replay_reports_store_hit_rate() {
        // Emulate a handle-reusing driver: first use of each slot is a
        // miss (put_a + spdm), later uses are hits.
        let spec = TraceSpec {
            requests: 64,
            rate_rps: 1e6,
            shared_a_pool: 3,
            shared_a_zipf: 1.0,
            ..Default::default()
        };
        let items = generate(&spec);
        let seen = std::sync::Mutex::new(std::collections::HashSet::new());
        let report = replay(&items, 2, |item| {
            let slot = item.a_slot.expect("pooled trace");
            if seen.lock().unwrap().insert(slot) {
                Ok(ReplayOutcome::store_miss())
            } else {
                Ok(ReplayOutcome::store_hit())
            }
        });
        assert_eq!(report.completed, 64);
        assert_eq!(report.store_misses, 3, "one registration per pool slot");
        assert_eq!(report.store_hits, 61);
        let rate = report.store_hit_rate();
        assert!((rate - 61.0 / 64.0).abs() < 1e-12, "{rate}");
    }

    #[test]
    fn flip_schedule_orders_flips_by_item_id() {
        let spec = TraceSpec { requests: 12, rate_rps: 1e6, ..Default::default() };
        let items = generate(&spec);
        let report = replay(&items, 3, |item| {
            Ok(ReplayOutcome::store_hit().with_algo("gcoo").with_flip(item.id == 7 || item.id == 3))
        });
        assert_eq!(report.flip_schedule(), vec![3, 7], "schedule is id-ordered");
        assert_eq!(report.store_hits, 12);
    }

    #[test]
    fn replay_counts_failures() {
        let spec = TraceSpec { requests: 10, rate_rps: 5000.0, ..Default::default() };
        let items = generate(&spec);
        let report = replay(&items, 2, |item| {
            if item.id % 2 == 0 {
                Err("boom".into())
            } else {
                Ok(ReplayOutcome::plain())
            }
        });
        assert_eq!(report.completed, 5);
        assert_eq!(report.failed, 5);
    }

    #[test]
    fn lateness_accumulates_when_saturated() {
        // 1 worker, instantaneous schedule, slow service ⇒ lateness grows.
        let spec = TraceSpec { requests: 6, rate_rps: 1e6, ..Default::default() };
        let items = generate(&spec);
        let report = replay(&items, 1, |_| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            Ok(ReplayOutcome::plain())
        });
        let max_late = report.lateness_s.iter().copied().fold(0.0, f64::max);
        assert!(max_late > 0.015, "expected queueing lateness, got {max_late}");
    }
}
