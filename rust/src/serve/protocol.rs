//! Wire protocol. Two planes share one listener, told apart by the first
//! byte of each message (`server.rs` sniffs without consuming):
//!
//! * **JSON v1/v2** — one JSON object per line, first byte `{`. v2 is
//!   additive over v1: every v1 line parses and behaves unchanged; v2 adds
//!   the operand-handle lifecycle (`put_a` / `drop_a` / `list_a`) and
//!   `spdm` by `a_handle`. This is the debug/compat plane: every v1/v2
//!   line is byte-for-byte unchanged under v3.
//! * **Binary v3** — length-prefixed frames ([`frame`]), first byte the
//!   magic `0xB3`. Operands travel as raw little-endian f32 payloads that
//!   decode in one pass into the pipeline's buffers: no per-float text
//!   parse, no intermediate `Vec<Value>`, no utf-8 validation on operand
//!   bytes. Both planes decode into the *same* [`Request`] type and flow
//!   through the same dispatch, so encoding can never change results —
//!   the cross-protocol differential (`tests/wire_differential.rs`) pins
//!   bitwise-identical C. See DESIGN.md §Wire for the byte-level grammar.
//!
//! v1 requests:
//!   {"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,
//!    "pattern":"uniform","seed":42,"algo":"auto","verify":false}
//!   {"id":2,"type":"spdm","n":4,"payload":"inline","a":[...16 floats],
//!    "b":[...16 floats]}
//!   {"id":3,"type":"metrics"}    {"id":4,"type":"ping"}
//!   {"id":5,"type":"stats"}   — structured metrics: the reply's `metrics`
//!   field carries the JSON-encoded snapshot (counters, latency, the
//!   batch-width histogram, `conversions_total`, the store gauges, and
//!   the adaptive-routing `route_flips`/`explorations` counters)
//!   {"id":12,"type":"explain"} — the adaptive routing table: the reply's
//!   `routing` field carries JSON with the policy in force and, per
//!   registered operand, the published version, incumbent routing, ranked
//!   candidate plans, and the tuner's per-algo latency estimates
//!
//! v2 requests (operand handles — register A once, multiply by reference):
//!   {"id":6,"type":"put_a","n":256,"payload":"synthetic","sparsity":0.99,
//!    "pattern":"uniform","seed":42,"algo":"auto"}
//!   {"id":7,"type":"put_a","n":4,"payload":"inline","a":[...16 floats]}
//!     → {"id":7,"ok":true,"a_handle":3,"algo":"gcoo","artifact":"…",
//!        "n_exec":256,"convert_ms":0.8,"reason":"sparse-crossover"}
//!       (the resolved routing, so clients can introspect the plan)
//!   {"id":8,"type":"spdm","a_handle":3,"b":[...floats],"verify":true}
//!   {"id":9,"type":"spdm","a_handle":3,"seed":7}   — synthetic B; `n` is
//!     optional on handle requests (the registered operand fixes it)
//!   {"id":10,"type":"drop_a","a_handle":3}
//!   {"id":11,"type":"list_a"}
//!     → {"id":11,"ok":true,"handles":[{"a_handle":3,"n":256,"nnz":655,
//!        "algo":"gcoo","artifact":"…","bytes":270336},…]}
//!
//! Responses (v1 shape, plus `a_handle`/`reason`/`handles` where relevant):
//!   {"id":1,"ok":true,"algo":"gcoo","artifact":"gcoo_n256_…","n_exec":256,
//!    "convert_ms":0.8,"kernel_ms":3.1,"total_ms":4.2,"verified":null,
//!    "checksum":123.5}
//!   {"id":3,"ok":true,"metrics":"…"}    {"id":1,"ok":false,"error":"…"}
//!
//! Validation happens at this boundary: non-finite floats in inline
//! payloads are rejected (a NaN would make `ASig` bit-pattern equality
//! disagree with the element-equality re-screen, silently demoting fusable
//! batches), and synthetic parameters (`sparsity` ∈ [0, 1), known
//! `pattern`) fail the request here instead of leaking into generation.

use crate::coordinator::{Algo, DEFAULT_TENANT, MAX_TENANT_LEN};
use crate::gen::Pattern;
use crate::json::{self, Value};

/// How the A/B operands arrive.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Synthetic { sparsity: f64, pattern: String, seed: u64 },
    Inline { a: Vec<f32>, b: Vec<f32> },
    /// v2: A by reference to a registered operand; only B travels.
    Handle { a_handle: u64, b: BPayload },
}

/// How a handle request supplies its B operand.
#[derive(Clone, Debug, PartialEq)]
pub enum BPayload {
    Inline(Vec<f32>),
    /// Server-side `randn` B from this seed (benchmarks and load tests:
    /// handle reuse without shipping n² floats per request).
    Synthetic { seed: u64 },
}

/// How `put_a` supplies the operand to register.
#[derive(Clone, Debug, PartialEq)]
pub enum APayload {
    Synthetic { sparsity: f64, pattern: String, seed: u64 },
    Inline { a: Vec<f32> },
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Spdm {
        id: u64,
        /// 0 on handle requests without an explicit `n` (the registered
        /// operand fixes the size); positive and validated otherwise.
        n: usize,
        payload: Payload,
        algo: Option<Algo>,
        verify: bool,
        /// Owning tenant (ISSUE 9): optional `tenant` field in JSON, a
        /// flagged slot in v3 frames; absent ⇒ [`DEFAULT_TENANT`], keeping
        /// every existing client line/frame byte-compatible.
        tenant: String,
    },
    /// v2: register an A operand (plan + convert once, reply with the
    /// handle and the resolved routing).
    PutA { id: u64, n: usize, payload: APayload, algo: Option<Algo>, tenant: String },
    /// v2: drop a registered operand.
    DropA { id: u64, a_handle: u64 },
    /// v2: list registered operands with their routing/cost summaries.
    ListA { id: u64 },
    Metrics { id: u64 },
    /// Structured (JSON) metrics snapshot — the machine-readable sibling of
    /// the human-oriented `Metrics` text render.
    Stats { id: u64 },
    /// Adaptive routing table + per-entry measured estimates (the reply's
    /// `routing` field carries the JSON document).
    Explain { id: u64 },
    Ping { id: u64 },
    Shutdown { id: u64 },
}

/// One row of a `list_a` reply.
#[derive(Clone, Debug, PartialEq)]
pub struct HandleInfo {
    pub a_handle: u64,
    pub n: usize,
    pub nnz: usize,
    pub algo: String,
    pub artifact: String,
    pub bytes: u64,
    /// Residency tier (ISSUE 9): `"ram"` (converted slabs resident) or
    /// `"spilled"` (demoted to the disk tier, promoted on next use).
    /// Parsed with a `"ram"` default so pre-tenancy replies still decode.
    pub tier: String,
    /// The store's LRU sequence at last use (0 = unknown / pre-tenancy).
    pub last_used_seq: u64,
}

/// A server response (subset of fields depending on request type).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Response {
    pub id: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub algo: Option<String>,
    pub artifact: Option<String>,
    pub n_exec: Option<usize>,
    pub convert_ms: Option<f64>,
    pub kernel_ms: Option<f64>,
    pub total_ms: Option<f64>,
    pub verified: Option<bool>,
    pub checksum: Option<f64>,
    pub metrics: Option<String>,
    /// v2: the operand handle (`put_a` replies; echoed on handle `spdm`).
    pub a_handle: Option<u64>,
    /// v2: why the plan chose its algorithm (`put_a` replies).
    pub reason: Option<String>,
    /// v2: `list_a` rows.
    pub handles: Option<Vec<HandleInfo>>,
    /// The `explain` reply's JSON routing table.
    pub routing: Option<String>,
}

/// Pull a float array field, rejecting non-finite entries: a NaN in A
/// would break `ASig` bit-pattern equality vs the element-equality
/// re-screen (NaN != NaN), silently demoting fusable batches; Inf
/// propagates garbage through every kernel. Reject both at the boundary.
fn finite_floats(v: &Value, k: &str) -> Result<Vec<f32>, String> {
    v.get(k)
        .and_then(Value::as_arr)
        .ok_or(format!("missing {k}"))?
        .iter()
        .map(|x| match x.as_f64() {
            // Finiteness is checked on the f32 the pipeline actually
            // stores: a finite f64 above f32::MAX (e.g. 1e39) saturates to
            // Inf in the cast and must be rejected just like a wire-level
            // Inf or NaN.
            Some(f) if (f as f32).is_finite() => Ok(f as f32),
            Some(f) => Err(format!("non-finite value {f} in {k}")),
            None => Err(format!("bad {k}")),
        })
        .collect()
}

/// Validate synthetic-payload parameters at the protocol boundary: a
/// sparsity outside [0, 1) (NaN included) or an unknown pattern name is a
/// malformed request, not a generation-time surprise.
fn synthetic_params(v: &Value) -> Result<(f64, String, u64), String> {
    let sparsity = v.get("sparsity").and_then(Value::as_f64).unwrap_or(0.99);
    if !(0.0..1.0).contains(&sparsity) {
        return Err(format!("sparsity {sparsity} outside [0, 1)"));
    }
    let pattern = v
        .get("pattern")
        .and_then(Value::as_str)
        .unwrap_or("uniform")
        .to_string();
    if Pattern::from_name(&pattern).is_none() {
        return Err(format!("unknown pattern {pattern}"));
    }
    Ok((sparsity, pattern, v.get("seed").and_then(Value::as_u64).unwrap_or(0)))
}

fn parse_algo(v: &Value) -> Result<Option<Algo>, String> {
    match v.get("algo").and_then(Value::as_str) {
        None | Some("auto") => Ok(None),
        Some(s) => Algo::from_str(s).map(Some).ok_or(format!("unknown algo {s}")),
    }
}

/// Optional `tenant` field (ISSUE 9): absent ⇒ the default tenant (every
/// pre-tenancy line parses unchanged); present, it must be a non-empty
/// string of at most [`MAX_TENANT_LEN`] bytes (the v3 frame slot is a
/// u8-length-prefixed string, so the JSON plane enforces the same bound).
fn parse_tenant(v: &Value) -> Result<String, String> {
    match v.get("tenant") {
        None => Ok(DEFAULT_TENANT.to_string()),
        Some(t) => {
            let s = t.as_str().ok_or("invalid tenant: must be a string")?;
            if s.is_empty() {
                return Err("invalid tenant: must be non-empty".into());
            }
            if s.len() > MAX_TENANT_LEN {
                return Err(format!(
                    "invalid tenant: {} bytes exceeds the {MAX_TENANT_LEN}-byte cap",
                    s.len()
                ));
            }
            Ok(s.to_string())
        }
    }
}

/// Satellite (ISSUE 9): the JSON plane enforces the binary plane's
/// 256 MiB operand ceiling on inline payloads. The declared `n` is
/// client-controlled, so the size is computed in checked u64 math exactly
/// like [`frame`]'s pre-allocation screen — a huge inline request gets a
/// typed error and the connection survives, it does not balloon the
/// server's operand buffers.
fn check_inline_cap(n: usize, operands: usize, what: &str) -> Result<(), String> {
    let ok = (n as u64)
        .checked_mul(n as u64)
        .and_then(|e| e.checked_mul(4))
        .and_then(|b| b.checked_mul(operands as u64))
        .is_some_and(|b| b <= frame::MAX_PAYLOAD as u64);
    if !ok {
        return Err(format!(
            "{what} declares dims {n}x{n}: {operands}·n²·4 inline operand bytes exceed the \
             {}-byte cap",
            frame::MAX_PAYLOAD
        ));
    }
    Ok(())
}

pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    let id = v.get("id").and_then(Value::as_u64).ok_or("missing id")?;
    match v.get("type").and_then(Value::as_str).ok_or("missing type")? {
        "ping" => Ok(Request::Ping { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "stats" => Ok(Request::Stats { id }),
        "explain" => Ok(Request::Explain { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "spdm" => {
            // v2: an `a_handle` field selects multiply-by-reference; `n`
            // becomes optional (the registered operand fixes it) and only
            // B travels — inline, or synthetic from `seed`. The key's mere
            // presence commits to the handle path: a malformed value
            // (string, negative, fractional) is an error, never a silent
            // fall-through to a v1 synthetic multiply against the wrong A.
            if let Some(ah) = v.get("a_handle") {
                let a_handle = ah.as_u64().ok_or("invalid a_handle")?;
                let n = v.get("n").and_then(Value::as_usize).unwrap_or(0);
                let b = if v.get("b").is_some() {
                    if n > 0 {
                        check_inline_cap(n, 1, "spdm")?;
                    }
                    let b = finite_floats(&v, "b")?;
                    if n > 0 && b.len() != n * n {
                        return Err(format!("inline b size {} != n²={}", b.len(), n * n));
                    }
                    // No declared n: cap the actual array (the operand
                    // still must fit the frame ceiling).
                    if b.len() as u64 * 4 > frame::MAX_PAYLOAD as u64 {
                        return Err(format!(
                            "inline b carries {} floats, exceeding the {}-byte cap",
                            b.len(),
                            frame::MAX_PAYLOAD
                        ));
                    }
                    BPayload::Inline(b)
                } else {
                    BPayload::Synthetic {
                        seed: v.get("seed").and_then(Value::as_u64).unwrap_or(0),
                    }
                };
                return Ok(Request::Spdm {
                    id,
                    n,
                    payload: Payload::Handle { a_handle, b },
                    algo: parse_algo(&v)?,
                    verify: v.get("verify").and_then(Value::as_bool).unwrap_or(false),
                    tenant: parse_tenant(&v)?,
                });
            }
            let n = v.get("n").and_then(Value::as_usize).ok_or("missing n")?;
            if n == 0 {
                return Err("n must be positive".into());
            }
            let payload = match v.get("payload").and_then(Value::as_str).unwrap_or("synthetic") {
                "synthetic" => {
                    let (sparsity, pattern, seed) = synthetic_params(&v)?;
                    Payload::Synthetic { sparsity, pattern, seed }
                }
                "inline" => {
                    check_inline_cap(n, 2, "spdm")?;
                    let a = finite_floats(&v, "a")?;
                    let b = finite_floats(&v, "b")?;
                    if a.len() != n * n || b.len() != n * n {
                        return Err(format!("inline payload sizes {} / {} != n²={}", a.len(), b.len(), n * n));
                    }
                    Payload::Inline { a, b }
                }
                other => return Err(format!("unknown payload kind {other}")),
            };
            Ok(Request::Spdm {
                id,
                n,
                payload,
                algo: parse_algo(&v)?,
                verify: v.get("verify").and_then(Value::as_bool).unwrap_or(false),
                tenant: parse_tenant(&v)?,
            })
        }
        "put_a" => {
            let n = v.get("n").and_then(Value::as_usize).ok_or("missing n")?;
            if n == 0 {
                return Err("n must be positive".into());
            }
            let payload = match v.get("payload").and_then(Value::as_str).unwrap_or("synthetic") {
                "synthetic" => {
                    let (sparsity, pattern, seed) = synthetic_params(&v)?;
                    APayload::Synthetic { sparsity, pattern, seed }
                }
                "inline" => {
                    check_inline_cap(n, 1, "put_a")?;
                    let a = finite_floats(&v, "a")?;
                    if a.len() != n * n {
                        return Err(format!("inline a size {} != n²={}", a.len(), n * n));
                    }
                    APayload::Inline { a }
                }
                other => return Err(format!("unknown payload kind {other}")),
            };
            Ok(Request::PutA { id, n, payload, algo: parse_algo(&v)?, tenant: parse_tenant(&v)? })
        }
        "drop_a" => {
            let a_handle = v.get("a_handle").and_then(Value::as_u64).ok_or("missing a_handle")?;
            Ok(Request::DropA { id, a_handle })
        }
        "list_a" => Ok(Request::ListA { id }),
        other => Err(format!("unknown request type {other}")),
    }
}

pub fn render_response(r: &Response) -> String {
    let mut b = Value::obj().field("id", r.id).field("ok", r.ok);
    if let Some(e) = &r.error {
        b = b.field("error", e.as_str());
    }
    if let Some(a) = &r.algo {
        b = b.field("algo", a.as_str());
    }
    if let Some(a) = &r.artifact {
        b = b.field("artifact", a.as_str());
    }
    if let Some(x) = r.n_exec {
        b = b.field("n_exec", x);
    }
    if let Some(x) = r.convert_ms {
        b = b.field("convert_ms", x);
    }
    if let Some(x) = r.kernel_ms {
        b = b.field("kernel_ms", x);
    }
    if let Some(x) = r.total_ms {
        b = b.field("total_ms", x);
    }
    if let Some(x) = r.verified {
        b = b.field("verified", x);
    }
    if let Some(x) = r.checksum {
        b = b.field("checksum", x);
    }
    if let Some(m) = &r.metrics {
        b = b.field("metrics", m.as_str());
    }
    if let Some(h) = r.a_handle {
        b = b.field("a_handle", h);
    }
    if let Some(reason) = &r.reason {
        b = b.field("reason", reason.as_str());
    }
    if let Some(routing) = &r.routing {
        b = b.field("routing", routing.as_str());
    }
    if let Some(hs) = &r.handles {
        let rows = Value::Arr(
            hs.iter()
                .map(|h| {
                    Value::obj()
                        .field("a_handle", h.a_handle)
                        .field("n", h.n)
                        .field("nnz", h.nnz)
                        .field("algo", h.algo.as_str())
                        .field("artifact", h.artifact.as_str())
                        .field("bytes", h.bytes)
                        .field("tier", h.tier.as_str())
                        .field("last_used_seq", h.last_used_seq)
                        .build()
                })
                .collect(),
        );
        b = b.field("handles", rows);
    }
    json::write(&b.build())
}

pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    Ok(Response {
        id: v.get("id").and_then(Value::as_u64).ok_or("missing id")?,
        ok: v.get("ok").and_then(Value::as_bool).ok_or("missing ok")?,
        error: v.get("error").and_then(Value::as_str).map(str::to_string),
        algo: v.get("algo").and_then(Value::as_str).map(str::to_string),
        artifact: v.get("artifact").and_then(Value::as_str).map(str::to_string),
        n_exec: v.get("n_exec").and_then(Value::as_usize),
        convert_ms: v.get("convert_ms").and_then(Value::as_f64),
        kernel_ms: v.get("kernel_ms").and_then(Value::as_f64),
        total_ms: v.get("total_ms").and_then(Value::as_f64),
        verified: v.get("verified").and_then(Value::as_bool),
        checksum: v.get("checksum").and_then(Value::as_f64),
        metrics: v.get("metrics").and_then(Value::as_str).map(str::to_string),
        a_handle: v.get("a_handle").and_then(Value::as_u64),
        reason: v.get("reason").and_then(Value::as_str).map(str::to_string),
        routing: v.get("routing").and_then(Value::as_str).map(str::to_string),
        handles: v.get("handles").and_then(Value::as_arr).map(|xs| {
            xs.iter()
                .filter_map(|x| {
                    Some(HandleInfo {
                        a_handle: x.get("a_handle")?.as_u64()?,
                        n: x.get("n")?.as_usize()?,
                        nnz: x.get("nnz")?.as_usize()?,
                        algo: x.get("algo")?.as_str()?.to_string(),
                        artifact: x.get("artifact")?.as_str()?.to_string(),
                        bytes: x.get("bytes")?.as_u64()?,
                        // Pre-tenancy peers omit the tier columns; default
                        // to resident so old replies keep parsing.
                        tier: x
                            .get("tier")
                            .and_then(Value::as_str)
                            .unwrap_or("ram")
                            .to_string(),
                        last_used_seq: x.get("last_used_seq").and_then(Value::as_u64).unwrap_or(0),
                    })
                })
                .collect()
        }),
    })
}

/// Wire protocol **v3**: length-prefixed binary frames. One frame =
/// 7-byte header + payload:
///
/// ```text
/// magic 0xB3 (1) | version 0x03 (1) | frame type (1) | payload len u32 LE (4)
/// ```
///
/// Operand elements travel as raw little-endian f32 bytes and decode in a
/// single pass — each float is finiteness-screened as it is read (the same
/// reject-NaN/Inf contract the JSON boundary enforces; a raw payload could
/// otherwise smuggle a NaN that splits `ASig` bit-equality from the
/// element re-screen). Any decode failure comes back as a typed error
/// frame ([`frame::FT_RESP_ERR`]) carrying the request id when the payload
/// prefix still yields one. Control-plane requests (metrics/stats/explain/
/// list/drop/shutdown) intentionally stay JSON-only: the binary plane
/// carries exactly the operand hot path. See DESIGN.md §Wire.
pub mod frame {
    use super::{Algo, BPayload, Payload, Request, Response, DEFAULT_TENANT};
    use crate::ndarray::Mat;

    /// First byte of every v3 frame. Deliberately distinct from `{`
    /// (0x7B), whitespace, and ASCII so the first-byte sniff is exact.
    pub const MAGIC: u8 = 0xB3;
    pub const VERSION: u8 = 0x03;
    /// Header: magic, version, frame type, payload length (u32 LE).
    pub const HEADER_LEN: usize = 7;
    /// Payload-size ceiling (256 MiB ≈ a 4096² inline A+B pair with
    /// headroom). An oversize length is rejected before any allocation —
    /// a garbled length must not OOM the server.
    pub const MAX_PAYLOAD: usize = 256 << 20;

    // Request frame types.
    pub const FT_SPDM_INLINE: u8 = 0x01;
    pub const FT_SPDM_HANDLE_B: u8 = 0x02;
    pub const FT_SPDM_HANDLE_SEED: u8 = 0x03;
    pub const FT_PUT_A: u8 = 0x04;
    pub const FT_PING: u8 = 0x05;
    /// Tenant-tagged `put_a` (ISSUE 9). [`FT_PUT_A`] has no flags byte, so
    /// the tenant slot needs its own frame type; untenanted clients keep
    /// emitting byte-identical [`FT_PUT_A`] frames.
    pub const FT_PUT_A_T: u8 = 0x06;
    // Response frame types.
    pub const FT_RESP_SPDM: u8 = 0x81;
    pub const FT_RESP_ERR: u8 = 0x82;
    pub const FT_RESP_PUT_A: u8 = 0x83;
    pub const FT_RESP_PONG: u8 = 0x84;

    // Request flag bits.
    const FLAG_VERIFY: u8 = 1 << 0;
    /// Ask for the full result matrix C in the reply frame (raw LE f32).
    /// JSON replies only carry the checksum; the binary plane can afford
    /// to return C because it is a memcpy, not an n² text render.
    const FLAG_WANT_C: u8 = 1 << 1;
    /// The frame carries a tenant slot (`tlen u8 | tenant utf8`) between
    /// the fixed fields and the operand bytes (ISSUE 9). Unset ⇒ the
    /// default tenant and a byte-identical pre-tenancy frame.
    const FLAG_TENANT: u8 = 1 << 2;

    /// Parsed frame header.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Header {
        pub ftype: u8,
        pub len: usize,
    }

    /// Validate a 7-byte header. Garbage magic, a foreign version, and an
    /// oversize length are all errors — the stream cannot be resynced
    /// after a bad header, so the connection handler closes on `Err`.
    pub fn parse_header(h: &[u8; HEADER_LEN]) -> Result<Header, String> {
        if h[0] != MAGIC {
            return Err(format!("bad frame magic 0x{:02x}", h[0]));
        }
        if h[1] != VERSION {
            return Err(format!("unsupported frame version 0x{:02x}", h[1]));
        }
        let len = u32::from_le_bytes([h[3], h[4], h[5], h[6]]) as usize;
        if len > MAX_PAYLOAD {
            return Err(format!("frame payload length {len} exceeds {MAX_PAYLOAD}"));
        }
        Ok(Header { ftype: h[2], len })
    }

    /// Best-effort request-id recovery from a payload whose full decode
    /// failed: every request frame leads with the id, so ≥ 8 bytes still
    /// correlate the error frame to the client's request (else id 0) —
    /// the binary twin of the JSON dispatcher's id recovery.
    pub fn request_id_hint(payload: &[u8]) -> u64 {
        if payload.len() >= 8 {
            u64::from_le_bytes(payload[..8].try_into().unwrap())
        } else {
            0
        }
    }

    fn algo_to_byte(algo: Option<Algo>) -> u8 {
        match algo {
            None => 0,
            Some(Algo::Gcoo) => 1,
            Some(Algo::GcooNoreuse) => 2,
            Some(Algo::Csr) => 3,
            Some(Algo::DenseXla) => 4,
            Some(Algo::DensePallas) => 5,
            Some(Algo::Cmrs) => 6,
            Some(Algo::RowSplit) => 7,
        }
    }

    fn algo_from_byte(b: u8) -> Result<Option<Algo>, String> {
        match b {
            0 => Ok(None),
            1 => Ok(Some(Algo::Gcoo)),
            2 => Ok(Some(Algo::GcooNoreuse)),
            3 => Ok(Some(Algo::Csr)),
            4 => Ok(Some(Algo::DenseXla)),
            5 => Ok(Some(Algo::DensePallas)),
            6 => Ok(Some(Algo::Cmrs)),
            7 => Ok(Some(Algo::RowSplit)),
            other => Err(format!("unknown algo byte 0x{other:02x}")),
        }
    }

    /// Bounds-checked payload cursor. Every read that would run past the
    /// end is a "truncated frame payload" error, never a panic — the
    /// truncation property tests drive arbitrary prefixes through here.
    struct Cur<'a> {
        b: &'a [u8],
        off: usize,
    }

    impl<'a> Cur<'a> {
        fn new(b: &'a [u8]) -> Self {
            Cur { b, off: 0 }
        }

        fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
            if self.off + n > self.b.len() {
                return Err(format!(
                    "truncated frame payload: need {} bytes at offset {}, have {}",
                    n,
                    self.off,
                    self.b.len()
                ));
            }
            let s = &self.b[self.off..self.off + n];
            self.off += n;
            Ok(s)
        }

        fn u8(&mut self) -> Result<u8, String> {
            Ok(self.take(1)?[0])
        }

        fn u16(&mut self) -> Result<u16, String> {
            Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
        }

        fn u32(&mut self) -> Result<u32, String> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        fn u64(&mut self) -> Result<u64, String> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        fn f64(&mut self) -> Result<f64, String> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        fn remaining(&self) -> usize {
            self.b.len() - self.off
        }

        /// Decode `count` raw LE f32s, screening each for finiteness as it
        /// is read — the v3 twin of the JSON boundary's `finite_floats`.
        fn f32s(&mut self, count: usize, k: &str) -> Result<Vec<f32>, String> {
            let bytes = self.take(count * 4)?;
            let mut out = Vec::with_capacity(count);
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                let f = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                if !f.is_finite() {
                    return Err(format!("non-finite value {f} at index {i} in {k}"));
                }
                out.push(f);
            }
            Ok(out)
        }

        /// Exact-consumption check: trailing garbage is a malformed frame.
        fn done(&self, what: &str) -> Result<(), String> {
            if self.remaining() != 0 {
                return Err(format!(
                    "{} trailing bytes after {what} frame payload",
                    self.remaining()
                ));
            }
            Ok(())
        }
    }

    /// Frame under construction: header written first, payload appended,
    /// length patched at the end — one contiguous buffer, one socket write.
    struct Builder {
        out: Vec<u8>,
    }

    impl Builder {
        fn new(ftype: u8, payload_hint: usize) -> Self {
            let mut out = Vec::with_capacity(HEADER_LEN + payload_hint);
            out.extend_from_slice(&[MAGIC, VERSION, ftype, 0, 0, 0, 0]);
            Builder { out }
        }

        fn u8(&mut self, x: u8) {
            self.out.push(x);
        }

        fn u16(&mut self, x: u16) {
            self.out.extend_from_slice(&x.to_le_bytes());
        }

        fn u32(&mut self, x: u32) {
            self.out.extend_from_slice(&x.to_le_bytes());
        }

        fn u64(&mut self, x: u64) {
            self.out.extend_from_slice(&x.to_le_bytes());
        }

        fn f64(&mut self, x: f64) {
            self.out.extend_from_slice(&x.to_le_bytes());
        }

        fn f32s(&mut self, xs: &[f32]) {
            self.out.reserve(xs.len() * 4);
            for x in xs {
                self.out.extend_from_slice(&x.to_le_bytes());
            }
        }

        fn bytes(&mut self, b: &[u8]) {
            self.out.extend_from_slice(b);
        }

        fn finish(mut self) -> Vec<u8> {
            let len = (self.out.len() - HEADER_LEN) as u32;
            self.out[3..7].copy_from_slice(&len.to_le_bytes());
            self.out
        }
    }

    fn flags(verify: bool, want_c: bool) -> u8 {
        (verify as u8) * FLAG_VERIFY | (want_c as u8) * FLAG_WANT_C
    }

    /// Append the tenant slot (`tlen u8 | tenant utf8`). Callers gate on a
    /// non-empty tenant; the u8 length prefix is what caps tenant names at
    /// 255 bytes ([`super::MAX_TENANT_LEN`]) across both wire planes.
    fn put_tenant(w: &mut Builder, tenant: &str) {
        debug_assert!(!tenant.is_empty() && tenant.len() <= u8::MAX as usize);
        w.u8(tenant.len() as u8);
        w.bytes(tenant.as_bytes());
    }

    /// Read the flagged tenant slot.
    fn read_tenant(c: &mut Cur<'_>) -> Result<String, String> {
        let tlen = c.u8()? as usize;
        if tlen == 0 {
            return Err("invalid tenant: must be non-empty".into());
        }
        utf8(c.take(tlen)?, "tenant")
    }

    /// `spdm` with both operands inline:
    /// `id u64 | n u32 | flags u8 | algo u8 | a n² f32 | b n² f32`.
    pub fn encode_spdm_inline(
        id: u64,
        n: usize,
        a: &[f32],
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Vec<u8> {
        let mut w = Builder::new(FT_SPDM_INLINE, 14 + (a.len() + b.len()) * 4);
        w.u64(id);
        w.u32(n as u32);
        w.u8(flags(verify, want_c));
        w.u8(algo_to_byte(algo));
        w.f32s(a);
        w.f32s(b);
        w.finish()
    }

    /// Tenant-tagged [`encode_spdm_inline`]: the tenant slot sits between
    /// the fixed fields and the operands, gated by `FLAG_TENANT`. An empty
    /// tenant delegates — byte-identical to the untenanted frame.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_spdm_inline_t(
        id: u64,
        n: usize,
        a: &[f32],
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
        tenant: &str,
    ) -> Vec<u8> {
        if tenant.is_empty() {
            return encode_spdm_inline(id, n, a, b, algo, verify, want_c);
        }
        let mut w = Builder::new(FT_SPDM_INLINE, 15 + tenant.len() + (a.len() + b.len()) * 4);
        w.u64(id);
        w.u32(n as u32);
        w.u8(flags(verify, want_c) | FLAG_TENANT);
        w.u8(algo_to_byte(algo));
        put_tenant(&mut w, tenant);
        w.f32s(a);
        w.f32s(b);
        w.finish()
    }

    /// `spdm` by registered handle with inline B:
    /// `id u64 | a_handle u64 | n u32 | flags u8 | algo u8 | b n² f32`.
    pub fn encode_spdm_handle_b(
        id: u64,
        a_handle: u64,
        n: usize,
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Vec<u8> {
        let mut w = Builder::new(FT_SPDM_HANDLE_B, 22 + b.len() * 4);
        w.u64(id);
        w.u64(a_handle);
        w.u32(n as u32);
        w.u8(flags(verify, want_c));
        w.u8(algo_to_byte(algo));
        w.f32s(b);
        w.finish()
    }

    /// Tenant-tagged [`encode_spdm_handle_b`] (empty tenant delegates).
    #[allow(clippy::too_many_arguments)]
    pub fn encode_spdm_handle_b_t(
        id: u64,
        a_handle: u64,
        n: usize,
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
        tenant: &str,
    ) -> Vec<u8> {
        if tenant.is_empty() {
            return encode_spdm_handle_b(id, a_handle, n, b, algo, verify, want_c);
        }
        let mut w = Builder::new(FT_SPDM_HANDLE_B, 23 + tenant.len() + b.len() * 4);
        w.u64(id);
        w.u64(a_handle);
        w.u32(n as u32);
        w.u8(flags(verify, want_c) | FLAG_TENANT);
        w.u8(algo_to_byte(algo));
        put_tenant(&mut w, tenant);
        w.f32s(b);
        w.finish()
    }

    /// `spdm` by registered handle with server-side seeded B:
    /// `id u64 | a_handle u64 | seed u64 | flags u8 | algo u8`.
    pub fn encode_spdm_handle_seed(
        id: u64,
        a_handle: u64,
        seed: u64,
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Vec<u8> {
        let mut w = Builder::new(FT_SPDM_HANDLE_SEED, 26);
        w.u64(id);
        w.u64(a_handle);
        w.u64(seed);
        w.u8(flags(verify, want_c));
        w.u8(algo_to_byte(algo));
        w.finish()
    }

    /// Tenant-tagged [`encode_spdm_handle_seed`] (empty tenant delegates).
    pub fn encode_spdm_handle_seed_t(
        id: u64,
        a_handle: u64,
        seed: u64,
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
        tenant: &str,
    ) -> Vec<u8> {
        if tenant.is_empty() {
            return encode_spdm_handle_seed(id, a_handle, seed, algo, verify, want_c);
        }
        let mut w = Builder::new(FT_SPDM_HANDLE_SEED, 27 + tenant.len());
        w.u64(id);
        w.u64(a_handle);
        w.u64(seed);
        w.u8(flags(verify, want_c) | FLAG_TENANT);
        w.u8(algo_to_byte(algo));
        put_tenant(&mut w, tenant);
        w.finish()
    }

    /// `put_a` with an inline operand:
    /// `id u64 | n u32 | algo u8 | a n² f32`.
    pub fn encode_put_a(id: u64, n: usize, a: &[f32], algo: Option<Algo>) -> Vec<u8> {
        let mut w = Builder::new(FT_PUT_A, 13 + a.len() * 4);
        w.u64(id);
        w.u32(n as u32);
        w.u8(algo_to_byte(algo));
        w.f32s(a);
        w.finish()
    }

    /// Tenant-tagged `put_a` ([`FT_PUT_A_T`]):
    /// `id u64 | n u32 | algo u8 | tlen u8 | tenant utf8 | a n² f32`.
    /// An empty tenant delegates to the untenanted [`FT_PUT_A`] frame.
    pub fn encode_put_a_t(id: u64, n: usize, a: &[f32], algo: Option<Algo>, tenant: &str) -> Vec<u8> {
        if tenant.is_empty() {
            return encode_put_a(id, n, a, algo);
        }
        let mut w = Builder::new(FT_PUT_A_T, 14 + tenant.len() + a.len() * 4);
        w.u64(id);
        w.u32(n as u32);
        w.u8(algo_to_byte(algo));
        put_tenant(&mut w, tenant);
        w.f32s(a);
        w.finish()
    }

    /// `ping`: `id u64`.
    pub fn encode_ping(id: u64) -> Vec<u8> {
        let mut w = Builder::new(FT_PING, 8);
        w.u64(id);
        w.finish()
    }

    /// Validate a frame's declared n×n dims against the bytes it actually
    /// carries **before any buffer is sized**, in checked u64 arithmetic.
    /// The declared `n` is attacker-controlled: `operands·n²·4` wraps even
    /// in 64-bit release math (n = 2³¹ makes `2·n²·4` ≡ 0 mod 2⁶⁴, so an
    /// empty payload would pass an unchecked equality and the decoder
    /// would then try to reserve n² floats). Overflow or an implied size
    /// beyond [`MAX_PAYLOAD`] is rejected with a typed error, as is any
    /// mismatch with `remaining`. Returns the per-operand float count the
    /// cursor may safely allocate.
    fn checked_operand_floats(
        n: usize,
        operands: usize,
        remaining: usize,
        what: &str,
    ) -> Result<usize, String> {
        let bytes = (n as u64)
            .checked_mul(n as u64)
            .and_then(|e| e.checked_mul(4))
            .and_then(|b| b.checked_mul(operands as u64))
            .filter(|&b| b <= MAX_PAYLOAD as u64);
        let bytes = bytes.ok_or_else(|| {
            format!(
                "{what} declares dims {n}x{n}: {operands}·n²·4 operand bytes overflow the \
                 {MAX_PAYLOAD}-byte frame cap"
            )
        })?;
        if bytes != remaining as u64 {
            return Err(format!(
                "{what} payload carries {remaining} operand bytes, expected {operands}·n²·4 = \
                 {bytes} for n={n}"
            ));
        }
        Ok(n * n)
    }

    /// Decode a request frame payload into the **same [`Request`] the JSON
    /// plane produces** — from here on the two planes share one dispatch
    /// path, which is what makes "encoding never changes results" a
    /// structural guarantee rather than a test-enforced hope. Returns the
    /// request plus the `want_c` flag (binary-only reply option).
    pub fn decode_request(ftype: u8, payload: &[u8]) -> Result<(Request, bool), String> {
        let mut c = Cur::new(payload);
        match ftype {
            FT_SPDM_INLINE => {
                let id = c.u64()?;
                let n = c.u32()? as usize;
                let fl = c.u8()?;
                let algo = algo_from_byte(c.u8()?)?;
                let tenant = if fl & FLAG_TENANT != 0 {
                    read_tenant(&mut c)?
                } else {
                    DEFAULT_TENANT.to_string()
                };
                if n == 0 {
                    return Err("n must be positive".into());
                }
                let floats = checked_operand_floats(n, 2, c.remaining(), "spdm_inline")?;
                let a = c.f32s(floats, "a")?;
                let b = c.f32s(floats, "b")?;
                c.done("spdm_inline")?;
                Ok((
                    Request::Spdm {
                        id,
                        n,
                        payload: Payload::Inline { a, b },
                        algo,
                        verify: fl & FLAG_VERIFY != 0,
                        tenant,
                    },
                    fl & FLAG_WANT_C != 0,
                ))
            }
            FT_SPDM_HANDLE_B => {
                let id = c.u64()?;
                let a_handle = c.u64()?;
                let n = c.u32()? as usize;
                let fl = c.u8()?;
                let algo = algo_from_byte(c.u8()?)?;
                let tenant = if fl & FLAG_TENANT != 0 {
                    read_tenant(&mut c)?
                } else {
                    DEFAULT_TENANT.to_string()
                };
                if n == 0 {
                    return Err("n must be positive".into());
                }
                let floats = checked_operand_floats(n, 1, c.remaining(), "spdm_handle_b")?;
                let b = c.f32s(floats, "b")?;
                c.done("spdm_handle_b")?;
                Ok((
                    Request::Spdm {
                        id,
                        n,
                        payload: Payload::Handle { a_handle, b: BPayload::Inline(b) },
                        algo,
                        verify: fl & FLAG_VERIFY != 0,
                        tenant,
                    },
                    fl & FLAG_WANT_C != 0,
                ))
            }
            FT_SPDM_HANDLE_SEED => {
                let id = c.u64()?;
                let a_handle = c.u64()?;
                let seed = c.u64()?;
                let fl = c.u8()?;
                let algo = algo_from_byte(c.u8()?)?;
                let tenant = if fl & FLAG_TENANT != 0 {
                    read_tenant(&mut c)?
                } else {
                    DEFAULT_TENANT.to_string()
                };
                c.done("spdm_handle_seed")?;
                Ok((
                    Request::Spdm {
                        id,
                        n: 0,
                        payload: Payload::Handle { a_handle, b: BPayload::Synthetic { seed } },
                        algo,
                        verify: fl & FLAG_VERIFY != 0,
                        tenant,
                    },
                    fl & FLAG_WANT_C != 0,
                ))
            }
            FT_PUT_A => {
                let id = c.u64()?;
                let n = c.u32()? as usize;
                let algo = algo_from_byte(c.u8()?)?;
                if n == 0 {
                    return Err("n must be positive".into());
                }
                let floats = checked_operand_floats(n, 1, c.remaining(), "put_a")?;
                let a = c.f32s(floats, "a")?;
                c.done("put_a")?;
                Ok((
                    Request::PutA {
                        id,
                        n,
                        payload: super::APayload::Inline { a },
                        algo,
                        tenant: DEFAULT_TENANT.to_string(),
                    },
                    false,
                ))
            }
            FT_PUT_A_T => {
                let id = c.u64()?;
                let n = c.u32()? as usize;
                let algo = algo_from_byte(c.u8()?)?;
                let tenant = read_tenant(&mut c)?;
                if n == 0 {
                    return Err("n must be positive".into());
                }
                let floats = checked_operand_floats(n, 1, c.remaining(), "put_a")?;
                let a = c.f32s(floats, "a")?;
                c.done("put_a")?;
                Ok((
                    Request::PutA {
                        id,
                        n,
                        payload: super::APayload::Inline { a },
                        algo,
                        tenant,
                    },
                    false,
                ))
            }
            FT_PING => {
                let id = c.u64()?;
                c.done("ping")?;
                Ok((Request::Ping { id }, false))
            }
            other => Err(format!("unknown request frame type 0x{other:02x}")),
        }
    }

    /// Successful `spdm` reply:
    /// `id u64 | algo u8 | verified i8 (−1 absent/0/1) | n_exec u32 |
    ///  convert_ms f64 | kernel_ms f64 | total_ms f64 |
    ///  has_checksum u8 | checksum f64 (bit-faithful) |
    ///  a_handle+1 u64 (0 = none) | artifact len u16 + utf8 |
    ///  c_n u32 (0 = absent) | c c_n² f32`.
    pub fn encode_resp_spdm(r: &Response, c: Option<&Mat>) -> Vec<u8> {
        let c_floats = c.map(|m| m.data.len()).unwrap_or(0);
        let mut w = Builder::new(FT_RESP_SPDM, 64 + c_floats * 4);
        w.u64(r.id);
        w.u8(algo_to_byte(r.algo.as_deref().and_then(Algo::from_str)));
        w.u8(match r.verified {
            None => -1i8 as u8,
            Some(false) => 0,
            Some(true) => 1,
        });
        w.u32(r.n_exec.unwrap_or(0) as u32);
        w.f64(r.convert_ms.unwrap_or(0.0));
        w.f64(r.kernel_ms.unwrap_or(0.0));
        w.f64(r.total_ms.unwrap_or(0.0));
        w.u8(r.checksum.is_some() as u8);
        w.f64(r.checksum.unwrap_or(0.0));
        w.u64(r.a_handle.map(|h| h + 1).unwrap_or(0));
        let artifact = r.artifact.as_deref().unwrap_or("");
        w.u16(artifact.len() as u16);
        w.bytes(artifact.as_bytes());
        match c {
            Some(m) => {
                w.u32(m.rows as u32);
                // Raw LE f32: the response-side twin of the operand
                // payloads — C returns as a memcpy, never as text.
                w.f32s(&m.data);
            }
            None => w.u32(0),
        }
        w.finish()
    }

    /// Typed error reply: `id u64 | utf8 message (rest of payload)`.
    pub fn encode_resp_err(id: u64, msg: &str) -> Vec<u8> {
        let mut w = Builder::new(FT_RESP_ERR, 8 + msg.len());
        w.u64(id);
        w.bytes(msg.as_bytes());
        w.finish()
    }

    /// Successful `put_a` reply:
    /// `id u64 | a_handle u64 | algo u8 | n_exec u32 | convert_ms f64 |
    ///  artifact len u16 + utf8 | reason utf8 (rest)`.
    pub fn encode_resp_put_a(r: &Response) -> Vec<u8> {
        let mut w = Builder::new(FT_RESP_PUT_A, 48);
        w.u64(r.id);
        w.u64(r.a_handle.unwrap_or(0));
        w.u8(algo_to_byte(r.algo.as_deref().and_then(Algo::from_str)));
        w.u32(r.n_exec.unwrap_or(0) as u32);
        w.f64(r.convert_ms.unwrap_or(0.0));
        let artifact = r.artifact.as_deref().unwrap_or("");
        w.u16(artifact.len() as u16);
        w.bytes(artifact.as_bytes());
        w.bytes(r.reason.as_deref().unwrap_or("").as_bytes());
        w.finish()
    }

    /// `pong`: `id u64`.
    pub fn encode_resp_pong(id: u64) -> Vec<u8> {
        let mut w = Builder::new(FT_RESP_PONG, 8);
        w.u64(id);
        w.finish()
    }

    fn utf8(bytes: &[u8], what: &str) -> Result<String, String> {
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| format!("invalid utf-8 in {what}"))
    }

    /// Decode a response frame payload into the shared [`Response`] struct
    /// (plus the returned C matrix when the reply carries one). The same
    /// struct the JSON plane parses into, so clients and tests compare the
    /// two planes field-for-field.
    pub fn decode_response(ftype: u8, payload: &[u8]) -> Result<(Response, Option<Mat>), String> {
        let mut c = Cur::new(payload);
        match ftype {
            FT_RESP_SPDM => {
                let id = c.u64()?;
                let algo = algo_from_byte(c.u8()?)?;
                let verified = match c.u8()? as i8 {
                    -1 => None,
                    0 => Some(false),
                    1 => Some(true),
                    other => return Err(format!("bad verified byte {other}")),
                };
                let n_exec = c.u32()? as usize;
                let convert_ms = c.f64()?;
                let kernel_ms = c.f64()?;
                let total_ms = c.f64()?;
                let has_checksum = c.u8()? != 0;
                let checksum = c.f64()?;
                let a_handle = match c.u64()? {
                    0 => None,
                    h => Some(h - 1),
                };
                let alen = c.u16()? as usize;
                let artifact = utf8(c.take(alen)?, "artifact")?;
                let c_n = c.u32()? as usize;
                let mat = if c_n > 0 {
                    // Same checked-dims rule as the request side: the
                    // declared C size must match what the frame carries
                    // before `take` sizes anything (`c_n²·4` wraps for
                    // adversarial c_n just like the operand paths).
                    let floats = checked_operand_floats(c_n, 1, c.remaining(), "resp_spdm c")?;
                    let bytes = c.take(floats * 4)?;
                    let mut m = Mat::zeros(0, 0);
                    m.fill_from_le_bytes(c_n, c_n, bytes)?;
                    Some(m)
                } else {
                    None
                };
                c.done("resp_spdm")?;
                Ok((
                    Response {
                        id,
                        ok: true,
                        algo: algo.map(|a| a.as_str().to_string()),
                        artifact: Some(artifact),
                        n_exec: Some(n_exec),
                        convert_ms: Some(convert_ms),
                        kernel_ms: Some(kernel_ms),
                        total_ms: Some(total_ms),
                        verified,
                        checksum: has_checksum.then_some(checksum),
                        a_handle,
                        ..Default::default()
                    },
                    mat,
                ))
            }
            FT_RESP_ERR => {
                let id = c.u64()?;
                let msg = utf8(c.take(c.remaining())?, "error message")?;
                Ok((
                    Response { id, ok: false, error: Some(msg), ..Default::default() },
                    None,
                ))
            }
            FT_RESP_PUT_A => {
                let id = c.u64()?;
                let a_handle = c.u64()?;
                let algo = algo_from_byte(c.u8()?)?;
                let n_exec = c.u32()? as usize;
                let convert_ms = c.f64()?;
                let alen = c.u16()? as usize;
                let artifact = utf8(c.take(alen)?, "artifact")?;
                let reason = utf8(c.take(c.remaining())?, "reason")?;
                Ok((
                    Response {
                        id,
                        ok: true,
                        a_handle: Some(a_handle),
                        algo: algo.map(|a| a.as_str().to_string()),
                        artifact: Some(artifact),
                        n_exec: Some(n_exec),
                        convert_ms: Some(convert_ms),
                        reason: Some(reason),
                        ..Default::default()
                    },
                    None,
                ))
            }
            FT_RESP_PONG => {
                let id = c.u64()?;
                c.done("pong")?;
                Ok((Response { id, ok: true, ..Default::default() }, None))
            }
            other => Err(format!("unknown response frame type 0x{other:02x}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_request() {
        let r = parse_request(
            r#"{"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,"pattern":"banded","seed":7,"algo":"gcoo","verify":true}"#,
        )
        .unwrap();
        match r {
            Request::Spdm { id, n, payload, algo, verify, tenant } => {
                assert_eq!((id, n, verify), (1, 256, true));
                assert_eq!(algo, Some(Algo::Gcoo));
                assert_eq!(tenant, "default", "absent tenant resolves to default");
                assert_eq!(
                    payload,
                    Payload::Synthetic { sparsity: 0.99, pattern: "banded".into(), seed: 7 }
                );
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_inline_request_checks_sizes() {
        let ok = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,4]}"#;
        assert!(matches!(parse_request(ok), Ok(Request::Spdm { .. })));
        let bad = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1],"b":[1,2,3,4]}"#;
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn parse_control_requests() {
        assert!(matches!(parse_request(r#"{"id":3,"type":"ping"}"#), Ok(Request::Ping { id: 3 })));
        assert!(matches!(
            parse_request(r#"{"id":4,"type":"metrics"}"#),
            Ok(Request::Metrics { id: 4 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":6,"type":"stats"}"#),
            Ok(Request::Stats { id: 6 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":5,"type":"shutdown"}"#),
            Ok(Request::Shutdown { id: 5 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":7,"type":"explain"}"#),
            Ok(Request::Explain { id: 7 })
        ));
    }

    #[test]
    fn explain_response_round_trips() {
        let r = Response {
            id: 12,
            ok: true,
            routing: Some(r#"{"route_flips":1,"entries":[]}"#.into()),
            ..Default::default()
        };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed, r);
        // The payload is itself parseable JSON (the explain contract).
        let doc = crate::json::parse(parsed.routing.as_deref().unwrap()).unwrap();
        assert_eq!(doc.get("route_flips").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn bad_requests_rejected() {
        assert!(parse_request("garbage").is_err());
        assert!(parse_request(r#"{"type":"spdm"}"#).is_err()); // no id
        assert!(parse_request(r#"{"id":1,"type":"spdm"}"#).is_err()); // no n
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":0}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"warp"}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":4,"algo":"nope"}"#).is_err());
    }

    #[test]
    fn parse_handle_spdm_requests() {
        // Inline B; n optional on handle requests.
        let r = parse_request(r#"{"id":8,"type":"spdm","a_handle":3,"b":[1,2,3,4],"verify":true}"#)
            .unwrap();
        match r {
            Request::Spdm { id, n, payload, algo, verify, .. } => {
                assert_eq!((id, n, verify), (8, 0, true));
                assert_eq!(algo, None);
                assert_eq!(
                    payload,
                    Payload::Handle { a_handle: 3, b: BPayload::Inline(vec![1.0, 2.0, 3.0, 4.0]) }
                );
            }
            _ => panic!("wrong variant"),
        }
        // Synthetic B from a seed; explicit n is validated against b when
        // inline and carried through otherwise.
        let r = parse_request(r#"{"id":9,"type":"spdm","a_handle":3,"seed":7,"algo":"gcoo"}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Spdm {
                id: 9,
                n: 0,
                payload: Payload::Handle { a_handle: 3, b: BPayload::Synthetic { seed: 7 } },
                algo: Some(Algo::Gcoo),
                verify: false,
                tenant: "default".into(),
            }
        );
        // Explicit n with a mismatched inline B fails at parse.
        assert!(parse_request(
            r#"{"id":8,"type":"spdm","a_handle":3,"n":4,"b":[1,2,3,4]}"#
        )
        .is_err());
        // A malformed a_handle is an error, not a silent fall-through to
        // the v1 synthetic path (which would multiply against the wrong A).
        for bad in [
            r#"{"id":8,"type":"spdm","a_handle":"3","n":64,"seed":7}"#,
            r#"{"id":8,"type":"spdm","a_handle":-1,"n":64,"seed":7}"#,
            r#"{"id":8,"type":"spdm","a_handle":3.5,"n":64,"seed":7}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("a_handle"), "{bad} → {err}");
        }
    }

    #[test]
    fn parse_put_a_requests() {
        let r = parse_request(
            r#"{"id":6,"type":"put_a","n":64,"payload":"synthetic","sparsity":0.99,"pattern":"banded","seed":5,"algo":"csr"}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::PutA {
                id: 6,
                n: 64,
                payload: APayload::Synthetic { sparsity: 0.99, pattern: "banded".into(), seed: 5 },
                algo: Some(Algo::Csr),
                tenant: "default".into(),
            }
        );
        let r = parse_request(r#"{"id":7,"type":"put_a","n":2,"payload":"inline","a":[1,0,0,1]}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::PutA {
                id: 7,
                n: 2,
                payload: APayload::Inline { a: vec![1.0, 0.0, 0.0, 1.0] },
                algo: None,
                tenant: "default".into(),
            }
        );
        // Size and positivity checks mirror v1 spdm.
        assert!(parse_request(r#"{"id":7,"type":"put_a","n":2,"payload":"inline","a":[1]}"#).is_err());
        assert!(parse_request(r#"{"id":7,"type":"put_a","n":0}"#).is_err());
        assert!(parse_request(r#"{"id":7,"type":"put_a"}"#).is_err());
    }

    #[test]
    fn parse_handle_lifecycle_requests() {
        assert_eq!(
            parse_request(r#"{"id":10,"type":"drop_a","a_handle":3}"#).unwrap(),
            Request::DropA { id: 10, a_handle: 3 }
        );
        assert!(parse_request(r#"{"id":10,"type":"drop_a"}"#).is_err(), "a_handle required");
        assert_eq!(parse_request(r#"{"id":11,"type":"list_a"}"#).unwrap(), Request::ListA { id: 11 });
    }

    /// Satellite bugfix: non-finite floats in inline payloads are rejected
    /// at the boundary — a NaN would split `ASig` equality from the
    /// element-equality re-screen (NaN != NaN) and silently demote fusable
    /// batches; Inf poisons every product.
    #[test]
    fn non_finite_inline_floats_rejected() {
        // Our writer never emits bare NaN/Infinity tokens, but "1e999"
        // overflows f64 parsing to +Inf — a real wire-level vector.
        let inf = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1e999],"b":[1,2,3,4]}"#;
        let err = parse_request(inf).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        let inf_b = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,-1e999]}"#;
        assert!(parse_request(inf_b).unwrap_err().contains("non-finite"));
        let put = r#"{"id":2,"type":"put_a","n":2,"payload":"inline","a":[1e999,0,0,1]}"#;
        assert!(parse_request(put).unwrap_err().contains("non-finite"));
        let handle_b = r#"{"id":2,"type":"spdm","a_handle":1,"b":[1e999]}"#;
        assert!(parse_request(handle_b).unwrap_err().contains("non-finite"));
        // A finite f64 beyond f32::MAX saturates to Inf in the cast the
        // pipeline performs — it must be rejected like a literal Inf.
        let overflow = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1e39,0,0,1],"b":[1,2,3,4]}"#;
        assert!(parse_request(overflow).unwrap_err().contains("non-finite"));
        // The f32 edge itself stays valid.
        let edge = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[3.4e38,0,0,1],"b":[1,2,3,4]}"#;
        assert!(parse_request(edge).is_ok());
    }

    /// Satellite bugfix: synthetic parameters are validated at parse time —
    /// sparsity outside [0, 1) and unknown pattern names fail the request
    /// instead of flowing into generation.
    #[test]
    fn synthetic_params_validated_at_parse() {
        for bad in [
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":1.0}"#,
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":-0.1}"#,
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":2.5}"#,
            r#"{"id":1,"type":"put_a","n":8,"payload":"synthetic","sparsity":1.5}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("sparsity"), "{bad} → {err}");
        }
        for bad in [
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","pattern":"not_a_pattern"}"#,
            r#"{"id":1,"type":"put_a","n":8,"payload":"synthetic","pattern":"warp"}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("pattern"), "{bad} → {err}");
        }
        // The valid edges stay valid.
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":0.0}"#).is_ok());
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":0.999}"#).is_ok());
    }

    #[test]
    fn v2_response_round_trip() {
        let r = Response {
            id: 6,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n256_cap512".into()),
            n_exec: Some(256),
            convert_ms: Some(0.75),
            a_handle: Some(3),
            reason: Some("sparse-crossover".into()),
            ..Default::default()
        };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
        let r = Response {
            id: 11,
            ok: true,
            handles: Some(vec![
                HandleInfo {
                    a_handle: 3,
                    n: 256,
                    nnz: 655,
                    algo: "gcoo".into(),
                    artifact: "gcoo_n256_cap512".into(),
                    bytes: 270336,
                    tier: "ram".into(),
                    last_used_seq: 12,
                },
                HandleInfo {
                    a_handle: 4,
                    n: 64,
                    nnz: 40,
                    algo: "csr".into(),
                    artifact: "csr_n64_rowcap64".into(),
                    bytes: 18432,
                    tier: "spilled".into(),
                    last_used_seq: 7,
                },
            ]),
            ..Default::default()
        };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
        // Empty list round-trips too.
        let r = Response { id: 12, ok: true, handles: Some(vec![]), ..Default::default() };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
    }

    #[test]
    fn response_round_trip() {
        let r = Response {
            id: 9,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n256_p8_tb128_cap256".into()),
            n_exec: Some(256),
            convert_ms: Some(0.5),
            kernel_ms: Some(2.25),
            total_ms: Some(3.5),
            verified: Some(true),
            checksum: Some(42.5),
            ..Default::default()
        };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn error_response_round_trip() {
        let r = Response { id: 1, ok: false, error: Some("no artifact".into()), ..Default::default() };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed.error.as_deref(), Some("no artifact"));
        assert!(!parsed.ok);
    }

    // ---- wire protocol v3: frame codec --------------------------------

    /// Split one encoded frame into (header, payload), validating the
    /// header the way the connection handler does.
    fn split(bytes: &[u8]) -> (frame::Header, &[u8]) {
        let hdr: [u8; frame::HEADER_LEN] = bytes[..frame::HEADER_LEN].try_into().unwrap();
        let h = frame::parse_header(&hdr).unwrap();
        let payload = &bytes[frame::HEADER_LEN..];
        assert_eq!(payload.len(), h.len, "length prefix must match payload");
        (h, payload)
    }

    #[test]
    fn frame_request_round_trips() {
        let a = vec![1.0f32, -0.0, 3.5e-41, 2.0]; // incl. -0.0 and a subnormal
        let b = vec![4.0f32, 5.0, 6.0, f32::MAX];
        let (h, p) = split(&frame::encode_spdm_inline(7, 2, &a, &b, Some(Algo::Gcoo), true, true));
        let (req, want_c) = frame::decode_request(h.ftype, p).unwrap();
        assert!(want_c);
        assert_eq!(
            req,
            Request::Spdm {
                id: 7,
                n: 2,
                payload: Payload::Inline { a: a.clone(), b: b.clone() },
                algo: Some(Algo::Gcoo),
                verify: true,
                tenant: "default".into(),
            }
        );

        let (h, p) = split(&frame::encode_spdm_handle_b(8, 3, 2, &b, None, false, false));
        let (req, want_c) = frame::decode_request(h.ftype, p).unwrap();
        assert!(!want_c);
        assert_eq!(
            req,
            Request::Spdm {
                id: 8,
                n: 2,
                payload: Payload::Handle { a_handle: 3, b: BPayload::Inline(b.clone()) },
                algo: None,
                verify: false,
                tenant: "default".into(),
            }
        );

        let (h, p) = split(&frame::encode_spdm_handle_seed(9, 3, 42, Some(Algo::Csr), true, false));
        let (req, _) = frame::decode_request(h.ftype, p).unwrap();
        assert_eq!(
            req,
            Request::Spdm {
                id: 9,
                n: 0,
                payload: Payload::Handle { a_handle: 3, b: BPayload::Synthetic { seed: 42 } },
                algo: Some(Algo::Csr),
                verify: true,
                tenant: "default".into(),
            }
        );

        let (h, p) = split(&frame::encode_put_a(10, 2, &a, None));
        let (req, _) = frame::decode_request(h.ftype, p).unwrap();
        assert_eq!(
            req,
            Request::PutA {
                id: 10,
                n: 2,
                payload: APayload::Inline { a: a.clone() },
                algo: None,
                tenant: "default".into(),
            }
        );

        let (h, p) = split(&frame::encode_ping(11));
        assert_eq!(frame::decode_request(h.ftype, p).unwrap().0, Request::Ping { id: 11 });
    }

    /// The structural core of the differential obligation: a binary frame
    /// and a JSON line describing the same request decode into the *same*
    /// `Request` value, so everything downstream of the protocol boundary
    /// is shared — encoding cannot change results.
    #[test]
    fn frame_decodes_to_same_request_as_json() {
        let a = vec![1.5f32, 0.0, -2.25, 4.0];
        let b = vec![0.5f32, 1.0, -1.0, 8.0];
        let json = r#"{"id":3,"type":"spdm","n":2,"payload":"inline","a":[1.5,0,-2.25,4],"b":[0.5,1,-1,8],"algo":"gcoo","verify":true}"#;
        let via_json = parse_request(json).unwrap();
        let (h, p) = split(&frame::encode_spdm_inline(3, 2, &a, &b, Some(Algo::Gcoo), true, false));
        let (via_frame, _) = frame::decode_request(h.ftype, p).unwrap();
        assert_eq!(via_frame, via_json);
    }

    #[test]
    fn frame_response_round_trips() {
        let c = crate::ndarray::Mat::from_vec(2, 2, vec![1.0, -0.0, f32::MAX, 0.25]);
        let r = Response {
            id: 5,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n64_cap64".into()),
            n_exec: Some(64),
            convert_ms: Some(0.5),
            kernel_ms: Some(1.25),
            total_ms: Some(2.0),
            verified: Some(true),
            checksum: Some(42.062_5),
            a_handle: Some(0), // handle 0 is valid — the +1 bias must keep it
            ..Default::default()
        };
        let bytes = frame::encode_resp_spdm(&r, Some(&c));
        let (h, p) = split(&bytes);
        let (back, mat) = frame::decode_response(h.ftype, p).unwrap();
        assert_eq!(back, r);
        let mat = mat.expect("want_c reply carries C");
        assert_eq!(mat.data.len(), c.data.len());
        for (x, y) in mat.data.iter().zip(&c.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "C transport must be bit-faithful");
        }

        // Without C, and with absent optionals.
        let r2 = Response {
            id: 6,
            ok: true,
            algo: Some("dense_xla".into()),
            artifact: Some("dense_xla_n64".into()),
            n_exec: Some(64),
            convert_ms: Some(0.0),
            kernel_ms: Some(1.0),
            total_ms: Some(1.0),
            verified: None,
            checksum: Some(-1.5),
            a_handle: None,
            ..Default::default()
        };
        let (h, p) = split(&frame::encode_resp_spdm(&r2, None));
        let (back, mat) = frame::decode_response(h.ftype, p).unwrap();
        assert_eq!(back, r2);
        assert!(mat.is_none());

        let (h, p) = split(&frame::encode_resp_err(9, "unknown operand handle a#7"));
        let (back, _) = frame::decode_response(h.ftype, p).unwrap();
        assert!(!back.ok);
        assert_eq!(back.id, 9);
        assert_eq!(back.error.as_deref(), Some("unknown operand handle a#7"));

        let put = Response {
            id: 12,
            ok: true,
            a_handle: Some(3),
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n64_cap512".into()),
            n_exec: Some(64),
            convert_ms: Some(0.75),
            reason: Some("sparse-crossover".into()),
            ..Default::default()
        };
        let (h, p) = split(&frame::encode_resp_put_a(&put));
        assert_eq!(frame::decode_response(h.ftype, p).unwrap().0, put);

        let (h, p) = split(&frame::encode_resp_pong(13));
        let (back, _) = frame::decode_response(h.ftype, p).unwrap();
        assert!(back.ok && back.id == 13);
    }

    #[test]
    fn frame_header_rejects_garbage_magic_version_and_oversize_length() {
        let ok = frame::encode_ping(1);
        let mut h: [u8; frame::HEADER_LEN] = ok[..frame::HEADER_LEN].try_into().unwrap();
        assert!(frame::parse_header(&h).is_ok());
        // Garbage magic — including `{`, which must route to the JSON
        // plane, never reach the frame parser as a valid magic.
        for bad in [0x00u8, b'{', b'P', 0xFF] {
            let mut g = h;
            g[0] = bad;
            let err = frame::parse_header(&g).unwrap_err();
            assert!(err.contains("magic"), "{err}");
        }
        // Foreign version byte.
        let mut g = h;
        g[1] = 0x02;
        assert!(frame::parse_header(&g).unwrap_err().contains("version"));
        // Oversize length prefix is rejected before any allocation.
        h[3..7].copy_from_slice(&(frame::MAX_PAYLOAD as u32 + 1).to_le_bytes());
        assert!(frame::parse_header(&h).unwrap_err().contains("exceeds"));
    }

    /// Every strict prefix of a valid payload must decode to an error —
    /// never a panic, never a silently short operand.
    #[test]
    fn frame_truncation_always_errors() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![5.0f32, 6.0, 7.0, 8.0];
        for full in [
            frame::encode_spdm_inline(1, 2, &a, &b, None, false, false),
            frame::encode_spdm_handle_b(2, 1, 2, &b, None, true, true),
            frame::encode_spdm_handle_seed(3, 1, 9, None, false, false),
            frame::encode_put_a(4, 2, &a, Some(Algo::Gcoo)),
            frame::encode_ping(5),
            frame::encode_spdm_inline_t(1, 2, &a, &b, None, false, false, "alpha"),
            frame::encode_spdm_handle_b_t(2, 1, 2, &b, None, true, true, "alpha"),
            frame::encode_spdm_handle_seed_t(3, 1, 9, None, false, false, "alpha"),
            frame::encode_put_a_t(4, 2, &a, Some(Algo::Gcoo), "alpha"),
        ] {
            let (h, payload) = split(&full);
            for cut in 0..payload.len() {
                assert!(
                    frame::decode_request(h.ftype, &payload[..cut]).is_err(),
                    "prefix of {cut}/{} bytes must fail (ftype 0x{:02x})",
                    payload.len(),
                    h.ftype
                );
            }
            // And trailing garbage is malformed too.
            let mut long = payload.to_vec();
            long.push(0xEE);
            assert!(frame::decode_request(h.ftype, &long).is_err());
        }
        assert!(frame::decode_request(0x7E, &[0u8; 8]).is_err(), "unknown frame type");
    }

    /// Satellite (PR 8): declared dims are validated with checked
    /// arithmetic *before* any buffer is sized. A tiny frame claiming a
    /// 60000×60000 A (≈ 28.8 GB of operands) must get a typed error, and
    /// an n crafted so the old unchecked `2·n²·4` wraps to 0 mod 2⁶⁴
    /// (n = 2³¹, empty operand region) must not slip past the length
    /// equality into an n²-float reservation.
    #[test]
    fn frame_checked_dims_reject_overflow_and_wrap_before_allocation() {
        // id u64 | n u32 | flags u8 | algo u8 — header fields only, no
        // operand bytes at all (a "20-byte frame" in ISSUE terms).
        let tiny_inline = |n: u32| {
            let mut p = Vec::new();
            p.extend_from_slice(&7u64.to_le_bytes());
            p.extend_from_slice(&n.to_le_bytes());
            p.push(0); // flags
            p.push(0); // algo auto
            p
        };
        // Over the frame cap: typed error naming the declared dims.
        let err = frame::decode_request(frame::FT_SPDM_INLINE, &tiny_inline(60000)).unwrap_err();
        assert!(err.contains("60000x60000"), "error names the declared dims: {err}");
        assert!(err.contains("overflow"), "{err}");
        // u64 wrap bait: 2·(2³¹)²·4 ≡ 0 mod 2⁶⁴ matches the empty operand
        // region under unchecked math. Checked math rejects it instead.
        let err =
            frame::decode_request(frame::FT_SPDM_INLINE, &tiny_inline(0x8000_0000)).unwrap_err();
        assert!(err.contains("overflow"), "wrapping dims must be typed errors: {err}");
        // Same screen on the single-operand frames (handle-B and put_a).
        let mut hb = Vec::new();
        hb.extend_from_slice(&7u64.to_le_bytes()); // id
        hb.extend_from_slice(&1u64.to_le_bytes()); // a_handle
        hb.extend_from_slice(&0xFFFF_FFFFu32.to_le_bytes()); // n
        hb.extend_from_slice(&[0, 0]); // flags, algo
        let err = frame::decode_request(frame::FT_SPDM_HANDLE_B, &hb).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        let mut pa = Vec::new();
        pa.extend_from_slice(&7u64.to_le_bytes()); // id
        pa.extend_from_slice(&0x8000_0000u32.to_le_bytes()); // n
        pa.push(0); // algo
        let err = frame::decode_request(frame::FT_PUT_A, &pa).unwrap_err();
        assert!(err.contains("overflow"), "{err}");
        // Plain mismatch (no overflow): dims and carried bytes disagree.
        let bytes = frame::encode_spdm_handle_b(1, 1, 3, &[1.0f32; 4], None, false, false);
        let (h, p) = split(&bytes);
        let err = frame::decode_request(h.ftype, p).unwrap_err();
        assert!(err.contains("expected 1·n²·4"), "typed mismatch error: {err}");
        // Response side: a reply claiming a huge C with no bytes behind it
        // is rejected by the same checked-dims rule.
        let mut resp = Vec::new();
        resp.extend_from_slice(&1u64.to_le_bytes()); // id
        resp.push(1); // algo gcoo
        resp.push(-1i8 as u8); // verified absent
        resp.extend_from_slice(&0u32.to_le_bytes()); // n_exec
        resp.extend_from_slice(&[0u8; 24]); // convert/kernel/total ms
        resp.push(0); // has_checksum
        resp.extend_from_slice(&[0u8; 8]); // checksum
        resp.extend_from_slice(&0u64.to_le_bytes()); // a_handle none
        resp.extend_from_slice(&0u16.to_le_bytes()); // artifact len 0
        resp.extend_from_slice(&0x8000_0000u32.to_le_bytes()); // c_n wrap bait
        let err = frame::decode_response(frame::FT_RESP_SPDM, &resp).unwrap_err();
        assert!(err.contains("overflow"), "response C dims are checked too: {err}");
    }

    /// Satellite: non-finite floats cannot smuggle through the raw f32
    /// plane — the binary decode screens every element exactly like the
    /// JSON boundary's `finite_floats` (NaN would split `ASig`
    /// bit-equality from the element re-screen; Inf poisons products).
    #[test]
    fn frame_rejects_non_finite_floats() {
        let good = vec![1.0f32, 2.0, 3.0, 4.0];
        for evil in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut a = good.clone();
            a[2] = evil;
            let bytes = frame::encode_spdm_inline(1, 2, &a, &good, None, false, false);
            let (h, p) = split(&bytes);
            let err = frame::decode_request(h.ftype, p).unwrap_err();
            assert!(err.contains("non-finite"), "{evil} → {err}");
            assert!(err.contains("index 2"), "error names the offending element: {err}");
            // Same screen on B, on handle-B, and on put_a payloads.
            let mut b = good.clone();
            b[0] = evil;
            let (h, p) = split(&frame::encode_spdm_inline(1, 2, &good, &b, None, false, false));
            assert!(frame::decode_request(h.ftype, p).unwrap_err().contains("non-finite"));
            let (h, p) = split(&frame::encode_spdm_handle_b(1, 1, 2, &b, None, false, false));
            assert!(frame::decode_request(h.ftype, p).unwrap_err().contains("non-finite"));
            let (h, p) = split(&frame::encode_put_a(1, 2, &a, None));
            assert!(frame::decode_request(h.ftype, p).unwrap_err().contains("non-finite"));
        }
        // A crafted quiet-NaN bit pattern (not produced by any encoder) is
        // caught the same way: patch the raw payload bytes directly.
        let mut bytes = frame::encode_spdm_inline(1, 2, &good, &good, None, false, false);
        let off = frame::HEADER_LEN + 14; // first element of a
        bytes[off..off + 4].copy_from_slice(&0x7FC0_0001u32.to_le_bytes());
        let (h, p) = split(&bytes);
        assert!(frame::decode_request(h.ftype, p).unwrap_err().contains("non-finite"));
    }

    #[test]
    fn frame_request_id_recovery() {
        let bytes = frame::encode_spdm_inline(0xDEAD_BEEF, 2, &[1.0; 4], &[2.0; 4], None, false, false);
        let payload = &bytes[frame::HEADER_LEN..];
        assert_eq!(frame::request_id_hint(payload), 0xDEAD_BEEF);
        assert_eq!(frame::request_id_hint(&payload[..7]), 0, "short payload → id 0");
    }

    // ---- ISSUE 9: tenant id plumbing + JSON inline operand cap ---------

    /// JSON plane: absent tenant ⇒ `default` (pinned above in the v1
    /// parses); present, it is carried verbatim and validated.
    #[test]
    fn json_tenant_field_parses_and_validates() {
        let r = parse_request(
            r#"{"id":1,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,4],"tenant":"alpha"}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::Spdm { ref tenant, .. } if tenant == "alpha"));
        let r = parse_request(
            r#"{"id":2,"type":"put_a","n":2,"payload":"inline","a":[1,0,0,1],"tenant":"beta"}"#,
        )
        .unwrap();
        assert!(matches!(r, Request::PutA { ref tenant, .. } if tenant == "beta"));
        let r = parse_request(r#"{"id":3,"type":"spdm","a_handle":4,"seed":7,"tenant":"gamma"}"#)
            .unwrap();
        assert!(matches!(r, Request::Spdm { ref tenant, .. } if tenant == "gamma"));
        // Invalid tenants are typed parse errors, not silent defaults.
        for bad in [
            r#"{"id":4,"type":"spdm","n":8,"tenant":""}"#,
            r#"{"id":4,"type":"spdm","n":8,"tenant":42}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("tenant"), "{bad} → {err}");
        }
        let long = format!(r#"{{"id":4,"type":"spdm","n":8,"tenant":"{}"}}"#, "x".repeat(256));
        assert!(parse_request(&long).unwrap_err().contains("tenant"));
        // 255 bytes — the u8-length-prefix bound — is still valid.
        let edge = format!(r#"{{"id":4,"type":"spdm","n":8,"tenant":"{}"}}"#, "x".repeat(255));
        assert!(parse_request(&edge).is_ok());
    }

    /// Satellite (ISSUE 9): the JSON plane enforces the binary plane's
    /// 256 MiB operand ceiling on inline payloads — a declared n whose
    /// operands cannot fit gets a typed error before any operand work.
    #[test]
    fn json_inline_operand_cap_enforced() {
        // 2·16384²·4 = 2 GiB of declared inline operands.
        let err = parse_request(
            r#"{"id":1,"type":"spdm","n":16384,"payload":"inline","a":[],"b":[]}"#,
        )
        .unwrap_err();
        assert!(err.contains("exceed"), "typed cap error: {err}");
        assert!(err.contains("16384x16384"), "error names the declared dims: {err}");
        // put_a: 1·16384²·4 = 1 GiB.
        let err = parse_request(r#"{"id":2,"type":"put_a","n":16384,"payload":"inline","a":[]}"#)
            .unwrap_err();
        assert!(err.contains("exceed"), "{err}");
        // Handle request with declared n and inline B.
        let err = parse_request(r#"{"id":3,"type":"spdm","a_handle":1,"n":16384,"b":[]}"#)
            .unwrap_err();
        assert!(err.contains("exceed"), "{err}");
        // The edge stays valid: 1·8192²·4 = 256 MiB exactly passes the cap
        // (and then fails the ordinary size check, proving the cap screen
        // ran first and let it through).
        let err = parse_request(r#"{"id":4,"type":"put_a","n":8192,"payload":"inline","a":[]}"#)
            .unwrap_err();
        assert!(err.contains("inline a size"), "cap admits the 256 MiB edge: {err}");
        // Synthetic payloads are untouched — no inline bytes to cap.
        assert!(parse_request(r#"{"id":5,"type":"spdm","n":16384,"payload":"synthetic"}"#).is_ok());
    }

    /// Binary plane: the tenant slot round-trips on all four operand
    /// frames, and an absent tenant stays byte-identical to the
    /// pre-tenancy encoding (the compatibility contract).
    #[test]
    fn frame_tenant_slots_round_trip_and_default_stays_byte_identical() {
        let a = vec![1.0f32, -0.0, 2.0, 3.0];
        let b = vec![4.0f32, 5.0, 6.0, 7.0];
        // Empty tenant delegates to the untenanted encoders byte-for-byte.
        assert_eq!(
            frame::encode_spdm_inline_t(7, 2, &a, &b, Some(Algo::Gcoo), true, true, ""),
            frame::encode_spdm_inline(7, 2, &a, &b, Some(Algo::Gcoo), true, true),
        );
        assert_eq!(
            frame::encode_spdm_handle_b_t(8, 3, 2, &b, None, false, false, ""),
            frame::encode_spdm_handle_b(8, 3, 2, &b, None, false, false),
        );
        assert_eq!(
            frame::encode_spdm_handle_seed_t(9, 3, 42, None, false, false, ""),
            frame::encode_spdm_handle_seed(9, 3, 42, None, false, false),
        );
        assert_eq!(
            frame::encode_put_a_t(10, 2, &a, None, ""),
            frame::encode_put_a(10, 2, &a, None),
        );
        // Tagged frames decode with the tenant; everything else matches
        // the untenanted decode.
        let (h, p) = split(&frame::encode_spdm_inline_t(7, 2, &a, &b, None, true, false, "alpha"));
        let (req, want_c) = frame::decode_request(h.ftype, p).unwrap();
        assert!(!want_c);
        assert_eq!(
            req,
            Request::Spdm {
                id: 7,
                n: 2,
                payload: Payload::Inline { a: a.clone(), b: b.clone() },
                algo: None,
                verify: true,
                tenant: "alpha".into(),
            }
        );
        let (h, p) = split(&frame::encode_spdm_handle_b_t(8, 3, 2, &b, None, false, true, "beta"));
        let (req, want_c) = frame::decode_request(h.ftype, p).unwrap();
        assert!(want_c, "want_c must survive alongside the tenant flag");
        assert!(matches!(req, Request::Spdm { ref tenant, .. } if tenant == "beta"));
        let (h, p) =
            split(&frame::encode_spdm_handle_seed_t(9, 3, 42, Some(Algo::Csr), false, false, "gamma"));
        let (req, _) = frame::decode_request(h.ftype, p).unwrap();
        assert!(matches!(req, Request::Spdm { ref tenant, .. } if tenant == "gamma"));
        let bytes = frame::encode_put_a_t(10, 2, &a, Some(Algo::Gcoo), "delta");
        let (h, p) = split(&bytes);
        assert_eq!(h.ftype, frame::FT_PUT_A_T);
        let (req, _) = frame::decode_request(h.ftype, p).unwrap();
        assert_eq!(
            req,
            Request::PutA {
                id: 10,
                n: 2,
                payload: APayload::Inline { a: a.clone() },
                algo: Some(Algo::Gcoo),
                tenant: "delta".into(),
            }
        );
        // A zero-length tenant slot in a tagged frame is malformed.
        let mut zt = Vec::new();
        zt.extend_from_slice(&10u64.to_le_bytes()); // id
        zt.extend_from_slice(&2u32.to_le_bytes()); // n
        zt.push(0); // algo auto
        zt.push(0); // tlen 0
        let err = frame::decode_request(frame::FT_PUT_A_T, &zt).unwrap_err();
        assert!(err.contains("tenant"), "{err}");
        // Non-utf8 tenant bytes are typed errors too.
        let mut bad = Vec::new();
        bad.extend_from_slice(&10u64.to_le_bytes());
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.push(0);
        bad.push(2); // tlen 2
        bad.extend_from_slice(&[0xFF, 0xFE]);
        let err = frame::decode_request(frame::FT_PUT_A_T, &bad).unwrap_err();
        assert!(err.contains("tenant"), "{err}");
    }
}
