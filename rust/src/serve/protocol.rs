//! Wire protocol: one JSON object per line.
//!
//! Requests:
//!   {"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,
//!    "pattern":"uniform","seed":42,"algo":"auto","verify":false}
//!   {"id":2,"type":"spdm","n":4,"payload":"inline","a":[...16 floats],
//!    "b":[...16 floats]}
//!   {"id":3,"type":"metrics"}    {"id":4,"type":"ping"}
//!   {"id":5,"type":"stats"}   — structured metrics: the reply's `metrics`
//!   field carries the JSON-encoded snapshot (counters, latency, the
//!   batch-width histogram, and `conversions_amortized`)
//!
//! Responses:
//!   {"id":1,"ok":true,"algo":"gcoo","artifact":"gcoo_n256_…","n_exec":256,
//!    "convert_ms":0.8,"kernel_ms":3.1,"total_ms":4.2,"verified":null,
//!    "checksum":123.5}
//!   {"id":3,"ok":true,"metrics":"…"}    {"id":1,"ok":false,"error":"…"}

use crate::coordinator::Algo;
use crate::json::{self, Value};

/// How the A/B operands arrive.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Synthetic { sparsity: f64, pattern: String, seed: u64 },
    Inline { a: Vec<f32>, b: Vec<f32> },
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Spdm {
        id: u64,
        n: usize,
        payload: Payload,
        algo: Option<Algo>,
        verify: bool,
    },
    Metrics { id: u64 },
    /// Structured (JSON) metrics snapshot — the machine-readable sibling of
    /// the human-oriented `Metrics` text render.
    Stats { id: u64 },
    Ping { id: u64 },
    Shutdown { id: u64 },
}

/// A server response (subset of fields depending on request type).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Response {
    pub id: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub algo: Option<String>,
    pub artifact: Option<String>,
    pub n_exec: Option<usize>,
    pub convert_ms: Option<f64>,
    pub kernel_ms: Option<f64>,
    pub total_ms: Option<f64>,
    pub verified: Option<bool>,
    pub checksum: Option<f64>,
    pub metrics: Option<String>,
}

pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    let id = v.get("id").and_then(Value::as_u64).ok_or("missing id")?;
    match v.get("type").and_then(Value::as_str).ok_or("missing type")? {
        "ping" => Ok(Request::Ping { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "stats" => Ok(Request::Stats { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "spdm" => {
            let n = v.get("n").and_then(Value::as_usize).ok_or("missing n")?;
            if n == 0 {
                return Err("n must be positive".into());
            }
            let payload = match v.get("payload").and_then(Value::as_str).unwrap_or("synthetic") {
                "synthetic" => Payload::Synthetic {
                    sparsity: v.get("sparsity").and_then(Value::as_f64).unwrap_or(0.99),
                    pattern: v
                        .get("pattern")
                        .and_then(Value::as_str)
                        .unwrap_or("uniform")
                        .to_string(),
                    seed: v.get("seed").and_then(Value::as_u64).unwrap_or(0),
                },
                "inline" => {
                    let grab = |k: &str| -> Result<Vec<f32>, String> {
                        v.get(k)
                            .and_then(Value::as_arr)
                            .ok_or(format!("missing {k}"))?
                            .iter()
                            .map(|x| x.as_f64().map(|f| f as f32).ok_or(format!("bad {k}")))
                            .collect()
                    };
                    let a = grab("a")?;
                    let b = grab("b")?;
                    if a.len() != n * n || b.len() != n * n {
                        return Err(format!("inline payload sizes {} / {} != n²={}", a.len(), b.len(), n * n));
                    }
                    Payload::Inline { a, b }
                }
                other => return Err(format!("unknown payload kind {other}")),
            };
            let algo = match v.get("algo").and_then(Value::as_str) {
                None | Some("auto") => None,
                Some(s) => Some(Algo::from_str(s).ok_or(format!("unknown algo {s}"))?),
            };
            Ok(Request::Spdm {
                id,
                n,
                payload,
                algo,
                verify: v.get("verify").and_then(Value::as_bool).unwrap_or(false),
            })
        }
        other => Err(format!("unknown request type {other}")),
    }
}

pub fn render_response(r: &Response) -> String {
    let mut b = Value::obj().field("id", r.id).field("ok", r.ok);
    if let Some(e) = &r.error {
        b = b.field("error", e.as_str());
    }
    if let Some(a) = &r.algo {
        b = b.field("algo", a.as_str());
    }
    if let Some(a) = &r.artifact {
        b = b.field("artifact", a.as_str());
    }
    if let Some(x) = r.n_exec {
        b = b.field("n_exec", x);
    }
    if let Some(x) = r.convert_ms {
        b = b.field("convert_ms", x);
    }
    if let Some(x) = r.kernel_ms {
        b = b.field("kernel_ms", x);
    }
    if let Some(x) = r.total_ms {
        b = b.field("total_ms", x);
    }
    if let Some(x) = r.verified {
        b = b.field("verified", x);
    }
    if let Some(x) = r.checksum {
        b = b.field("checksum", x);
    }
    if let Some(m) = &r.metrics {
        b = b.field("metrics", m.as_str());
    }
    json::write(&b.build())
}

pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    Ok(Response {
        id: v.get("id").and_then(Value::as_u64).ok_or("missing id")?,
        ok: v.get("ok").and_then(Value::as_bool).ok_or("missing ok")?,
        error: v.get("error").and_then(Value::as_str).map(str::to_string),
        algo: v.get("algo").and_then(Value::as_str).map(str::to_string),
        artifact: v.get("artifact").and_then(Value::as_str).map(str::to_string),
        n_exec: v.get("n_exec").and_then(Value::as_usize),
        convert_ms: v.get("convert_ms").and_then(Value::as_f64),
        kernel_ms: v.get("kernel_ms").and_then(Value::as_f64),
        total_ms: v.get("total_ms").and_then(Value::as_f64),
        verified: v.get("verified").and_then(Value::as_bool),
        checksum: v.get("checksum").and_then(Value::as_f64),
        metrics: v.get("metrics").and_then(Value::as_str).map(str::to_string),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_request() {
        let r = parse_request(
            r#"{"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,"pattern":"banded","seed":7,"algo":"gcoo","verify":true}"#,
        )
        .unwrap();
        match r {
            Request::Spdm { id, n, payload, algo, verify } => {
                assert_eq!((id, n, verify), (1, 256, true));
                assert_eq!(algo, Some(Algo::Gcoo));
                assert_eq!(
                    payload,
                    Payload::Synthetic { sparsity: 0.99, pattern: "banded".into(), seed: 7 }
                );
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_inline_request_checks_sizes() {
        let ok = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,4]}"#;
        assert!(matches!(parse_request(ok), Ok(Request::Spdm { .. })));
        let bad = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1],"b":[1,2,3,4]}"#;
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn parse_control_requests() {
        assert!(matches!(parse_request(r#"{"id":3,"type":"ping"}"#), Ok(Request::Ping { id: 3 })));
        assert!(matches!(
            parse_request(r#"{"id":4,"type":"metrics"}"#),
            Ok(Request::Metrics { id: 4 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":6,"type":"stats"}"#),
            Ok(Request::Stats { id: 6 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":5,"type":"shutdown"}"#),
            Ok(Request::Shutdown { id: 5 })
        ));
    }

    #[test]
    fn bad_requests_rejected() {
        assert!(parse_request("garbage").is_err());
        assert!(parse_request(r#"{"type":"spdm"}"#).is_err()); // no id
        assert!(parse_request(r#"{"id":1,"type":"spdm"}"#).is_err()); // no n
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":0}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"warp"}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":4,"algo":"nope"}"#).is_err());
    }

    #[test]
    fn response_round_trip() {
        let r = Response {
            id: 9,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n256_p8_tb128_cap256".into()),
            n_exec: Some(256),
            convert_ms: Some(0.5),
            kernel_ms: Some(2.25),
            total_ms: Some(3.5),
            verified: Some(true),
            checksum: Some(42.5),
            ..Default::default()
        };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn error_response_round_trip() {
        let r = Response { id: 1, ok: false, error: Some("no artifact".into()), ..Default::default() };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed.error.as_deref(), Some("no artifact"));
        assert!(!parsed.ok);
    }
}
