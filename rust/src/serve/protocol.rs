//! Wire protocol: one JSON object per line. **v2** — additive over v1:
//! every v1 line parses and behaves unchanged; v2 adds the operand-handle
//! lifecycle (`put_a` / `drop_a` / `list_a`) and `spdm` by `a_handle`.
//!
//! v1 requests:
//!   {"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,
//!    "pattern":"uniform","seed":42,"algo":"auto","verify":false}
//!   {"id":2,"type":"spdm","n":4,"payload":"inline","a":[...16 floats],
//!    "b":[...16 floats]}
//!   {"id":3,"type":"metrics"}    {"id":4,"type":"ping"}
//!   {"id":5,"type":"stats"}   — structured metrics: the reply's `metrics`
//!   field carries the JSON-encoded snapshot (counters, latency, the
//!   batch-width histogram, `conversions_total`, the store gauges, and
//!   the adaptive-routing `route_flips`/`explorations` counters)
//!   {"id":12,"type":"explain"} — the adaptive routing table: the reply's
//!   `routing` field carries JSON with the policy in force and, per
//!   registered operand, the published version, incumbent routing, ranked
//!   candidate plans, and the tuner's per-algo latency estimates
//!
//! v2 requests (operand handles — register A once, multiply by reference):
//!   {"id":6,"type":"put_a","n":256,"payload":"synthetic","sparsity":0.99,
//!    "pattern":"uniform","seed":42,"algo":"auto"}
//!   {"id":7,"type":"put_a","n":4,"payload":"inline","a":[...16 floats]}
//!     → {"id":7,"ok":true,"a_handle":3,"algo":"gcoo","artifact":"…",
//!        "n_exec":256,"convert_ms":0.8,"reason":"sparse-crossover"}
//!       (the resolved routing, so clients can introspect the plan)
//!   {"id":8,"type":"spdm","a_handle":3,"b":[...floats],"verify":true}
//!   {"id":9,"type":"spdm","a_handle":3,"seed":7}   — synthetic B; `n` is
//!     optional on handle requests (the registered operand fixes it)
//!   {"id":10,"type":"drop_a","a_handle":3}
//!   {"id":11,"type":"list_a"}
//!     → {"id":11,"ok":true,"handles":[{"a_handle":3,"n":256,"nnz":655,
//!        "algo":"gcoo","artifact":"…","bytes":270336},…]}
//!
//! Responses (v1 shape, plus `a_handle`/`reason`/`handles` where relevant):
//!   {"id":1,"ok":true,"algo":"gcoo","artifact":"gcoo_n256_…","n_exec":256,
//!    "convert_ms":0.8,"kernel_ms":3.1,"total_ms":4.2,"verified":null,
//!    "checksum":123.5}
//!   {"id":3,"ok":true,"metrics":"…"}    {"id":1,"ok":false,"error":"…"}
//!
//! Validation happens at this boundary: non-finite floats in inline
//! payloads are rejected (a NaN would make `ASig` bit-pattern equality
//! disagree with the element-equality re-screen, silently demoting fusable
//! batches), and synthetic parameters (`sparsity` ∈ [0, 1), known
//! `pattern`) fail the request here instead of leaking into generation.

use crate::coordinator::Algo;
use crate::gen::Pattern;
use crate::json::{self, Value};

/// How the A/B operands arrive.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Synthetic { sparsity: f64, pattern: String, seed: u64 },
    Inline { a: Vec<f32>, b: Vec<f32> },
    /// v2: A by reference to a registered operand; only B travels.
    Handle { a_handle: u64, b: BPayload },
}

/// How a handle request supplies its B operand.
#[derive(Clone, Debug, PartialEq)]
pub enum BPayload {
    Inline(Vec<f32>),
    /// Server-side `randn` B from this seed (benchmarks and load tests:
    /// handle reuse without shipping n² floats per request).
    Synthetic { seed: u64 },
}

/// How `put_a` supplies the operand to register.
#[derive(Clone, Debug, PartialEq)]
pub enum APayload {
    Synthetic { sparsity: f64, pattern: String, seed: u64 },
    Inline { a: Vec<f32> },
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Spdm {
        id: u64,
        /// 0 on handle requests without an explicit `n` (the registered
        /// operand fixes the size); positive and validated otherwise.
        n: usize,
        payload: Payload,
        algo: Option<Algo>,
        verify: bool,
    },
    /// v2: register an A operand (plan + convert once, reply with the
    /// handle and the resolved routing).
    PutA { id: u64, n: usize, payload: APayload, algo: Option<Algo> },
    /// v2: drop a registered operand.
    DropA { id: u64, a_handle: u64 },
    /// v2: list registered operands with their routing/cost summaries.
    ListA { id: u64 },
    Metrics { id: u64 },
    /// Structured (JSON) metrics snapshot — the machine-readable sibling of
    /// the human-oriented `Metrics` text render.
    Stats { id: u64 },
    /// Adaptive routing table + per-entry measured estimates (the reply's
    /// `routing` field carries the JSON document).
    Explain { id: u64 },
    Ping { id: u64 },
    Shutdown { id: u64 },
}

/// One row of a `list_a` reply.
#[derive(Clone, Debug, PartialEq)]
pub struct HandleInfo {
    pub a_handle: u64,
    pub n: usize,
    pub nnz: usize,
    pub algo: String,
    pub artifact: String,
    pub bytes: u64,
}

/// A server response (subset of fields depending on request type).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Response {
    pub id: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub algo: Option<String>,
    pub artifact: Option<String>,
    pub n_exec: Option<usize>,
    pub convert_ms: Option<f64>,
    pub kernel_ms: Option<f64>,
    pub total_ms: Option<f64>,
    pub verified: Option<bool>,
    pub checksum: Option<f64>,
    pub metrics: Option<String>,
    /// v2: the operand handle (`put_a` replies; echoed on handle `spdm`).
    pub a_handle: Option<u64>,
    /// v2: why the plan chose its algorithm (`put_a` replies).
    pub reason: Option<String>,
    /// v2: `list_a` rows.
    pub handles: Option<Vec<HandleInfo>>,
    /// The `explain` reply's JSON routing table.
    pub routing: Option<String>,
}

/// Pull a float array field, rejecting non-finite entries: a NaN in A
/// would break `ASig` bit-pattern equality vs the element-equality
/// re-screen (NaN != NaN), silently demoting fusable batches; Inf
/// propagates garbage through every kernel. Reject both at the boundary.
fn finite_floats(v: &Value, k: &str) -> Result<Vec<f32>, String> {
    v.get(k)
        .and_then(Value::as_arr)
        .ok_or(format!("missing {k}"))?
        .iter()
        .map(|x| match x.as_f64() {
            // Finiteness is checked on the f32 the pipeline actually
            // stores: a finite f64 above f32::MAX (e.g. 1e39) saturates to
            // Inf in the cast and must be rejected just like a wire-level
            // Inf or NaN.
            Some(f) if (f as f32).is_finite() => Ok(f as f32),
            Some(f) => Err(format!("non-finite value {f} in {k}")),
            None => Err(format!("bad {k}")),
        })
        .collect()
}

/// Validate synthetic-payload parameters at the protocol boundary: a
/// sparsity outside [0, 1) (NaN included) or an unknown pattern name is a
/// malformed request, not a generation-time surprise.
fn synthetic_params(v: &Value) -> Result<(f64, String, u64), String> {
    let sparsity = v.get("sparsity").and_then(Value::as_f64).unwrap_or(0.99);
    if !(0.0..1.0).contains(&sparsity) {
        return Err(format!("sparsity {sparsity} outside [0, 1)"));
    }
    let pattern = v
        .get("pattern")
        .and_then(Value::as_str)
        .unwrap_or("uniform")
        .to_string();
    if Pattern::from_name(&pattern).is_none() {
        return Err(format!("unknown pattern {pattern}"));
    }
    Ok((sparsity, pattern, v.get("seed").and_then(Value::as_u64).unwrap_or(0)))
}

fn parse_algo(v: &Value) -> Result<Option<Algo>, String> {
    match v.get("algo").and_then(Value::as_str) {
        None | Some("auto") => Ok(None),
        Some(s) => Algo::from_str(s).map(Some).ok_or(format!("unknown algo {s}")),
    }
}

pub fn parse_request(line: &str) -> Result<Request, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    let id = v.get("id").and_then(Value::as_u64).ok_or("missing id")?;
    match v.get("type").and_then(Value::as_str).ok_or("missing type")? {
        "ping" => Ok(Request::Ping { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "stats" => Ok(Request::Stats { id }),
        "explain" => Ok(Request::Explain { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "spdm" => {
            // v2: an `a_handle` field selects multiply-by-reference; `n`
            // becomes optional (the registered operand fixes it) and only
            // B travels — inline, or synthetic from `seed`. The key's mere
            // presence commits to the handle path: a malformed value
            // (string, negative, fractional) is an error, never a silent
            // fall-through to a v1 synthetic multiply against the wrong A.
            if let Some(ah) = v.get("a_handle") {
                let a_handle = ah.as_u64().ok_or("invalid a_handle")?;
                let n = v.get("n").and_then(Value::as_usize).unwrap_or(0);
                let b = if v.get("b").is_some() {
                    let b = finite_floats(&v, "b")?;
                    if n > 0 && b.len() != n * n {
                        return Err(format!("inline b size {} != n²={}", b.len(), n * n));
                    }
                    BPayload::Inline(b)
                } else {
                    BPayload::Synthetic {
                        seed: v.get("seed").and_then(Value::as_u64).unwrap_or(0),
                    }
                };
                return Ok(Request::Spdm {
                    id,
                    n,
                    payload: Payload::Handle { a_handle, b },
                    algo: parse_algo(&v)?,
                    verify: v.get("verify").and_then(Value::as_bool).unwrap_or(false),
                });
            }
            let n = v.get("n").and_then(Value::as_usize).ok_or("missing n")?;
            if n == 0 {
                return Err("n must be positive".into());
            }
            let payload = match v.get("payload").and_then(Value::as_str).unwrap_or("synthetic") {
                "synthetic" => {
                    let (sparsity, pattern, seed) = synthetic_params(&v)?;
                    Payload::Synthetic { sparsity, pattern, seed }
                }
                "inline" => {
                    let a = finite_floats(&v, "a")?;
                    let b = finite_floats(&v, "b")?;
                    if a.len() != n * n || b.len() != n * n {
                        return Err(format!("inline payload sizes {} / {} != n²={}", a.len(), b.len(), n * n));
                    }
                    Payload::Inline { a, b }
                }
                other => return Err(format!("unknown payload kind {other}")),
            };
            Ok(Request::Spdm {
                id,
                n,
                payload,
                algo: parse_algo(&v)?,
                verify: v.get("verify").and_then(Value::as_bool).unwrap_or(false),
            })
        }
        "put_a" => {
            let n = v.get("n").and_then(Value::as_usize).ok_or("missing n")?;
            if n == 0 {
                return Err("n must be positive".into());
            }
            let payload = match v.get("payload").and_then(Value::as_str).unwrap_or("synthetic") {
                "synthetic" => {
                    let (sparsity, pattern, seed) = synthetic_params(&v)?;
                    APayload::Synthetic { sparsity, pattern, seed }
                }
                "inline" => {
                    let a = finite_floats(&v, "a")?;
                    if a.len() != n * n {
                        return Err(format!("inline a size {} != n²={}", a.len(), n * n));
                    }
                    APayload::Inline { a }
                }
                other => return Err(format!("unknown payload kind {other}")),
            };
            Ok(Request::PutA { id, n, payload, algo: parse_algo(&v)? })
        }
        "drop_a" => {
            let a_handle = v.get("a_handle").and_then(Value::as_u64).ok_or("missing a_handle")?;
            Ok(Request::DropA { id, a_handle })
        }
        "list_a" => Ok(Request::ListA { id }),
        other => Err(format!("unknown request type {other}")),
    }
}

pub fn render_response(r: &Response) -> String {
    let mut b = Value::obj().field("id", r.id).field("ok", r.ok);
    if let Some(e) = &r.error {
        b = b.field("error", e.as_str());
    }
    if let Some(a) = &r.algo {
        b = b.field("algo", a.as_str());
    }
    if let Some(a) = &r.artifact {
        b = b.field("artifact", a.as_str());
    }
    if let Some(x) = r.n_exec {
        b = b.field("n_exec", x);
    }
    if let Some(x) = r.convert_ms {
        b = b.field("convert_ms", x);
    }
    if let Some(x) = r.kernel_ms {
        b = b.field("kernel_ms", x);
    }
    if let Some(x) = r.total_ms {
        b = b.field("total_ms", x);
    }
    if let Some(x) = r.verified {
        b = b.field("verified", x);
    }
    if let Some(x) = r.checksum {
        b = b.field("checksum", x);
    }
    if let Some(m) = &r.metrics {
        b = b.field("metrics", m.as_str());
    }
    if let Some(h) = r.a_handle {
        b = b.field("a_handle", h);
    }
    if let Some(reason) = &r.reason {
        b = b.field("reason", reason.as_str());
    }
    if let Some(routing) = &r.routing {
        b = b.field("routing", routing.as_str());
    }
    if let Some(hs) = &r.handles {
        let rows = Value::Arr(
            hs.iter()
                .map(|h| {
                    Value::obj()
                        .field("a_handle", h.a_handle)
                        .field("n", h.n)
                        .field("nnz", h.nnz)
                        .field("algo", h.algo.as_str())
                        .field("artifact", h.artifact.as_str())
                        .field("bytes", h.bytes)
                        .build()
                })
                .collect(),
        );
        b = b.field("handles", rows);
    }
    json::write(&b.build())
}

pub fn parse_response(line: &str) -> Result<Response, String> {
    let v = json::parse(line).map_err(|e| e.to_string())?;
    Ok(Response {
        id: v.get("id").and_then(Value::as_u64).ok_or("missing id")?,
        ok: v.get("ok").and_then(Value::as_bool).ok_or("missing ok")?,
        error: v.get("error").and_then(Value::as_str).map(str::to_string),
        algo: v.get("algo").and_then(Value::as_str).map(str::to_string),
        artifact: v.get("artifact").and_then(Value::as_str).map(str::to_string),
        n_exec: v.get("n_exec").and_then(Value::as_usize),
        convert_ms: v.get("convert_ms").and_then(Value::as_f64),
        kernel_ms: v.get("kernel_ms").and_then(Value::as_f64),
        total_ms: v.get("total_ms").and_then(Value::as_f64),
        verified: v.get("verified").and_then(Value::as_bool),
        checksum: v.get("checksum").and_then(Value::as_f64),
        metrics: v.get("metrics").and_then(Value::as_str).map(str::to_string),
        a_handle: v.get("a_handle").and_then(Value::as_u64),
        reason: v.get("reason").and_then(Value::as_str).map(str::to_string),
        routing: v.get("routing").and_then(Value::as_str).map(str::to_string),
        handles: v.get("handles").and_then(Value::as_arr).map(|xs| {
            xs.iter()
                .filter_map(|x| {
                    Some(HandleInfo {
                        a_handle: x.get("a_handle")?.as_u64()?,
                        n: x.get("n")?.as_usize()?,
                        nnz: x.get("nnz")?.as_usize()?,
                        algo: x.get("algo")?.as_str()?.to_string(),
                        artifact: x.get("artifact")?.as_str()?.to_string(),
                        bytes: x.get("bytes")?.as_u64()?,
                    })
                })
                .collect()
        }),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_request() {
        let r = parse_request(
            r#"{"id":1,"type":"spdm","n":256,"payload":"synthetic","sparsity":0.99,"pattern":"banded","seed":7,"algo":"gcoo","verify":true}"#,
        )
        .unwrap();
        match r {
            Request::Spdm { id, n, payload, algo, verify } => {
                assert_eq!((id, n, verify), (1, 256, true));
                assert_eq!(algo, Some(Algo::Gcoo));
                assert_eq!(
                    payload,
                    Payload::Synthetic { sparsity: 0.99, pattern: "banded".into(), seed: 7 }
                );
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn parse_inline_request_checks_sizes() {
        let ok = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,4]}"#;
        assert!(matches!(parse_request(ok), Ok(Request::Spdm { .. })));
        let bad = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1],"b":[1,2,3,4]}"#;
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn parse_control_requests() {
        assert!(matches!(parse_request(r#"{"id":3,"type":"ping"}"#), Ok(Request::Ping { id: 3 })));
        assert!(matches!(
            parse_request(r#"{"id":4,"type":"metrics"}"#),
            Ok(Request::Metrics { id: 4 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":6,"type":"stats"}"#),
            Ok(Request::Stats { id: 6 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":5,"type":"shutdown"}"#),
            Ok(Request::Shutdown { id: 5 })
        ));
        assert!(matches!(
            parse_request(r#"{"id":7,"type":"explain"}"#),
            Ok(Request::Explain { id: 7 })
        ));
    }

    #[test]
    fn explain_response_round_trips() {
        let r = Response {
            id: 12,
            ok: true,
            routing: Some(r#"{"route_flips":1,"entries":[]}"#.into()),
            ..Default::default()
        };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed, r);
        // The payload is itself parseable JSON (the explain contract).
        let doc = crate::json::parse(parsed.routing.as_deref().unwrap()).unwrap();
        assert_eq!(doc.get("route_flips").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn bad_requests_rejected() {
        assert!(parse_request("garbage").is_err());
        assert!(parse_request(r#"{"type":"spdm"}"#).is_err()); // no id
        assert!(parse_request(r#"{"id":1,"type":"spdm"}"#).is_err()); // no n
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":0}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"warp"}"#).is_err());
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":4,"algo":"nope"}"#).is_err());
    }

    #[test]
    fn parse_handle_spdm_requests() {
        // Inline B; n optional on handle requests.
        let r = parse_request(r#"{"id":8,"type":"spdm","a_handle":3,"b":[1,2,3,4],"verify":true}"#)
            .unwrap();
        match r {
            Request::Spdm { id, n, payload, algo, verify } => {
                assert_eq!((id, n, verify), (8, 0, true));
                assert_eq!(algo, None);
                assert_eq!(
                    payload,
                    Payload::Handle { a_handle: 3, b: BPayload::Inline(vec![1.0, 2.0, 3.0, 4.0]) }
                );
            }
            _ => panic!("wrong variant"),
        }
        // Synthetic B from a seed; explicit n is validated against b when
        // inline and carried through otherwise.
        let r = parse_request(r#"{"id":9,"type":"spdm","a_handle":3,"seed":7,"algo":"gcoo"}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::Spdm {
                id: 9,
                n: 0,
                payload: Payload::Handle { a_handle: 3, b: BPayload::Synthetic { seed: 7 } },
                algo: Some(Algo::Gcoo),
                verify: false,
            }
        );
        // Explicit n with a mismatched inline B fails at parse.
        assert!(parse_request(
            r#"{"id":8,"type":"spdm","a_handle":3,"n":4,"b":[1,2,3,4]}"#
        )
        .is_err());
        // A malformed a_handle is an error, not a silent fall-through to
        // the v1 synthetic path (which would multiply against the wrong A).
        for bad in [
            r#"{"id":8,"type":"spdm","a_handle":"3","n":64,"seed":7}"#,
            r#"{"id":8,"type":"spdm","a_handle":-1,"n":64,"seed":7}"#,
            r#"{"id":8,"type":"spdm","a_handle":3.5,"n":64,"seed":7}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("a_handle"), "{bad} → {err}");
        }
    }

    #[test]
    fn parse_put_a_requests() {
        let r = parse_request(
            r#"{"id":6,"type":"put_a","n":64,"payload":"synthetic","sparsity":0.99,"pattern":"banded","seed":5,"algo":"csr"}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::PutA {
                id: 6,
                n: 64,
                payload: APayload::Synthetic { sparsity: 0.99, pattern: "banded".into(), seed: 5 },
                algo: Some(Algo::Csr),
            }
        );
        let r = parse_request(r#"{"id":7,"type":"put_a","n":2,"payload":"inline","a":[1,0,0,1]}"#)
            .unwrap();
        assert_eq!(
            r,
            Request::PutA {
                id: 7,
                n: 2,
                payload: APayload::Inline { a: vec![1.0, 0.0, 0.0, 1.0] },
                algo: None,
            }
        );
        // Size and positivity checks mirror v1 spdm.
        assert!(parse_request(r#"{"id":7,"type":"put_a","n":2,"payload":"inline","a":[1]}"#).is_err());
        assert!(parse_request(r#"{"id":7,"type":"put_a","n":0}"#).is_err());
        assert!(parse_request(r#"{"id":7,"type":"put_a"}"#).is_err());
    }

    #[test]
    fn parse_handle_lifecycle_requests() {
        assert_eq!(
            parse_request(r#"{"id":10,"type":"drop_a","a_handle":3}"#).unwrap(),
            Request::DropA { id: 10, a_handle: 3 }
        );
        assert!(parse_request(r#"{"id":10,"type":"drop_a"}"#).is_err(), "a_handle required");
        assert_eq!(parse_request(r#"{"id":11,"type":"list_a"}"#).unwrap(), Request::ListA { id: 11 });
    }

    /// Satellite bugfix: non-finite floats in inline payloads are rejected
    /// at the boundary — a NaN would split `ASig` equality from the
    /// element-equality re-screen (NaN != NaN) and silently demote fusable
    /// batches; Inf poisons every product.
    #[test]
    fn non_finite_inline_floats_rejected() {
        // Our writer never emits bare NaN/Infinity tokens, but "1e999"
        // overflows f64 parsing to +Inf — a real wire-level vector.
        let inf = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1e999],"b":[1,2,3,4]}"#;
        let err = parse_request(inf).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        let inf_b = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1,0,0,1],"b":[1,2,3,-1e999]}"#;
        assert!(parse_request(inf_b).unwrap_err().contains("non-finite"));
        let put = r#"{"id":2,"type":"put_a","n":2,"payload":"inline","a":[1e999,0,0,1]}"#;
        assert!(parse_request(put).unwrap_err().contains("non-finite"));
        let handle_b = r#"{"id":2,"type":"spdm","a_handle":1,"b":[1e999]}"#;
        assert!(parse_request(handle_b).unwrap_err().contains("non-finite"));
        // A finite f64 beyond f32::MAX saturates to Inf in the cast the
        // pipeline performs — it must be rejected like a literal Inf.
        let overflow = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[1e39,0,0,1],"b":[1,2,3,4]}"#;
        assert!(parse_request(overflow).unwrap_err().contains("non-finite"));
        // The f32 edge itself stays valid.
        let edge = r#"{"id":2,"type":"spdm","n":2,"payload":"inline","a":[3.4e38,0,0,1],"b":[1,2,3,4]}"#;
        assert!(parse_request(edge).is_ok());
    }

    /// Satellite bugfix: synthetic parameters are validated at parse time —
    /// sparsity outside [0, 1) and unknown pattern names fail the request
    /// instead of flowing into generation.
    #[test]
    fn synthetic_params_validated_at_parse() {
        for bad in [
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":1.0}"#,
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":-0.1}"#,
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":2.5}"#,
            r#"{"id":1,"type":"put_a","n":8,"payload":"synthetic","sparsity":1.5}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("sparsity"), "{bad} → {err}");
        }
        for bad in [
            r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","pattern":"not_a_pattern"}"#,
            r#"{"id":1,"type":"put_a","n":8,"payload":"synthetic","pattern":"warp"}"#,
        ] {
            let err = parse_request(bad).unwrap_err();
            assert!(err.contains("pattern"), "{bad} → {err}");
        }
        // The valid edges stay valid.
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":0.0}"#).is_ok());
        assert!(parse_request(r#"{"id":1,"type":"spdm","n":8,"payload":"synthetic","sparsity":0.999}"#).is_ok());
    }

    #[test]
    fn v2_response_round_trip() {
        let r = Response {
            id: 6,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n256_cap512".into()),
            n_exec: Some(256),
            convert_ms: Some(0.75),
            a_handle: Some(3),
            reason: Some("sparse-crossover".into()),
            ..Default::default()
        };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
        let r = Response {
            id: 11,
            ok: true,
            handles: Some(vec![
                HandleInfo {
                    a_handle: 3,
                    n: 256,
                    nnz: 655,
                    algo: "gcoo".into(),
                    artifact: "gcoo_n256_cap512".into(),
                    bytes: 270336,
                },
                HandleInfo {
                    a_handle: 4,
                    n: 64,
                    nnz: 40,
                    algo: "csr".into(),
                    artifact: "csr_n64_rowcap64".into(),
                    bytes: 18432,
                },
            ]),
            ..Default::default()
        };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
        // Empty list round-trips too.
        let r = Response { id: 12, ok: true, handles: Some(vec![]), ..Default::default() };
        assert_eq!(parse_response(&render_response(&r)).unwrap(), r);
    }

    #[test]
    fn response_round_trip() {
        let r = Response {
            id: 9,
            ok: true,
            algo: Some("gcoo".into()),
            artifact: Some("gcoo_n256_p8_tb128_cap256".into()),
            n_exec: Some(256),
            convert_ms: Some(0.5),
            kernel_ms: Some(2.25),
            total_ms: Some(3.5),
            verified: Some(true),
            checksum: Some(42.5),
            ..Default::default()
        };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn error_response_round_trip() {
        let r = Response { id: 1, ok: false, error: Some("no artifact".into()), ..Default::default() };
        let parsed = parse_response(&render_response(&r)).unwrap();
        assert_eq!(parsed.error.as_deref(), Some("no artifact"));
        assert!(!parsed.ok);
    }
}
