//! Blocking TCP client for the serving protocol. Speaks both planes:
//! JSON v1/v2 lines (the `*_` methods below, unchanged since PR 4) and
//! binary v3 frames (the `*_bin` methods), freely interleaved on one
//! connection. Every method counts bytes written/read so benches can
//! report wire cost per request (`bytes_on_wire`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use super::protocol::{frame, parse_response, Response};
use crate::json::Value;
use crate::ndarray::Mat;
use crate::runtime::Algo;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    bytes_sent: u64,
    bytes_received: u64,
    /// Tenant id stamped onto every subsequent request on both planes
    /// (ISSUE 9). `None` leaves the wire byte-identical to a pre-tenancy
    /// client: no `tenant` field on JSON lines, untagged v3 frames.
    tenant: Option<String>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader, bytes_sent: 0, bytes_received: 0, tenant: None })
    }

    /// Cluster-aware addressing: dial addresses in order and connect to
    /// the first that answers. A client holding a cluster membership doc
    /// passes the router address first, then the node addresses as
    /// fallbacks (every node speaks the full protocol for the operands it
    /// owns). Returns the last connect error if nothing is reachable.
    pub fn connect_any<S: AsRef<str>>(addrs: &[S]) -> std::io::Result<Client> {
        let mut last: Option<std::io::Error> = None;
        for addr in addrs {
            match Client::connect(addr.as_ref()) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "connect_any: empty address list",
            )
        }))
    }

    /// Total bytes this client has put on / taken off the wire, across
    /// both planes: `(sent, received)`.
    pub fn bytes_on_wire(&self) -> (u64, u64) {
        (self.bytes_sent, self.bytes_received)
    }

    /// Reset the wire counters (e.g. between bench phases on one
    /// connection).
    pub fn reset_wire_counters(&mut self) {
        self.bytes_sent = 0;
        self.bytes_received = 0;
    }

    /// Tag (or untag, with `None`) every subsequent request with a tenant
    /// id. Applies to both planes; `None` restores the pre-tenancy wire
    /// encoding byte for byte.
    pub fn set_tenant(&mut self, tenant: Option<&str>) {
        self.tenant = tenant.map(str::to_string);
    }

    /// JSON plane: append the `tenant` field when one is set.
    fn tag_tenant(&self, o: crate::json::ObjBuilder) -> crate::json::ObjBuilder {
        match &self.tenant {
            Some(t) => o.field("tenant", t.as_str()),
            None => o,
        }
    }

    /// Binary plane: the tenant slot payload ("" = encode untagged frames).
    fn tenant_str(&self) -> &str {
        self.tenant.as_deref().unwrap_or("")
    }

    fn round_trip(&mut self, line: &str) -> Result<Response, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())?;
        self.bytes_sent += line.len() as u64 + 1;
        let mut buf = String::new();
        self.reader.read_line(&mut buf).map_err(|e| e.to_string())?;
        self.bytes_received += buf.len() as u64;
        parse_response(buf.trim())
    }

    /// Write one v3 frame, read one v3 reply frame. Returns the decoded
    /// response plus the full C matrix when the reply carried one
    /// (`want_c` requests).
    fn frame_round_trip(&mut self, bytes: &[u8]) -> Result<(Response, Option<Mat>), String> {
        self.writer
            .write_all(bytes)
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())?;
        self.bytes_sent += bytes.len() as u64;
        let mut hdr = [0u8; frame::HEADER_LEN];
        self.reader.read_exact(&mut hdr).map_err(|e| e.to_string())?;
        let h = frame::parse_header(&hdr)?;
        let mut payload = vec![0u8; h.len];
        self.reader.read_exact(&mut payload).map_err(|e| e.to_string())?;
        self.bytes_received += (frame::HEADER_LEN + h.len) as u64;
        frame::decode_response(h.ftype, &payload)
    }

    pub fn ping(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "ping").build(),
        ))
    }

    pub fn metrics(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "metrics").build(),
        ))
    }

    /// Structured metrics snapshot: the reply's `metrics` field is the
    /// JSON-encoded `MetricsSnapshot` (parse it with `json::parse`).
    pub fn stats(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "stats").build(),
        ))
    }

    /// Adaptive routing table: the reply's `routing` field is the
    /// JSON-encoded explain document (policy, flip/exploration counters,
    /// per-entry candidates + estimates).
    pub fn explain(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "explain").build(),
        ))
    }

    pub fn shutdown(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "shutdown").build(),
        ))
    }

    /// Synthetic-workload SpDM request.
    #[allow(clippy::too_many_arguments)]
    pub fn spdm_synthetic(
        &mut self,
        id: u64,
        n: usize,
        sparsity: f64,
        pattern: &str,
        seed: u64,
        algo: &str,
        verify: bool,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "spdm")
            .field("n", n)
            .field("payload", "synthetic")
            .field("sparsity", sparsity)
            .field("pattern", pattern)
            .field("seed", seed)
            .field("algo", algo)
            .field("verify", verify);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// Inline-payload SpDM request.
    pub fn spdm_inline(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        b: &[f32],
        verify: bool,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "spdm")
            .field("n", n)
            .field("payload", "inline")
            .field("a", to_arr(a))
            .field("b", to_arr(b))
            .field("verify", verify);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// v2: register an inline A operand. The reply's `a_handle` names it;
    /// `algo`/`artifact`/`n_exec`/`reason`/`convert_ms` expose the resolved
    /// routing and the one-time conversion cost.
    pub fn put_a_inline(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        algo: &str,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "put_a")
            .field("n", n)
            .field("payload", "inline")
            .field("a", to_arr(a))
            .field("algo", algo);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// v2: register a synthetic A operand (server-side generation).
    #[allow(clippy::too_many_arguments)]
    pub fn put_a_synthetic(
        &mut self,
        id: u64,
        n: usize,
        sparsity: f64,
        pattern: &str,
        seed: u64,
        algo: &str,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "put_a")
            .field("n", n)
            .field("payload", "synthetic")
            .field("sparsity", sparsity)
            .field("pattern", pattern)
            .field("seed", seed)
            .field("algo", algo);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// v2: multiply a registered A by an inline B.
    pub fn spdm_handle(
        &mut self,
        id: u64,
        a_handle: u64,
        b: &[f32],
        verify: bool,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "spdm")
            .field("a_handle", a_handle)
            .field("b", to_arr(b))
            .field("verify", verify);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// v2: multiply a registered A by a synthetic (seeded) B — handle reuse
    /// without shipping n² floats per request.
    pub fn spdm_handle_synthetic_b(
        &mut self,
        id: u64,
        a_handle: u64,
        seed: u64,
        verify: bool,
    ) -> Result<Response, String> {
        let line = Value::obj()
            .field("id", id)
            .field("type", "spdm")
            .field("a_handle", a_handle)
            .field("seed", seed)
            .field("verify", verify);
        self.round_trip(&crate::json::write(&self.tag_tenant(line).build()))
    }

    /// v2: drop a registered operand.
    pub fn drop_a(&mut self, id: u64, a_handle: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "drop_a")
                .field("a_handle", a_handle)
                .build(),
        ))
    }

    /// v2: list registered operands (the reply's `handles` rows).
    pub fn list_a(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "list_a").build(),
        ))
    }

    // ---- binary v3 plane -------------------------------------------------

    /// v3: inline SpDM as a binary frame — raw little-endian f32 operands,
    /// no text parse server-side. With `want_c` the reply carries the full
    /// C matrix as raw f32s.
    #[allow(clippy::too_many_arguments)]
    pub fn spdm_inline_bin(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Result<(Response, Option<Mat>), String> {
        let f = frame::encode_spdm_inline_t(id, n, a, b, algo, verify, want_c, self.tenant_str());
        self.frame_round_trip(&f)
    }

    /// v3: multiply a registered A by an inline B, as a binary frame.
    #[allow(clippy::too_many_arguments)]
    pub fn spdm_handle_bin(
        &mut self,
        id: u64,
        a_handle: u64,
        n: usize,
        b: &[f32],
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Result<(Response, Option<Mat>), String> {
        let f = frame::encode_spdm_handle_b_t(
            id,
            a_handle,
            n,
            b,
            algo,
            verify,
            want_c,
            self.tenant_str(),
        );
        self.frame_round_trip(&f)
    }

    /// v3: multiply a registered A by a synthetic (seeded) B, as a binary
    /// frame.
    pub fn spdm_handle_synthetic_b_bin(
        &mut self,
        id: u64,
        a_handle: u64,
        seed: u64,
        algo: Option<Algo>,
        verify: bool,
        want_c: bool,
    ) -> Result<(Response, Option<Mat>), String> {
        let f = frame::encode_spdm_handle_seed_t(
            id,
            a_handle,
            seed,
            algo,
            verify,
            want_c,
            self.tenant_str(),
        );
        self.frame_round_trip(&f)
    }

    /// v3: register an inline A operand, as a binary frame.
    pub fn put_a_inline_bin(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        algo: Option<Algo>,
    ) -> Result<Response, String> {
        let f = frame::encode_put_a_t(id, n, a, algo, self.tenant_str());
        self.frame_round_trip(&f).map(|(r, _)| r)
    }

    /// v3: liveness check over the binary plane.
    pub fn ping_bin(&mut self, id: u64) -> Result<Response, String> {
        let f = frame::encode_ping(id);
        self.frame_round_trip(&f).map(|(r, _)| r)
    }
}

fn to_arr(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}
