//! Blocking TCP client for the serving protocol.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use super::protocol::{parse_response, Response};
use crate::json::Value;

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    fn round_trip(&mut self, line: &str) -> Result<Response, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf).map_err(|e| e.to_string())?;
        parse_response(buf.trim())
    }

    pub fn ping(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "ping").build(),
        ))
    }

    pub fn metrics(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "metrics").build(),
        ))
    }

    /// Structured metrics snapshot: the reply's `metrics` field is the
    /// JSON-encoded `MetricsSnapshot` (parse it with `json::parse`).
    pub fn stats(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "stats").build(),
        ))
    }

    /// Adaptive routing table: the reply's `routing` field is the
    /// JSON-encoded explain document (policy, flip/exploration counters,
    /// per-entry candidates + estimates).
    pub fn explain(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "explain").build(),
        ))
    }

    pub fn shutdown(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "shutdown").build(),
        ))
    }

    /// Synthetic-workload SpDM request.
    #[allow(clippy::too_many_arguments)]
    pub fn spdm_synthetic(
        &mut self,
        id: u64,
        n: usize,
        sparsity: f64,
        pattern: &str,
        seed: u64,
        algo: &str,
        verify: bool,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "spdm")
                .field("n", n)
                .field("payload", "synthetic")
                .field("sparsity", sparsity)
                .field("pattern", pattern)
                .field("seed", seed)
                .field("algo", algo)
                .field("verify", verify)
                .build(),
        );
        self.round_trip(&line)
    }

    /// Inline-payload SpDM request.
    pub fn spdm_inline(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        b: &[f32],
        verify: bool,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "spdm")
                .field("n", n)
                .field("payload", "inline")
                .field("a", to_arr(a))
                .field("b", to_arr(b))
                .field("verify", verify)
                .build(),
        );
        self.round_trip(&line)
    }

    /// v2: register an inline A operand. The reply's `a_handle` names it;
    /// `algo`/`artifact`/`n_exec`/`reason`/`convert_ms` expose the resolved
    /// routing and the one-time conversion cost.
    pub fn put_a_inline(
        &mut self,
        id: u64,
        n: usize,
        a: &[f32],
        algo: &str,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "put_a")
                .field("n", n)
                .field("payload", "inline")
                .field("a", to_arr(a))
                .field("algo", algo)
                .build(),
        );
        self.round_trip(&line)
    }

    /// v2: register a synthetic A operand (server-side generation).
    #[allow(clippy::too_many_arguments)]
    pub fn put_a_synthetic(
        &mut self,
        id: u64,
        n: usize,
        sparsity: f64,
        pattern: &str,
        seed: u64,
        algo: &str,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "put_a")
                .field("n", n)
                .field("payload", "synthetic")
                .field("sparsity", sparsity)
                .field("pattern", pattern)
                .field("seed", seed)
                .field("algo", algo)
                .build(),
        );
        self.round_trip(&line)
    }

    /// v2: multiply a registered A by an inline B.
    pub fn spdm_handle(
        &mut self,
        id: u64,
        a_handle: u64,
        b: &[f32],
        verify: bool,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "spdm")
                .field("a_handle", a_handle)
                .field("b", to_arr(b))
                .field("verify", verify)
                .build(),
        );
        self.round_trip(&line)
    }

    /// v2: multiply a registered A by a synthetic (seeded) B — handle reuse
    /// without shipping n² floats per request.
    pub fn spdm_handle_synthetic_b(
        &mut self,
        id: u64,
        a_handle: u64,
        seed: u64,
        verify: bool,
    ) -> Result<Response, String> {
        let line = crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "spdm")
                .field("a_handle", a_handle)
                .field("seed", seed)
                .field("verify", verify)
                .build(),
        );
        self.round_trip(&line)
    }

    /// v2: drop a registered operand.
    pub fn drop_a(&mut self, id: u64, a_handle: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj()
                .field("id", id)
                .field("type", "drop_a")
                .field("a_handle", a_handle)
                .build(),
        ))
    }

    /// v2: list registered operands (the reply's `handles` rows).
    pub fn list_a(&mut self, id: u64) -> Result<Response, String> {
        self.round_trip(&crate::json::write(
            &Value::obj().field("id", id).field("type", "list_a").build(),
        ))
    }
}

fn to_arr(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}
