//! Serving layer: two wire planes over one TCP listener, server and client.
//!
//! The JSON debug/compat plane (v1/v2, line-delimited) is byte-for-byte
//! unchanged; the binary data plane (v3, [`frame`]) ships operands as raw
//! little-endian f32 payloads in length-prefixed frames so the hot path
//! pays no per-float text parse and no utf-8 validation. The server sniffs
//! the first byte of each message (`{` → JSON line, magic `0xB3` → frame)
//! and both planes decode into the same [`Request`] and run one dispatch
//! core — encoding can change wire cost, never results (DESIGN.md §Wire).
//!
//! A request carries inline matrix data, a synthetic-workload spec the
//! server materializes with [`crate::gen`], or (v2/v3) an `a_handle`
//! referencing an operand registered once via `put_a` and served from the
//! coordinator's converted-operand store — the register-once /
//! multiply-by-reference contract that amortizes the paper's conversion
//! overhead across all traffic sharing an A.

mod protocol;
mod server;
mod client;
mod trace;

pub use protocol::{
    frame, parse_request, parse_response, render_response, APayload, BPayload, HandleInfo,
    Payload, Request, Response,
};
pub use server::{Server, ServerConfig};
pub use client::Client;
pub use trace::{
    generate as generate_trace, replay as replay_trace, shared_pool, ReplayKind, ReplayOutcome,
    ReplayReport, SharedA, TraceItem, TraceSpec,
};
