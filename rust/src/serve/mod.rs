//! Serving layer: line-delimited-JSON protocol over TCP, server and client.
//!
//! The request path is rust-only: a request carries inline matrix data, a
//! synthetic-workload spec the server materializes with [`crate::gen`], or
//! (protocol v2) an `a_handle` referencing an operand registered once via
//! `put_a` and served from the coordinator's converted-operand store —
//! the register-once / multiply-by-reference contract that amortizes the
//! paper's conversion overhead across all traffic sharing an A.

mod protocol;
mod server;
mod client;
mod trace;

pub use protocol::{
    parse_request, parse_response, render_response, APayload, BPayload, HandleInfo, Payload,
    Request, Response,
};
pub use server::{Server, ServerConfig};
pub use client::Client;
pub use trace::{
    generate as generate_trace, replay as replay_trace, shared_pool, ReplayKind, ReplayOutcome,
    ReplayReport, SharedA, TraceItem, TraceSpec,
};
