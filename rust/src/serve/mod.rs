//! Serving layer: two wire planes over one TCP listener, server, client,
//! and the sharded multi-coordinator cluster (`cluster.rs`): N coordinator
//! nodes behind a stateless consistent-hash router that forwards raw
//! bytes, replicates hot operands, and fails over to ring successors — a
//! K-node cluster answers bitwise identically to a single node.
//!
//! The JSON debug/compat plane (v1/v2, line-delimited) is byte-for-byte
//! unchanged; the binary data plane (v3, [`frame`]) ships operands as raw
//! little-endian f32 payloads in length-prefixed frames so the hot path
//! pays no per-float text parse and no utf-8 validation. The server sniffs
//! the first byte of each message (`{` → JSON line, magic `0xB3` → frame)
//! and both planes decode into the same [`Request`] and run one dispatch
//! core — encoding can change wire cost, never results (DESIGN.md §Wire).
//!
//! A request carries inline matrix data, a synthetic-workload spec the
//! server materializes with [`crate::gen`], or (v2/v3) an `a_handle`
//! referencing an operand registered once via `put_a` and served from the
//! coordinator's converted-operand store — the register-once /
//! multiply-by-reference contract that amortizes the paper's conversion
//! overhead across all traffic sharing an A.

mod protocol;
mod server;
mod cluster;
mod client;
mod trace;

pub use cluster::{
    aggregate_snapshots, Cluster, ClusterConfig, Membership, NodeInfo, DEGRADED_PREFIX,
    MEMBERSHIP_VERSION,
};
pub use protocol::{
    frame, parse_request, parse_response, render_response, APayload, BPayload, HandleInfo,
    Payload, Request, Response,
};
pub use server::{Server, ServerConfig};
pub use client::Client;
pub use trace::{
    generate as generate_trace, replay as replay_trace, shared_pool, ReplayKind, ReplayOutcome,
    ReplayReport, SharedA, TraceItem, TraceSpec,
};
