//! Serving layer: line-delimited-JSON protocol over TCP, server and client.
//!
//! The request path is rust-only: a request either carries inline matrix
//! data or (for benchmarking and the examples) a synthetic-workload spec the
//! server materializes with [`crate::gen`] before handing the job to the
//! coordinator.

mod protocol;
mod server;
mod client;
mod trace;

pub use protocol::{Request, Response, Payload, parse_request, render_response, parse_response};
pub use server::{Server, ServerConfig};
pub use client::Client;
pub use trace::{TraceSpec, TraceItem, ReplayReport, generate as generate_trace, replay as replay_trace};
